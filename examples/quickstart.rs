//! Quickstart: load an FBQuant-quantized checkpoint and generate text.
//!
//! ```sh
//! cargo run --release --example quickstart
//! cargo run --release --example quickstart -- pjrt     # via AOT artifacts
//! ```
//!
//! Demonstrates the minimal public-API path: WeightStore → backend →
//! Coordinator closed loop.

use fbquant::coordinator::backend::{Backend, NativeBackend, PjrtBackend};
use fbquant::coordinator::request::GenRequest;
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::{ByteTokenizer, WeightStore};
use fbquant::runtime::ExecRegistry;

fn main() -> anyhow::Result<()> {
    let backend_kind = std::env::args().nth(1).unwrap_or_else(|| "native".into());
    let artifacts = fbquant::artifacts_dir();

    // 1) load an FBQuant INT4 checkpoint of the tiny llama-shaped model
    let path = WeightStore::path_for(&artifacts, "llamoid-tiny", "fbquant", 4);
    let store = WeightStore::load(&path)?;
    println!(
        "loaded {}: {} params, {} resident",
        store.cfg.name,
        store.cfg.n_params(),
        fbquant::util::human_bytes(store.resident_bytes())
    );

    // 2) pick an execution backend
    let mut backend: Box<dyn Backend> = if backend_kind == "pjrt" {
        let mut reg = ExecRegistry::open(&artifacts)?;
        Box::new(PjrtBackend::new(&mut reg, &store, &[1], "quickstart")?)
    } else {
        Box::new(NativeBackend::new(
            NativeEngine::from_store(&store, SubMode::Fused)?,
            "quickstart",
        ))
    };

    // 3) generate a few continuations
    let tok = ByteTokenizer::default();
    let prompts =
        ["= sea =\nthe salty crab ", "= winter =\nthe pale snow ", "two plus three equals "];
    for prompt in prompts {
        let req = GenRequest::new(0, tok.encode(prompt), 40);
        let (mut responses, _metrics) =
            Coordinator::run_closed_loop(
                backend.as_mut(),
                vec![req],
                &CoordinatorConfig::default(),
            )?;
        let r = responses.remove(0);
        println!(
            "\n> {prompt}{}\n  [{:.1} tk/s decode, ttft {:.1} ms]",
            tok.decode(&r.tokens),
            r.decode_tps(),
            r.ttft_us / 1e3
        );
    }
    Ok(())
}
