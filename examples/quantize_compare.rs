//! Compare the quantizer zoo on one model: per-layer reconstruction
//! error, bound compliance, resident bytes and a quick perplexity probe.
//!
//! ```sh
//! cargo run --release --example quantize_compare -- [model] [bits]
//! ```

use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::eval::ppl::{perplexity, PplConfig};
use fbquant::eval::scorer::NativeScorer;
use fbquant::model::{LinearWeights, WeightStore};
use fbquant::quant::subbranch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("llamoid-tiny").to_string();
    let bits: u8 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let artifacts = fbquant::artifacts_dir();

    let fp = WeightStore::load(&WeightStore::path_for(&artifacts, &model, "fp", bits))?;
    let stream = TokenStream::load(&artifacts.join("data/corpus_val.fbqw"))?;
    let ppl_cfg = PplConfig { seq: 128, max_tokens: 4096 };

    println!("=== quantizer zoo on {model} @ {bits}-bit (group 128) ===\n");
    println!(
        "{:<11} {:>10} {:>12} {:>12} {:>10} {:>9}",
        "method", "val ppl", "mean |ΔW|", "max |ΔW|", "bytes", "bounded"
    );
    println!("{}", "-".repeat(70));

    let methods =
        ["rtn", "gptq", "awq", "omniquant", "loftq", "svdquant", "caldera", "eora", "fbquant"];
    for method in methods {
        let path = WeightStore::path_for(&artifacts, &model, method, bits);
        let Ok(store) = WeightStore::load(&path) else {
            println!("{method:<11} (missing)");
            continue;
        };
        // weight-space stats vs the FP reference
        let mut sum_dev = 0f64;
        let mut count = 0usize;
        let mut max_dev = 0f32;
        let mut bounded = true;
        for l in 0..store.cfg.n_layers {
            for lname in store.cfg.linear_names() {
                let prefix = format!("l{l}.{lname}");
                let (out, cin) = store.cfg.linear_shape(lname);
                let LinearWeights::Dense { w, .. } = fp.linear(&prefix)? else { unreachable!() };
                let lw = store.linear(&prefix)?;
                let mut q = lw.clone();
                if let LinearWeights::Quant { col_scale, .. } = &mut q {
                    *col_scale = None; // bound is about the weight grid
                }
                let w_eff = q.effective_dense();
                let sigma = match lw {
                    LinearWeights::Quant { a: Some(a), b: Some(b), rank, .. } => {
                        subbranch::SubBranch::new(a.clone(), b.clone(), *rank, cin, out)
                            .dense_sigma()
                    }
                    _ => vec![0f32; out * cin],
                };
                let bound = subbranch::fbq_bound(w, &sigma, out, cin, bits, store.group);
                for i in 0..w.len() {
                    let dev = (w[i] - w_eff[i]).abs();
                    sum_dev += dev as f64;
                    count += 1;
                    max_dev = max_dev.max(dev);
                    if dev > bound[i] + 1e-4 {
                        bounded = false;
                    }
                }
            }
        }
        let mut scorer = NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused)?);
        let ppl = perplexity(&mut scorer, &stream, ppl_cfg)?.ppl;
        println!(
            "{:<11} {:>10.4} {:>12.5} {:>12.4} {:>10} {:>9}",
            method,
            ppl,
            sum_dev / count as f64,
            max_dev,
            fbquant::util::human_bytes(store.resident_bytes()),
            if bounded { "yes" } else { "no" }
        );
    }

    let mut fp_scorer = NativeScorer::new(NativeEngine::from_store(&fp, SubMode::None)?);
    let fp_ppl = perplexity(&mut fp_scorer, &stream, ppl_cfg)?.ppl;
    println!("\nFP reference ppl: {fp_ppl:.4}");
    Ok(())
}
