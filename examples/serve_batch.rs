//! Serving under concurrent load: spawn the coordinator worker, submit a
//! Poisson-arrival workload, consume the per-request event streams and
//! report latency, throughput and slot-occupancy percentiles.
//!
//! Tokens arrive incrementally (continuous batching streams every sampled
//! token), so the client-side time-to-first-token is measured from the
//! first `Token` event — not from the final response.
//!
//! ```sh
//! cargo run --release --example serve_batch -- [requests] [rate_rps]
//! ```

use fbquant::coordinator::request::GenEvent;
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::coordinator::workload::{generate, WorkloadConfig};
use fbquant::coordinator::Backend;
use fbquant::coordinator::NativeBackend;
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let artifacts = fbquant::artifacts_dir();

    let stream = TokenStream::load(&artifacts.join("data/corpus_val.fbqw"))?;
    let workload = generate(
        &stream,
        &WorkloadConfig {
            n_requests,
            prompt_lens: vec![32, 64],
            max_new_tokens: 24,
            arrival_rate: rate,
            temperature: 0.7,
            seed: 11,
        },
    );

    let store =
        WeightStore::load(&WeightStore::path_for(&artifacts, "llamoid-tiny", "fbquant", 4))?;
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(NativeBackend::new(
                NativeEngine::from_store(&store, SubMode::Fused)?,
                "serve_batch",
            )))
        },
        CoordinatorConfig::default(),
    );

    println!("submitting {n_requests} requests at ~{rate} rps (Poisson)...");
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::new();
    let mut prev = std::time::Duration::ZERO;
    for (req, arrival) in workload.requests.into_iter().zip(workload.arrivals) {
        std::thread::sleep(arrival.saturating_sub(prev));
        prev = arrival;
        receivers.push((std::time::Instant::now(), handle.submit(req)));
    }
    let mut client_ttfts = Vec::new();
    let mut ttfts = Vec::new();
    let mut e2es = Vec::new();
    for (submitted, rx) in receivers {
        let mut first_token: Option<f64> = None;
        for ev in rx {
            match ev {
                GenEvent::Token { .. } => {
                    if first_token.is_none() {
                        first_token = Some(submitted.elapsed().as_secs_f64() * 1e3);
                    }
                }
                GenEvent::Done(r) => {
                    ttfts.push(r.ttft_us / 1e3);
                    e2es.push(r.total_us / 1e3);
                    break;
                }
                GenEvent::Error { id, message } => {
                    eprintln!("request {id} failed: {message}");
                    break;
                }
            }
        }
        if let Some(ms) = first_token {
            client_ttfts.push(ms);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = handle.shutdown()?;

    println!("\n{}", metrics.report());
    println!(
        "\nwall {:.2}s | slot occupancy {:.2} (peak {}) | {} admissions into {} pool(s)",
        wall,
        metrics.mean_slot_occupancy(),
        metrics.peak_occupied,
        metrics.admissions,
        metrics.pools_opened,
    );
    println!(
        "streamed ttft p50 {:.0}ms p95 {:.0}ms | ttft p50 {:.0}ms p95 {:.0}ms | \
         e2e p50 {:.0}ms p95 {:.0}ms",
        fbquant::util::percentile(&client_ttfts, 50.0),
        fbquant::util::percentile(&client_ttfts, 95.0),
        fbquant::util::percentile(&ttfts, 50.0),
        fbquant::util::percentile(&ttfts, 95.0),
        fbquant::util::percentile(&e2es, 50.0),
        fbquant::util::percentile(&e2es, 95.0),
    );
    Ok(())
}
