//! Serving under concurrent load: spawn the coordinator worker, replay a
//! Poisson-arrival workload open-loop through the in-process harness and
//! report latency percentiles, goodput and slot occupancy.
//!
//! Tokens arrive incrementally (continuous batching streams every sampled
//! token), so the client-side time-to-first-token is measured from the
//! first `Token` event — not from the final response. The HTTP flavor of
//! the same replay is `fbquant loadgen` (which writes BENCH_serve.json).
//!
//! ```sh
//! cargo run --release --example serve_batch -- [requests] [rate_rps]
//! ```

use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::coordinator::workload::{generate, Arrival, WorkloadConfig};
use fbquant::coordinator::Backend;
use fbquant::coordinator::NativeBackend;
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;
use fbquant::serve::run_in_process;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let artifacts = fbquant::artifacts_dir();

    let stream = TokenStream::load(&artifacts.join("data/corpus_val.fbqw"))?;
    let store =
        WeightStore::load(&WeightStore::path_for(&artifacts, "llamoid-tiny", "fbquant", 4))?;
    let cfg = WorkloadConfig {
        n_requests,
        arrival: if rate > 0.0 { Arrival::Poisson { rate } } else { Arrival::Closed },
        temperature: 0.7,
        seed: 11,
        ..WorkloadConfig::default()
    };
    let mut workload = generate(&cfg, Some(&stream));
    workload.clamp_to(store.cfg.max_seq);

    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(NativeBackend::new(
                NativeEngine::from_store(&store, SubMode::Fused)?,
                "serve_batch",
            )))
        },
        CoordinatorConfig::default(),
    );

    println!("replaying {n_requests} requests at ~{rate} rps (Poisson, open loop)...");
    let res = run_in_process(&handle.client(), &workload);
    let metrics = handle.shutdown()?;

    println!("\n{}", metrics.report());
    let done: Vec<_> = res.records.iter().filter(|r| r.ok).collect();
    let ttft: Vec<f64> = done.iter().map(|r| r.ttft_us / 1e3).collect();
    let e2e: Vec<f64> = done.iter().map(|r| r.e2e_us / 1e3).collect();
    println!(
        "\nwall {:.2}s | goodput {:.0} tok/s | {} done, {} shed | slot occupancy {:.2} (peak {})",
        res.wall_s,
        res.goodput_tps(),
        done.len(),
        res.shed(),
        metrics.mean_slot_occupancy(),
        metrics.peak_occupied,
    );
    println!(
        "ttft p50 {:.0}ms p95 {:.0}ms | e2e p50 {:.0}ms p95 {:.0}ms",
        fbquant::util::percentile(&ttft, 50.0),
        fbquant::util::percentile(&ttft, 95.0),
        fbquant::util::percentile(&e2e, 50.0),
        fbquant::util::percentile(&e2e, 95.0),
    );
    Ok(())
}
