//! Edge-deployment profile: for each bit-width, report what actually
//! matters on a memory-constrained device — resident weight bytes, decode
//! tokens/s, time-to-first-token and bytes moved per generated token.
//!
//! ```sh
//! cargo run --release --example edge_profile -- [model]
//! ```

use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::data::TokenStream;
use fbquant::model::WeightStore;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llamoid-tiny".into());
    let artifacts = fbquant::artifacts_dir();
    let stream = TokenStream::load(&artifacts.join("data/corpus_val.fbqw"))?;
    let prompt: Vec<u32> = stream.tokens()[..64].iter().map(|&b| b as u32).collect();
    let decode = 48;

    println!(
        "=== edge profile: {model} (prompt {} tokens, {decode} generated) ===\n",
        prompt.len()
    );
    println!(
        "{:<18} {:>12} {:>11} {:>11} {:>14}",
        "config", "weights", "decode tk/s", "ttft(ms)", "bytes/token"
    );
    println!("{}", "-".repeat(70));

    let cases: Vec<(String, &str, u8, SubMode)> = vec![
        ("FP32".into(), "fp", 4, SubMode::None),
        ("INT4 RTN".into(), "rtn", 4, SubMode::None),
        ("INT3 RTN".into(), "rtn", 3, SubMode::None),
        ("INT4 FBQuant".into(), "fbquant", 4, SubMode::Fused),
        ("INT3 FBQuant".into(), "fbquant", 3, SubMode::Fused),
        ("INT2 FBQuant".into(), "fbquant", 2, SubMode::Fused),
    ];

    for (name, method, bits, mode) in cases {
        let path = WeightStore::path_for(&artifacts, &model, method, bits);
        let Ok(store) = WeightStore::load(&path) else {
            println!("{name:<18} (missing)");
            continue;
        };
        let engine = NativeEngine::from_store(&store, mode)?;
        let bytes = engine.resident_bytes();
        let mut backend = NativeBackend::new(engine, &name);

        let t0 = Instant::now();
        let mut state = backend.open_batch(1)?;
        let logits = backend.prefill_slot(&mut state, 0, &prompt)?;
        let ttft = t0.elapsed().as_secs_f64() * 1e3;
        backend.reset_traffic();
        let mut tok = fbquant::tensor::ops::argmax(&logits) as u32;
        let td = Instant::now();
        for _ in 0..decode {
            let lg = backend.decode(&mut state, &[SlotToken { slot: 0, token: tok }])?;
            tok = fbquant::tensor::ops::argmax(&lg[0]) as u32;
        }
        let tps = decode as f64 / td.elapsed().as_secs_f64();
        let bytes_per_tok = backend.traffic().total_bytes() / decode as u64;
        println!(
            "{:<18} {:>12} {:>11.1} {:>11.2} {:>14}",
            name,
            fbquant::util::human_bytes(bytes),
            tps,
            ttft,
            fbquant::util::human_bytes(bytes_per_tok as usize)
        );
    }
    println!("\n(bytes/token = measured kernel traffic — the decode bottleneck on edge devices)");
    Ok(())
}
