"""Byte-level tokenizer shared (by specification) with the rust side.

Vocabulary = the 256 byte values. Token id == byte value. Id 0 (NUL, which
never occurs in generated text) doubles as BOS/pad. The spec is written to
`artifacts/data/vocab.json` so the rust tokenizer can assert compatibility.
"""

from __future__ import annotations

import json
import os

VOCAB_SIZE = 256
BOS_ID = 0
PAD_ID = 0


def encode(text: str) -> list[int]:
    return list(text.encode("utf-8"))


def decode(ids: list[int]) -> str:
    return bytes(int(i) & 0xFF for i in ids).decode("utf-8", errors="replace")


def write_spec(path: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(
            {
                "kind": "byte",
                "vocab_size": VOCAB_SIZE,
                "bos_id": BOS_ID,
                "pad_id": PAD_ID,
            },
            f,
            indent=2,
        )
