"""Build-time driver: apply the quantizer zoo to the model grid.

For each (model, method, bits) combination this produces
``artifacts/models/<model>_<method>_w<bits>.fbqw`` containing:

* all non-quantized float params (embeddings, norms, biases, lm head),
* per quantizable linear ``<prefix>/codes_packed`` (u32 nibble-packed),
  ``<prefix>/scales``, ``<prefix>/zeros`` and optionally ``<prefix>/a``,
  ``<prefix>/b``, ``<prefix>/col_scale``,
* meta: method, bits, group, rank, per-layer reconstruction losses.

Packing convention (shared with rust `quant::pack`): codes along the input
dimension, 8 codes per u32 word, code j in bits [4j, 4j+4). Both 3- and
4-bit codes occupy a nibble; the logical bit-width governs the code range
and the quantization grid (byte-exact 3-bit packing would complicate every
consumer for a 12.5% size delta that the latency benches account for
analytically — DESIGN.md §2).

Usage: python -m compile.quantize_all --out ../artifacts [--model X]
       [--method Y] [--bits 3,4] [--rank R] [--group G] [--calib-seqs N]
       [--tag suffix]
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict

import numpy as np

from . import pack
from .calibrate import load_or_capture_stats, stats_path
from .model import MODELS, Config
from . import quantizers


def pack_codes(codes: np.ndarray) -> np.ndarray:
    """int8 [out, in] -> u32 [out, in/8], 8 nibbles per word, little-end."""
    out, cin = codes.shape
    assert cin % 8 == 0
    c = codes.astype(np.uint32).reshape(out, cin // 8, 8)
    shifts = (4 * np.arange(8, dtype=np.uint32))[None, None, :]
    return (c << shifts).sum(axis=-1, dtype=np.uint32)


def unpack_codes(packed: np.ndarray, cin: int) -> np.ndarray:
    """Inverse of `pack_codes` (used by tests and the AOT feeder)."""
    out = packed.shape[0]
    shifts = (4 * np.arange(8, dtype=np.uint32))[None, None, :]
    c = (packed[:, :, None] >> shifts) & 0xF
    return c.reshape(out, -1)[:, :cin].astype(np.int8)


def default_rank(cfg: Config) -> int:
    """Paper: r=128 at d=4096 (d/32); richer ratio at toy scale: d/8."""
    return max(8, cfg.d_model // 8)


def quantize_model(cfg: Config, fp_tensors: Dict[str, np.ndarray], stats,
                   method: str, bits: int, group: int, rank: int, seed: int = 0):
    """Returns (tensors dict for the archive, per-layer loss report)."""
    fn = quantizers.get(method)
    tensors: Dict[str, np.ndarray] = {}
    report = {}
    qprefixes = []
    for l in range(cfg.n_layers):
        for name in cfg.linear_names():
            qprefixes.append(f"l{l}.{name}")
    qset = set(qprefixes)

    for key, arr in fp_tensors.items():
        prefix = key[:-2] if key.endswith(".w") else None
        if prefix in qset:
            continue  # replaced by quantized tensors below
        tensors[key] = arr

    for prefix in qprefixes:
        w = fp_tensors[prefix + ".w"].astype(np.float64)
        st = stats[prefix]
        t0 = time.time()
        q = fn(w, st, bits, group, rank, seed=seed)
        w_eff = quantizers.effective_weight(q, group)
        loss = quantizers.recon_loss_np(w_eff, w, np.asarray(st["h"], np.float64))
        report[prefix] = {"loss": loss, "secs": time.time() - t0}
        tensors[prefix + "/codes_packed"] = pack_codes(q["codes"])
        tensors[prefix + "/scales"] = q["scales"].astype(np.float32)
        tensors[prefix + "/zeros"] = q["zeros"].astype(np.float32)
        for opt in ("a", "b", "col_scale"):
            if q.get(opt) is not None:
                tensors[f"{prefix}/{opt}"] = q[opt].astype(np.float32)
    return tensors, report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="all")
    ap.add_argument("--method", default="all")
    ap.add_argument("--bits", default="4,3")
    ap.add_argument("--group", type=int, default=128)
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--calib-seqs", type=int, default=128)
    ap.add_argument("--calib-len", type=int, default=256,
                    help="tokens per calibration sequence (ablation: below "
                         "d_in the Gram matrix XtX goes rank-deficient, the "
                         "paper's §3.1 ill-posed regime)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    calib, _ = pack.read_fbqw(os.path.join(args.out, "data", "calib.fbqw"))
    calib_tokens = calib["tokens"][: args.calib_seqs, : args.calib_len]

    models = list(MODELS) if args.model == "all" else args.model.split(",")
    methods = quantizers.METHODS if args.method == "all" else args.method.split(",")
    bit_list = [int(b) for b in args.bits.split(",")]

    for mname in models:
        cfg = MODELS[mname]
        fp_path = os.path.join(args.out, "models", f"{mname}_fp.fbqw")
        if not os.path.exists(fp_path):
            print(f"[skip] {mname}: no FP checkpoint yet")
            continue
        fp_tensors, fp_meta = pack.read_fbqw(fp_path)
        # stats cache is keyed by calibration size (ablation support)
        sname = cfg.name
        if args.calib_seqs != 128 or args.calib_len != 256:
            sname = f"{cfg.name}_n{args.calib_seqs}_l{args.calib_len}"
        scfg = Config(**{**cfg.to_meta(), "name": sname})
        params = {k: v for k, v in fp_tensors.items()}
        stats = load_or_capture_stats(args.out, scfg, params, calib_tokens)

        rank = args.rank or default_rank(cfg)
        for method in methods:
            for bits in bit_list:
                tag = f"_{args.tag}" if args.tag else ""
                outp = os.path.join(args.out, "models", f"{mname}_{method}_w{bits}{tag}.fbqw")
                if os.path.exists(outp) and not args.force:
                    print(f"[skip] {os.path.basename(outp)} exists")
                    continue
                t0 = time.time()
                tensors, report = quantize_model(cfg, fp_tensors, stats, method, bits,
                                                 args.group, rank)
                mean_loss = float(np.mean([r["loss"] for r in report.values()]))
                meta = {
                    "kind": "weights",
                    "scheme": "quant",
                    "method": method,
                    "bits": bits,
                    "group": args.group,
                    "rank": rank,
                    "calib_seqs": args.calib_seqs,
                    "calib_tokens": args.calib_seqs * args.calib_len,
                    "config": cfg.to_meta(),
                    "mean_recon_loss": mean_loss,
                    "layer_losses": {k: r["loss"] for k, r in report.items()},
                }
                pack.write_fbqw(outp, tensors, meta)
                print(
                    f"[{mname}] {method} w{bits}: mean-recon={mean_loss:.3e} "
                    f"({time.time() - t0:.1f}s)",
                    flush=True,
                )


if __name__ == "__main__":
    main()
