"""Analytic HBM-traffic / kernel-launch model for the §4.3 fusion.

interpret-mode Pallas gives CPU-numpy timings, which are *not* a TPU/GPU
proxy — so the figure-4 "modeled" series comes from this cost model, and
the measured series comes from the rust native engine (real memory-bound
wall-clock on CPU). Both are printed by `cargo bench --bench
fig4_subbranch_delay`.

Model: a kernel's cost = launch overhead + max(bytes/BW, flops/peak).
At decode (m=1) every matmul is bandwidth-bound, which is exactly the
regime the paper exploits (§1) and suffers from (§3.2).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Machine:
    """Roofline parameters. Defaults approximate an RTX-3090-class part
    (936 GB/s, ~35 f32 TFLOP/s, ~4 µs launch overhead)."""

    bw_bytes: float = 936e9
    flops: float = 35e12
    launch_s: float = 4e-6


@dataclass(frozen=True)
class LayerShape:
    m: int          # tokens in the step (1 = decode)
    k: int          # in features
    n: int          # out features
    r: int = 0      # sub-branch rank (0 = no sub-branch)
    bits: int = 4   # weight bits
    group: int = 128


def _kernel_cost(mach: Machine, bytes_moved: float, flops: float) -> float:
    return mach.launch_s + max(bytes_moved / mach.bw_bytes, flops / mach.flops)


def macs(s: LayerShape) -> dict:
    """MAC counts for main path and sub-branch (paper Fig. 4 upper-left)."""
    main = s.m * s.k * s.n
    sub = 2 * s.m * s.r * s.k if s.r else 0  # r*(k+n) in general; k==n in the paper's example
    return {"main": main, "sub": sub, "ratio": sub / main if main else 0.0}


def cost_fp16(mach: Machine, s: LayerShape) -> float:
    """Single FP16 matmul kernel."""
    bytes_moved = 2 * (s.m * s.k + s.k * s.n + s.m * s.n)
    return _kernel_cost(mach, bytes_moved, 2 * s.m * s.k * s.n)


def cost_quant_plain(mach: Machine, s: LayerShape) -> float:
    """Fused dequant+matmul, no sub-branch (the "INT4" series)."""
    w_bytes = s.k * s.n * s.bits / 8 + 4 * 2 * s.n * (s.k // s.group)
    bytes_moved = 2 * s.m * s.k + w_bytes + 2 * s.m * s.n
    return _kernel_cost(mach, bytes_moved, 2 * s.m * s.k * s.n)


def cost_naive_sub(mach: Machine, s: LayerShape) -> float:
    """Conventional 4-kernel sub-branch pipeline ("INT4-Sub"):
    dequant | main matmul | down proj | up proj, each with HBM traffic."""
    w_bytes = s.k * s.n * s.bits / 8 + 4 * 2 * s.n * (s.k // s.group)
    # k1: read packed weights, write fp16 weights (materialized in HBM)
    c1 = _kernel_cost(mach, w_bytes + 2 * s.k * s.n, s.k * s.n)
    # k2: read x + fp16 weights, write y
    c2 = _kernel_cost(mach, 2 * s.m * s.k + 2 * s.k * s.n + 2 * s.m * s.n,
                      2 * s.m * s.k * s.n)
    # k3: read x + A, write xa
    c3 = _kernel_cost(mach, 2 * s.m * s.k + 2 * s.r * s.k + 4 * s.m * s.r,
                      2 * s.m * s.k * s.r)
    # k4: read y + xa + B, write y  (the redundant output round-trip)
    c4 = _kernel_cost(mach, 2 * 2 * s.m * s.n + 4 * s.m * s.r + 2 * s.n * s.r,
                      2 * s.m * s.r * s.n)
    return c1 + c2 + c3 + c4


def cost_fused_sub(mach: Machine, s: LayerShape) -> float:
    """FBQuant fused kernels (2 launches): [dequant+main+up] and [down].
    The output tensor is written once; xa stays in VMEM for the fused
    kernel's tiles (down-projection kernel still writes it once)."""
    w_bytes = s.k * s.n * s.bits / 8 + 4 * 2 * s.n * (s.k // s.group)
    c_down = _kernel_cost(mach, 2 * s.m * s.k + 2 * s.r * s.k + 4 * s.m * s.r,
                          2 * s.m * s.k * s.r)
    c_main = _kernel_cost(mach, 2 * s.m * s.k + w_bytes + 4 * s.m * s.r + 2 * s.n * s.r + 2 * s.m * s.n,
                          2 * s.m * s.k * s.n + 2 * s.m * s.r * s.n)
    return c_down + c_main


def fig4_rows(mach: Machine | None = None) -> list:
    """Paper-scale (Llama2-7B linear layer) modeled latencies."""
    mach = mach or Machine()
    rows = []
    for phase, m in [("prefill", 1024), ("decode", 1)]:
        s = LayerShape(m=m, k=4096, n=4096, r=128)
        base = cost_quant_plain(mach, s)
        rows.append(
            {
                "phase": phase,
                "macs_overhead": macs(s)["ratio"],
                "int4": 1.0,
                "int4_sub": cost_naive_sub(mach, s) / base,
                "int4_fused": cost_fused_sub(mach, s) / base,
                "fp16": cost_fp16(mach, s) / base,
            }
        )
    return rows


def extra_latency_saved(mach: Machine | None = None, m: int = 1) -> float:
    """The paper's headline '60% of extra inference time saved' statistic:
    1 - (fused_extra / naive_extra) at decode shape."""
    mach = mach or Machine()
    s = LayerShape(m=m, k=4096, n=4096, r=128)
    base = cost_quant_plain(mach, s)
    naive_extra = cost_naive_sub(mach, s) - base
    fused_extra = cost_fused_sub(mach, s) - base
    return 1.0 - fused_extra / naive_extra


if __name__ == "__main__":
    for row in fig4_rows():
        print(row)
    print(f"extra latency saved (decode): {extra_latency_saved():.1%}")
