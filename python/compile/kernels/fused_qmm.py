"""Layer-1 Pallas kernels: the paper's §4.3 kernel fusion, TPU-style.

The paper fuses (on CUDA): weight de-quantization, the main-path
activation×weight product, and the sub-branch up-projection into a single
kernel that *shares the output tensor*, cutting kernel launches 4 → 2 and
eliminating redundant HBM writes of the output and of the `(A·x)`
intermediate.

TPU re-think (DESIGN.md §3): the fused kernel tiles the output `[M, N]`
into `(bm, bn)` VMEM blocks. For each block it streams the packed codes
and per-group scales/zeros HBM→VMEM via `BlockSpec`, de-quantizes
in-register, runs the MXU-shaped `dot`, then accumulates the sub-branch
up-projection `B·(Ax)` into the *same VMEM accumulator* before the single
write-back. "Share the output tensor" becomes "share the accumulator
tile".

Two entry points:

* :func:`fused_qmm` — ONE `pallas_call` for the whole reconstructed layer
  (de-quant + main matmul + down- and up-projection),
* :func:`unfused_qmm` — the conventional 4-kernel pipeline
  (de-quant | main matmul | down-proj | up-proj), each its own
  `pallas_call` with materialized HBM intermediates. This is the "INT4-Sub"
  baseline of Figs 4/7.

Kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); numerics are validated against `ref.qmm_ref` in
`python/tests/test_fused_qmm.py`, and HBM-traffic/launch-count effects are
modeled analytically in `traffic.py` and measured for real in the rust
native engine.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Fused kernel
# ---------------------------------------------------------------------------

def _fused_kernel(x_ref, codes_ref, scales_ref, zeros_ref, a_ref, b_ref, o_ref, *, group: int):
    """One (bm, bn) output tile.

    x_ref:      [bm, K]   activations
    codes_ref:  [bn, K]   int8 codes for the weight rows of this tile
    scales_ref: [bn, K//group] f32
    zeros_ref:  [bn, K//group] f32
    a_ref:      [r, K]    sub-branch down-projection (full)
    b_ref:      [bn, r]   sub-branch up-projection rows of this tile
    o_ref:      [bm, bn]  output tile (single write)
    """
    x = x_ref[...]
    # De-quantize in-register: rank-1-per-group broadcast (free on the VPU).
    s = jnp.repeat(scales_ref[...], group, axis=1)
    z = jnp.repeat(zeros_ref[...], group, axis=1)
    w = (codes_ref[...].astype(jnp.float32) - z) * s  # [bn, K]
    acc = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bm, bn]
    if a_ref is not None:
        xa = jax.lax.dot_general(
            x, a_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bm, r]
        acc = acc + jax.lax.dot_general(
            xa, b_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    o_ref[...] = acc


def fused_qmm(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    zeros: jnp.ndarray,
    a: Optional[jnp.ndarray],
    b: Optional[jnp.ndarray],
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ dequant(codes).T [+ (x @ A.T) @ B.T] in one pallas_call.

    x: [M, K]; codes: [N, K]; scales/zeros: [N, K//group];
    a: [r, K]; b: [N, r]. Returns [M, N] f32.
    """
    m, k = x.shape
    n = codes.shape[0]
    gk = k // group
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (_cdiv(m, bm), _cdiv(n, bn))
    has_sub = a is not None and b is not None

    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        pl.BlockSpec((bn, gk), lambda i, j: (j, 0)),
        pl.BlockSpec((bn, gk), lambda i, j: (j, 0)),
    ]
    args = [x, codes, scales, zeros]
    if has_sub:
        r = a.shape[0]
        in_specs += [
            pl.BlockSpec((r, k), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ]
        args += [a, b]
        kernel = functools.partial(_fused_kernel, group=group)
    else:
        kernel = functools.partial(_no_sub_kernel, group=group)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)


def _no_sub_kernel(x_ref, codes_ref, scales_ref, zeros_ref, o_ref, *, group: int):
    """Plain quantized matmul tile (no sub-branch): the "INT4" baseline."""
    x = x_ref[...]
    s = jnp.repeat(scales_ref[...], group, axis=1)
    z = jnp.repeat(zeros_ref[...], group, axis=1)
    w = (codes_ref[...].astype(jnp.float32) - z) * s
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# Un-fused 4-kernel pipeline (the conventional sub-branch implementation)
# ---------------------------------------------------------------------------

def _dequant_kernel(codes_ref, scales_ref, zeros_ref, w_ref, *, group: int):
    s = jnp.repeat(scales_ref[...], group, axis=1)
    z = jnp.repeat(zeros_ref[...], group, axis=1)
    w_ref[...] = (codes_ref[...].astype(jnp.float32) - z) * s


def _matmul_t_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _add_matmul_t_kernel(y_ref, xa_ref, b_ref, o_ref):
    o_ref[...] = y_ref[...] + jax.lax.dot_general(
        xa_ref[...], b_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def unfused_qmm(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    zeros: jnp.ndarray,
    a: Optional[jnp.ndarray],
    b: Optional[jnp.ndarray],
    *,
    group: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Conventional pipeline: 4 separate kernels with HBM intermediates.

    kernel 1: W = dequant(codes)          (writes [N,K] floats to HBM!)
    kernel 2: Y0 = x @ W.T
    kernel 3: XA = x @ A.T
    kernel 4: Y  = Y0 + XA @ B.T          (re-reads + re-writes the output)
    """
    m, k = x.shape
    n = codes.shape[0]
    gk = k // group
    bn = min(block_n, n)
    bm = min(block_m, m)

    # kernel 1: dequantize the whole weight matrix to HBM
    w = pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=(_cdiv(n, bn),),
        in_specs=[
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
            pl.BlockSpec((bn, gk), lambda j: (j, 0)),
            pl.BlockSpec((bn, gk), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(codes, scales, zeros)

    # kernel 2: main-path matmul
    y0 = pl.pallas_call(
        _matmul_t_kernel,
        grid=(_cdiv(m, bm), _cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)

    if a is None or b is None:
        return y0

    r = a.shape[0]
    # kernel 3: sub-branch down-projection (intermediate written to HBM)
    xa = pl.pallas_call(
        _matmul_t_kernel,
        grid=(_cdiv(m, bm), 1),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((r, k), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=interpret,
    )(x, a)

    # kernel 4: up-projection, re-reading and re-writing the layer output
    return pl.pallas_call(
        _add_matmul_t_kernel,
        grid=(_cdiv(m, bm), _cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(y0, xa, b)
