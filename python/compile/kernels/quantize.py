"""Layer-1 Pallas kernel: group-wise asymmetric RTN quantization.

Used on the artifact-build path (quantizing a whole linear layer in one
dispatch) and as a second, simpler Pallas correctness target besides the
fused matmul. Semantics match `ref.quant_params` + `ref.quantize` exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(w_ref, codes_ref, scales_ref, zeros_ref, *, bits: int, group: int):
    """One block of rows: compute per-group scale/zero and the codes."""
    w = w_ref[...]  # [bm, K]
    bm, k = w.shape
    wg = w.reshape(bm, k // group, group)
    lo = jnp.minimum(wg.min(axis=-1), 0.0)
    hi = jnp.maximum(wg.max(axis=-1), 0.0)
    qmax = (1 << bits) - 1
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    zero = jnp.round(-lo / scale)
    s = jnp.repeat(scale, group, axis=1)
    z = jnp.repeat(zero, group, axis=1)
    codes = jnp.clip(jnp.round(w / s) + z, 0, qmax)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale
    zeros_ref[...] = zero


def quantize_pallas(w: jnp.ndarray, *, bits: int, group: int,
                    block_rows: int = 128, interpret: bool = True):
    """w: [out, in] -> (codes i8 [out,in], scales f32 [out,in/g], zeros)."""
    out, cin = w.shape
    gk = cin // group
    bm = min(block_rows, out)
    grid = (-(-out // bm),)
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, group=group),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, cin), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, cin), lambda i: (i, 0)),
            pl.BlockSpec((bm, gk), lambda i: (i, 0)),
            pl.BlockSpec((bm, gk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out, cin), jnp.int8),
            jax.ShapeDtypeStruct((out, gk), jnp.float32),
            jax.ShapeDtypeStruct((out, gk), jnp.float32),
        ],
        interpret=interpret,
    )(w)
