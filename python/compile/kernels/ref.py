"""Pure-jnp oracle for every quantization primitive.

This is the single source of truth for numerics. The Pallas kernels
(`quantize.py`, `fused_qmm.py`), the quantizer zoo, the AOT score graphs
and the rust native engine are all tested against these functions.

Conventions (shared with rust `quant::groupwise`):

* weights `W` are `[out, in]`; groups of `group` consecutive *input*
  channels share one (scale, zero) pair → scales/zeros are
  `[out, in/group]`,
* asymmetric round-to-nearest: `code = clip(round(w/scale) + zero, 0,
  2^bits - 1)`, `dequant = (code - zero) * scale`, with
  `scale = (max-min)/(2^bits-1)` and `zero = round(-min/scale)`,
* the sub-branch is `Σ = B·A` with `A: [r, in]`, `B: [out, r]`; a
  reconstructed layer computes `y = x @ dequant(Wq).T + (x @ A.T) @ B.T`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def group_minmax(w: jnp.ndarray, group: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(row, group) min and max. w: [out, in] -> [out, in/group]."""
    out, cin = w.shape
    assert cin % group == 0, f"in={cin} not divisible by group={group}"
    wg = w.reshape(out, cin // group, group)
    return wg.min(axis=-1), wg.max(axis=-1)


def quant_params(w: jnp.ndarray, bits: int, group: int,
                 clip_lo: Optional[jnp.ndarray] = None,
                 clip_hi: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Asymmetric (scale, zero) per group. Optional learned clipping factors
    (OmniQuant-style) shrink the [min, max] range: clip_* has shape
    broadcastable to [out, in/group] with values in (0, 1]."""
    lo, hi = group_minmax(w, group)
    if clip_lo is not None:
        lo = lo * clip_lo
    if clip_hi is not None:
        hi = hi * clip_hi
    # Ensure the range covers zero so that zero error stays bounded.
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 0.0)
    qmax = (1 << bits) - 1
    scale = (hi - lo) / qmax
    scale = jnp.maximum(scale, 1e-8)
    zero = jnp.round(-lo / scale)
    return scale, zero


def quantize(w: jnp.ndarray, bits: int, group: int,
             scale: Optional[jnp.ndarray] = None,
             zero: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """RTN codes [out, in] int8 (int8 holds 2..6-bit codes comfortably)."""
    if scale is None or zero is None:
        scale, zero = quant_params(w, bits, group)
    out, cin = w.shape
    qmax = (1 << bits) - 1
    s = jnp.repeat(scale, group, axis=1)
    z = jnp.repeat(zero, group, axis=1)
    codes = jnp.clip(jnp.round(w / s) + z, 0, qmax)
    return codes.astype(jnp.int8)


def dequantize(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
               group: int) -> jnp.ndarray:
    """(codes - zero) * scale -> float weights [out, in]."""
    s = jnp.repeat(scale, group, axis=1)
    z = jnp.repeat(zero, group, axis=1)
    return (codes.astype(jnp.float32) - z) * s


def quantize_dequantize(w: jnp.ndarray, bits: int, group: int,
                        clip_lo=None, clip_hi=None) -> jnp.ndarray:
    """One-shot fake-quantization Q(w) (the paper's Q(·))."""
    scale, zero = quant_params(w, bits, group, clip_lo, clip_hi)
    return dequantize(quantize(w, bits, group, scale, zero), scale, zero, group)


def qmm_ref(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
            zero: jnp.ndarray, a: Optional[jnp.ndarray], b: Optional[jnp.ndarray],
            group: int) -> jnp.ndarray:
    """Reference reconstructed-layer matmul.

    x: [n, in] -> y: [n, out]; y = x @ dequant.T + (x @ A.T) @ B.T.
    This is the un-fused semantics the fused Pallas kernel must match.
    """
    wd = dequantize(codes, scale, zero, group)
    y = x @ wd.T
    if a is not None and b is not None:
        y = y + (x @ a.T) @ b.T
    return y


def fbq_reconstruct(w: jnp.ndarray, sigma: jnp.ndarray, bits: int,
                    group: int) -> jnp.ndarray:
    """FBQuant reconstruction W_F = Q(W - Σ) + Σ (paper Eq. 11)."""
    return quantize_dequantize(w - sigma, bits, group) + sigma


def fbq_reconstruct_ste(w: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                        bits: int, group: int) -> jnp.ndarray:
    """Differentiable W_F with the paper's §4.2 detach: gradients flow only
    through the explicit +Σ term (Eq. 18), not through Q(W−Σ)."""
    sigma = b @ a
    q = jax.lax.stop_gradient(quantize_dequantize(w - sigma, bits, group))
    return q + sigma


def max_reconstruction_error(w: jnp.ndarray, w_rec: jnp.ndarray) -> jnp.ndarray:
    """max |w - w_rec| — the quantity bounded by s/2 for FBQuant (Eq. 13)."""
    return jnp.max(jnp.abs(w - w_rec))


def scale_bound(w: jnp.ndarray, sigma: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """The per-element bound s/2 evaluated for the FBQuant quantizer of
    (W − Σ), expanded to [out, in]."""
    scale, _ = quant_params(w - sigma, bits, group)
    return jnp.repeat(scale, group, axis=1) / 2.0
