"""Calibration machinery shared by the quantizer zoo.

Follows the paper's protocol (§5.1): 128 calibration sequences drawn from
the training distribution. Instead of materializing per-layer activation
matrices X (O(n_tokens × d) each), we capture the Gram matrix
``H = XᵀX`` and the mean absolute activation per input channel — the
sufficient statistics for every method in the zoo:

* layer-wise reconstruction loss (paper Eq. 14):
  ``‖(W − W')Xᵀ‖_F² = tr((W − W') H (W − W')ᵀ)`` — exact, not an
  approximation,
* GPTQ's Hessian is `2H` (damped),
* AWQ's activation saliency is the per-channel mean |x|,
* EoRA's eigenspace projection diagonalizes `H`.

Stats are captured once per model from the FP forward pass and cached in
``artifacts/calib/<model>.fbqw`` (shared across methods and bit-widths;
per-method error propagation would multiply build time ~9× on one CPU core
— noted in DESIGN.md §2).

Also hosts the generic Adam-on-(A,B) layer-wise reconstruction loop used
by FBQuant (Algorithm 1) and the learned-clipping loop used by
OmniQuant-lite.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import pack
from .model import Config, attention, norm, rope_tables, apply_rope


def capture_stats(cfg: Config, params: Dict[str, jnp.ndarray],
                  calib_tokens: np.ndarray, batch: int = 16) -> Dict[str, Dict[str, np.ndarray]]:
    """Run the FP model over the calibration set, accumulating per-linear
    sufficient statistics.

    Returns {prefix: {"h": [in,in] f32, "mean_abs": [in] f32, "n": int}}
    where prefix is e.g. "l0.q".
    """
    stats: Dict[str, Dict[str, np.ndarray]] = {}

    def record(prefix: str, x2: np.ndarray):
        # x2: [n, in] float32
        h = x2.T @ x2
        ma = np.abs(x2).mean(axis=0)
        if prefix not in stats:
            stats[prefix] = {"h": h, "mean_abs": ma * len(x2), "n": len(x2)}
        else:
            s = stats[prefix]
            s["h"] += h
            s["mean_abs"] += ma * len(x2)
            s["n"] += len(x2)

    def linear_fn(params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
        if ".w" not in prefix + ".w":  # pragma: no cover - defensive
            raise AssertionError
        # record only the seven quantizable projections (they start "l<i>.")
        if prefix.startswith("l"):
            x2 = np.asarray(x.reshape(-1, x.shape[-1]), np.float32)
            record(prefix, x2)
        y = x @ params[prefix + ".w"].T
        if prefix + ".b" in params:
            y = y + params[prefix + ".b"]
        return y

    # capture path: plain (non-jit) forward so the python-side hook runs.
    from .model import block, embed

    n_seqs = calib_tokens.shape[0]
    for i in range(0, n_seqs, batch):
        chunk = jnp.asarray(calib_tokens[i : i + batch].astype(np.int32))
        x = embed(cfg, params, chunk)
        for l in range(cfg.n_layers):
            x, _ = block(cfg, params, l, x, 0, linear_fn)

    for s in stats.values():
        s["mean_abs"] = s["mean_abs"] / s["n"]
        s["n"] = np.asarray([s["n"]], np.int32)
    return stats


def stats_path(artifacts: str, model_name: str) -> str:
    return os.path.join(artifacts, "calib", f"{model_name}.fbqw")


def load_or_capture_stats(artifacts: str, cfg: Config, params, calib_tokens) -> Dict[str, Dict[str, np.ndarray]]:
    path = stats_path(artifacts, cfg.name)
    if os.path.exists(path):
        tensors, _ = pack.read_fbqw(path)
        stats: Dict[str, Dict[str, np.ndarray]] = {}
        for key, arr in tensors.items():
            prefix, field = key.rsplit("/", 1)
            stats.setdefault(prefix, {})[field] = arr
        return stats
    stats = capture_stats(cfg, params, calib_tokens)
    flat = {}
    for prefix, fields in stats.items():
        for fname, arr in fields.items():
            flat[f"{prefix}/{fname}"] = np.asarray(arr, np.float32 if fname != "n" else np.int32)
    pack.write_fbqw(path, flat, meta={"kind": "calib_stats", "model": cfg.name})
    return stats


# ---------------------------------------------------------------------------
# Reconstruction losses and optimisation loops
# ---------------------------------------------------------------------------

def recon_loss(w_rec: jnp.ndarray, w: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """tr((W − W_rec) H (W − W_rec)ᵀ) — the paper's Eq. 14 in Gram form,
    normalised by tr(WHWᵀ) for cross-layer comparability."""
    d = w - w_rec
    return jnp.einsum("oi,ij,oj->", d, h, d)


def _adam_loop(loss_fn: Callable, params: Dict[str, jnp.ndarray], steps: int,
               lr: float) -> Tuple[Dict[str, jnp.ndarray], list]:
    """Minimal Adam used for the per-layer optimizers (no optax offline)."""
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    history = []
    for t in range(1, steps + 1):
        loss, g = grad_fn(params)
        history.append(float(loss))
        for k in params:
            m[k] = b1 * m[k] + (1 - b1) * g[k]
            v[k] = b2 * v[k] + (1 - b2) * g[k] * g[k]
            mhat = m[k] / (1 - b1**t)
            vhat = v[k] / (1 - b2**t)
            params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return params, history


def fbquant_optimize(w: np.ndarray, h: np.ndarray, bits: int, group: int,
                     rank: int, steps: int = 160, lr: float = 2e-3,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray, list]:
    """Algorithm 1: layer-wise reconstruction of the FBQuant sub-branch.

    Returns (A [r, in], B [out, r], loss history). Gradients flow only
    through the explicit +Σ term (§4.2 STE detach); the quantizer
    parameters of Q(W − Σ) are recomputed every step — the feedback path.
    """
    from .kernels import ref as kref

    out, cin = w.shape
    rng = np.random.default_rng(seed)
    # A ~ N(0, σ²), B = 0  (Algorithm 1 lines 1-2) → Σ₀ = 0, start at RTN.
    a0 = jnp.asarray(rng.normal(0.0, 0.02, size=(rank, cin)), jnp.float32)
    b0 = jnp.zeros((out, rank), jnp.float32)
    wj = jnp.asarray(w)
    hj = jnp.asarray(h)
    # normalise H so lr is scale-free across layers
    hj = hj / (jnp.trace(hj) / cin + 1e-12)

    def loss(ps):
        w_f = kref.fbq_reconstruct_ste(wj, ps["a"], ps["b"], bits, group)
        return recon_loss(w_f, wj, hj)

    params, hist = _adam_loop(loss, {"a": a0, "b": b0}, steps, lr)
    return np.asarray(params["a"]), np.asarray(params["b"]), hist


def omniquant_optimize(w: np.ndarray, h: np.ndarray, bits: int, group: int,
                       steps: int = 120, lr: float = 5e-3) -> Tuple[np.ndarray, np.ndarray, list]:
    """OmniQuant-lite: learn per-group clipping factors γ_lo, γ_hi ∈ (0,1]
    (sigmoid-parameterised) minimising the Gram-form reconstruction loss."""
    from .kernels import ref as kref

    wj = jnp.asarray(w)
    hj = jnp.asarray(h)
    hj = hj / (jnp.trace(hj) / w.shape[1] + 1e-12)
    gshape = (w.shape[0], w.shape[1] // group)
    # sigmoid(4.0) ≈ 0.982 → start near no-clipping
    init = jnp.full(gshape, 4.0, jnp.float32)

    def loss(ps):
        clip_lo = jax.nn.sigmoid(ps["lo"])
        clip_hi = jax.nn.sigmoid(ps["hi"])
        # straight-through on the rounding inside quantize_dequantize:
        scale, zero = kref.quant_params(wj, bits, group, clip_lo, clip_hi)
        s = jnp.repeat(scale, group, axis=1)
        z = jnp.repeat(zero, group, axis=1)
        qmax = (1 << bits) - 1
        codes = jnp.clip(jnp.round(wj / s) + z, 0, qmax)
        codes = codes + (wj / s + z - jax.lax.stop_gradient(wj / s + z))  # STE
        w_rec = (codes - z) * s
        return recon_loss(w_rec, wj, hj)

    params, hist = _adam_loop(loss, {"lo": init, "hi": init}, steps, lr)
    lo = np.asarray(jax.nn.sigmoid(params["lo"]))
    hi = np.asarray(jax.nn.sigmoid(params["hi"]))
    return lo, hi, hist
