"""Layer-2 model definitions: tiny-LLM families in pure JAX.

Two-and-a-half architecture families mirror the paper's model grid
(Llama2/Llama3 / Qwen2.5 / plus a GPT-style control):

* ``llamoid`` — RMSNorm, RoPE, SiLU-gated MLP, no biases (Llama-shaped)
* ``qwenoid`` — llamoid + QKV biases (Qwen-shaped)
* ``gptoid``  — LayerNorm, learned positions, GELU MLP, biases (GPT-shaped)

Weight convention: every linear stores ``W`` with shape ``[out, in]`` and
computes ``y = x @ W.T (+ b)``. The seven quantizable projections per block
are q, k, v, o and the MLP triplet (gate/up/down, or fc/proj for gptoid).

The quantized forward path consumes per-linear quantization parameters
(int codes + group scales/zeros + optional low-rank sub-branch A/B) and can
run either through plain ``jnp`` ops (fast, used for AOT score graphs) or
through the fused Pallas kernel (`kernels.fused_qmm`, the paper's §4.3
contribution — used for kernel-path artifacts and tests).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .tokenizer import VOCAB_SIZE


@dataclass(frozen=True)
class Config:
    name: str
    family: str  # llamoid | gptoid | qwenoid
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = VOCAB_SIZE
    max_seq: int = 256
    rope_theta: float = 10_000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def gated(self) -> bool:
        return self.family in ("llamoid", "qwenoid")

    @property
    def rms(self) -> bool:
        return self.family in ("llamoid", "qwenoid")

    @property
    def rope(self) -> bool:
        return self.family in ("llamoid", "qwenoid")

    @property
    def qkv_bias(self) -> bool:
        return self.family == "qwenoid"

    @property
    def mlp_bias(self) -> bool:
        return self.family == "gptoid"

    def linear_names(self) -> list:
        """The quantizable projections of one block."""
        if self.gated:
            return ["q", "k", "v", "o", "gate", "up", "down"]
        return ["q", "k", "v", "o", "fc", "proj"]

    def linear_shape(self, name: str) -> Tuple[int, int]:
        d, ff = self.d_model, self.d_ff
        return {
            "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
            "gate": (ff, d), "up": (ff, d), "down": (d, ff),
            "fc": (ff, d), "proj": (d, ff),
        }[name]

    def n_params(self) -> int:
        n = 2 * self.vocab * self.d_model  # embeddings + head
        if not self.rope:
            n += self.max_seq * self.d_model
        per = sum(o * i for o, i in (self.linear_shape(x) for x in self.linear_names()))
        return n + self.n_layers * per

    def to_meta(self) -> dict:
        return asdict(self)


# The model grid: families × sizes, mirroring the paper's six-model axis at
# a scale a single CPU core can pretrain.
MODELS: Dict[str, Config] = {
    c.name: c
    for c in [
        Config("llamoid-tiny", "llamoid", d_model=128, n_layers=2, n_heads=4, d_ff=384),
        Config("llamoid-small", "llamoid", d_model=256, n_layers=2, n_heads=8, d_ff=768),
        Config("llamoid-base", "llamoid", d_model=256, n_layers=4, n_heads=8, d_ff=768),
        Config("gptoid-tiny", "gptoid", d_model=128, n_layers=2, n_heads=4, d_ff=512),
        Config("gptoid-small", "gptoid", d_model=256, n_layers=2, n_heads=8, d_ff=1024),
        Config("qwenoid-tiny", "qwenoid", d_model=128, n_layers=2, n_heads=4, d_ff=384),
    ]
}


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def init_params(cfg: Config, key: jax.Array) -> Dict[str, jnp.ndarray]:
    params: Dict[str, jnp.ndarray] = {}
    keys = iter(jax.random.split(key, 4 + cfg.n_layers * 16))

    def dense(shape, scale=None):
        fan_in = shape[-1]
        s = scale if scale is not None else (1.0 / np.sqrt(fan_in))
        return jax.random.normal(next(keys), shape, jnp.float32) * s

    params["tok_emb"] = dense((cfg.vocab, cfg.d_model), scale=0.02)
    params["lm_head"] = dense((cfg.vocab, cfg.d_model))
    if not cfg.rope:
        params["pos_emb"] = dense((cfg.max_seq, cfg.d_model), scale=0.02)
    for l in range(cfg.n_layers):
        p = f"l{l}."
        params[p + "attn_norm.w"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[p + "mlp_norm.w"] = jnp.ones((cfg.d_model,), jnp.float32)
        if not cfg.rms:
            params[p + "attn_norm.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
            params[p + "mlp_norm.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        for name in cfg.linear_names():
            shape = cfg.linear_shape(name)
            # residual-path projections get the depth-scaled init
            scale = 1.0 / np.sqrt(shape[1]) / (np.sqrt(2 * cfg.n_layers) if name in ("o", "down", "proj") else 1.0)
            params[p + name + ".w"] = dense(shape, scale=scale)
            if (name in ("q", "k", "v") and cfg.qkv_bias) or (name in ("fc", "proj") and cfg.mlp_bias):
                params[p + name + ".b"] = jnp.zeros((shape[0],), jnp.float32)
    params["final_norm.w"] = jnp.ones((cfg.d_model,), jnp.float32)
    if not cfg.rms:
        params["final_norm.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Core ops (shared by float and quantized paths)
# ---------------------------------------------------------------------------

def norm(cfg: Config, params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    w = params[prefix + ".w"]
    if cfg.rms:
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + 1e-5) * w
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * w + params[prefix + ".b"]


def rope_tables(cfg: Config, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables [*pos_shape, head_dim/2] (half-split convention)."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, H, hd]; cos/sin: [T, hd/2] broadcast over batch and heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _linear_f(params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params[prefix + ".w"].T
    if prefix + ".b" in params:
        y = y + params[prefix + ".b"]
    return y


def attention(cfg: Config, q, k, v, causal_from: int = 0):
    """q: [B,Tq,H,hd], k/v: [B,Tk,H,hd]. Causal mask aligned so query i
    attends to keys 0..causal_from+i."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qpos = causal_from + jnp.arange(Tq)
    kpos = jnp.arange(Tk)
    mask = kpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None, None, :, :], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(B, Tq, cfg.d_model)


def block(cfg: Config, params, l: int, x: jnp.ndarray, pos0,
          linear_fn, kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None):
    """One transformer block. `linear_fn(params, prefix, x)` abstracts the
    float vs quantized projection. If `kv` is given it is (k_cache, v_cache)
    with layout [B, T_max, H, hd]; returns the updated caches."""
    p = f"l{l}."
    B, T, _ = x.shape
    h = norm(cfg, params, p + "attn_norm", x)
    q = linear_fn(params, p + "q", h).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = linear_fn(params, p + "k", h).reshape(B, T, cfg.n_heads, cfg.head_dim)
    v = linear_fn(params, p + "v", h).reshape(B, T, cfg.n_heads, cfg.head_dim)
    if cfg.rope:
        cos, sin = rope_tables(cfg, pos0 + jnp.arange(T))
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    new_kv = None
    if kv is not None:
        k_cache, v_cache = kv
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos0, 0, 0))
        new_kv = (k_cache, v_cache)
        attn = attention(cfg, q, k_cache, v_cache, causal_from=pos0)
    else:
        attn = attention(cfg, q, k, v)
    x = x + linear_fn(params, p + "o", attn)

    h = norm(cfg, params, p + "mlp_norm", x)
    if cfg.gated:
        g = linear_fn(params, p + "gate", h)
        u = linear_fn(params, p + "up", h)
        m = linear_fn(params, p + "down", jax.nn.silu(g) * u)
    else:
        m = linear_fn(params, p + "proj", jax.nn.gelu(linear_fn(params, p + "fc", h)))
    return x + m, new_kv


def embed(cfg: Config, params, tokens: jnp.ndarray, pos0=0) -> jnp.ndarray:
    x = params["tok_emb"][tokens]
    if not cfg.rope:
        T = tokens.shape[-1]
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos0, T, axis=0)[None, :, :]
    return x


def forward(cfg: Config, params, tokens: jnp.ndarray, linear_fn=_linear_f) -> jnp.ndarray:
    """Full-sequence forward: tokens [B, T] -> logits [B, T, V]."""
    x = embed(cfg, params, tokens)
    for l in range(cfg.n_layers):
        x, _ = block(cfg, params, l, x, 0, linear_fn)
    x = norm(cfg, params, "final_norm", x)
    return x @ params["lm_head"].T


def decode_step(cfg: Config, params, tokens: jnp.ndarray, pos0,
                kv_k: jnp.ndarray, kv_v: jnp.ndarray, linear_fn=_linear_f):
    """Incremental step: tokens [B, T_step]; kv_[kv]: [L, B, T_max, H, hd];
    pos0 scalar int32 — returns (logits [B, T_step, V], new kv_k, new kv_v).

    Note: attention masking treats all cache slots ≥ pos0+T_step as masked
    (they are beyond the causal horizon), so stale cache contents are
    harmless.
    """
    x = embed(cfg, params, tokens, pos0=pos0)
    ks, vs = [], []
    for l in range(cfg.n_layers):
        x, new_kv = block(cfg, params, l, x, pos0, linear_fn, kv=(kv_k[l], kv_v[l]))
        ks.append(new_kv[0])
        vs.append(new_kv[1])
    x = norm(cfg, params, "final_norm", x)
    logits = x @ params["lm_head"].T
    return logits, jnp.stack(ks), jnp.stack(vs)


def loss_fn(cfg: Config, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy over [B, T] byte sequences."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# Quantized forward path
# ---------------------------------------------------------------------------

def make_quantized_linear(qweights: Dict[str, Dict[str, jnp.ndarray]], group: int,
                          use_pallas: bool = False, interpret: bool = True):
    """Build a `linear_fn` closing over per-linear quantization params.

    `qweights` maps a linear's full prefix (e.g. "l0.q") to a dict with
    `codes` [out,in] int8 (unpacked), `scales`/`zeros` [out, in/group] f32
    and optionally `a` [r, in] / `b` [out, r] (the sub-branch). Biases stay
    in the float `params` dict. Prefixes not present in `qweights`
    (embeddings, norms — never quantized) fall back to the float weights.
    """
    from .kernels import ref as kref

    if use_pallas:
        from .kernels import fused_qmm

    def linear_fn(params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
        if prefix not in qweights:
            return _linear_f(params, prefix, x)
        qw = qweights[prefix]
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if qw.get("col_scale") is not None:
            # AWQ-style activation scaling, applied once — both the main
            # path and the sub-branch read the scaled activation buffer.
            x2 = x2 * qw["col_scale"][None, :]
        if use_pallas:
            y2 = fused_qmm.fused_qmm(
                x2, qw["codes"], qw["scales"], qw["zeros"],
                qw.get("a"), qw.get("b"), group=group, interpret=interpret,
            )
        else:
            y2 = kref.qmm_ref(
                x2, qw["codes"], qw["scales"], qw["zeros"],
                qw.get("a"), qw.get("b"), group=group,
            )
        y = y2.reshape(*lead, -1)
        if prefix + ".b" in params:
            y = y + params[prefix + ".b"]
        return y

    return linear_fn
