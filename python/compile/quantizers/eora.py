"""EoRA baseline (Liu et al., 2024): training-free eigenspace low-rank
compensation.

Projects the quantization error E = W − Q(W) into the eigenspace of the
activation Gram XᵀX, truncates there (so directions the data actually
exercises are kept first) and projects back:

    H = U diag(λ) Uᵀ;  E' = E U diag(√λ̃);  Σ' = SVD_r(E');
    Σ = Σ' diag(1/√λ̃) Uᵀ

with λ̃ floored well above zero (EoRA regularises; unlike CALDERA-lite it
does not chase near-null-space directions, which keeps it bounded-ish but
limits how much error it can cancel).
"""

from __future__ import annotations

import numpy as np

from . import dequant, rtn_parts, sym_eigh


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0):
    h = np.asarray(stats["h"], np.float64)
    codes, scales, zeros = rtn_parts(w, bits, group)
    q = dequant(codes, scales, zeros, group)
    e = w - q

    lam, u = sym_eigh(h)
    lmax = float(lam.max()) if lam.size else 1.0
    lam_f = np.maximum(lam, 1e-4 * max(lmax, 1e-12))  # strong floor: regularised
    sqrt_l = np.sqrt(lam_f)
    ew = (e @ u) * sqrt_l[None, :]
    uu, ss, vvt = np.linalg.svd(ew, full_matrices=False)
    b = (uu[:, :rank] * ss[:rank]).astype(np.float32)
    a = ((vvt[:rank] / sqrt_l[None, :]) @ u.T).astype(np.float32)
    return {"codes": codes, "scales": scales, "zeros": zeros, "a": a, "b": b}
