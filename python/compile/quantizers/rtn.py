"""Round-To-Nearest baseline: group-wise asymmetric RTN, no calibration."""

from __future__ import annotations

import numpy as np

from . import rtn_parts


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0):
    codes, scales, zeros = rtn_parts(w, bits, group)
    return {"codes": codes, "scales": scales, "zeros": zeros}
