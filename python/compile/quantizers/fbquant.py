"""FBQuant — the paper's method (§4).

Feedback sub-branch: the main path stores Q(W − Σ) and the runtime adds
Σ = B·A back, so the reconstruction error |w − w_F| = |(w−σ) − Q(w−σ)| is
bounded by s/2 *regardless of Σ* (Eq. 13). A and B are optimized by
layer-wise reconstruction (Algorithm 1) with the §4.2 STE detach, via
`calibrate.fbquant_optimize` on the Gram-form loss.
"""

from __future__ import annotations

import numpy as np

from . import rtn_parts
from ..calibrate import fbquant_optimize


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0,
                   steps: int = 160, lr: float = 2e-3):
    h = np.asarray(stats["h"], np.float64)
    a, b, _hist = fbquant_optimize(w, h, bits, group, rank, steps=steps, lr=lr, seed=seed)
    sigma = b @ a
    # main path: Q(W − Σ); the feedback grid is recomputed for W − Σ
    codes, scales, zeros = rtn_parts(w - sigma, bits, group)
    return {"codes": codes, "scales": scales, "zeros": zeros, "a": a, "b": b}
