"""OmniQuant-lite baseline (Shao et al., 2023): learnable clipping.

Per-group clipping factors γ ∈ (0,1] for the min/max quantization range
are learned by gradient descent on the Gram-form layer reconstruction loss
(straight-through rounding). The full OmniQuant also learns equivalent
transformations; the clipping component is the one that matters for
weight-only quantization (their LWC), so this lite version keeps exactly
that — noted in DESIGN.md §2.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import rtn_parts
from ..calibrate import omniquant_optimize
from ..kernels import ref as kref


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0):
    h = np.asarray(stats["h"], np.float64)
    clip_lo, clip_hi, _hist = omniquant_optimize(w, h, bits, group)
    wj = jnp.asarray(w, jnp.float32)
    scale, zero = kref.quant_params(wj, bits, group, jnp.asarray(clip_lo), jnp.asarray(clip_hi))
    codes = kref.quantize(wj, bits, group, scale, zero)
    return {
        "codes": np.asarray(codes),
        "scales": np.asarray(scale),
        "zeros": np.asarray(zero),
    }
