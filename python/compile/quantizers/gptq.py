"""GPTQ baseline (Frantar et al., 2022): column-wise optimal-brain-
compression with Hessian-guided error propagation.

The Hessian of the layer-wise quadratic objective is ``2·XᵀX`` — exactly
the Gram matrix captured by `calibrate`. We implement the standard
sequential algorithm (no act-order) with per-group scale refresh: when the
column index crosses a group boundary, scale/zero for that group are
recomputed from the *current* (error-compensated) weights, matching the
groupsize behaviour of the reference implementation.
"""

from __future__ import annotations

import numpy as np


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0,
                   damp: float = 0.01):
    out, cin = w.shape
    h = np.asarray(stats["h"], np.float64).copy()
    # dampen: H += damp * mean(diag) * I  (dead columns get identity)
    diag_mean = float(np.mean(np.diag(h))) or 1.0
    h[np.diag_indices(cin)] += damp * diag_mean
    dead = np.diag(h) <= 0
    h[dead, dead] = diag_mean

    # Hinv via Cholesky of the inverse (upper triangular), as in the paper.
    hinv = np.linalg.inv(h)
    # regularize tiny asymmetries before cholesky
    hinv = 0.5 * (hinv + hinv.T)
    try:
        u = np.linalg.cholesky(hinv).T  # upper
    except np.linalg.LinAlgError:
        hinv[np.diag_indices(cin)] += 1e-8 * np.mean(np.diag(hinv))
        u = np.linalg.cholesky(hinv).T

    wq = np.asarray(w, np.float64).copy()
    codes = np.zeros((out, cin), np.int8)
    scales = np.zeros((out, cin // group), np.float32)
    zeros = np.zeros((out, cin // group), np.float32)
    qmax = (1 << bits) - 1

    g_scale = np.zeros(out)
    g_zero = np.zeros(out)
    for j in range(cin):
        if j % group == 0:
            # refresh quantization grid for this group from current weights
            gidx = j // group
            wg = wq[:, j : j + group]
            lo = np.minimum(wg.min(axis=1), 0.0)
            hi = np.maximum(wg.max(axis=1), 0.0)
            g_scale = np.maximum((hi - lo) / qmax, 1e-8)
            g_zero = np.round(-lo / g_scale)
            scales[:, gidx] = g_scale
            zeros[:, gidx] = g_zero
        col = wq[:, j]
        q = np.clip(np.round(col / g_scale) + g_zero, 0, qmax)
        codes[:, j] = q.astype(np.int8)
        deq = (q - g_zero) * g_scale
        err = (col - deq) / u[j, j]
        if j + 1 < cin:
            wq[:, j + 1 :] -= np.outer(err, u[j, j + 1 :])

    return {"codes": codes, "scales": scales, "zeros": zeros}
