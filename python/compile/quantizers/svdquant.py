"""SVDQuant-style baseline (Li et al., 2024): outlier absorption.

The high-rank components of W capture most outliers; SVDQuant keeps the
top-r SVD component in the FP sub-branch and quantizes only the residual:
``Σ = SVD_r(W)``, ``W' = Q(W − Σ) + Σ``. Weight-only adaptation of the
diffusion-model method, as the paper's comparison does. Data-free; it
optimises the *weight* error, not the layer-output error — the weakness
the paper calls out on 3-bit Llama3-8B.
"""

from __future__ import annotations

import numpy as np

from . import rtn_parts


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0):
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    b = (u[:, :rank] * s[:rank]).astype(np.float32)
    a = vt[:rank].astype(np.float32)
    sigma = b @ a
    codes, scales, zeros = rtn_parts(w - sigma, bits, group)
    return {"codes": codes, "scales": scales, "zeros": zeros, "a": a, "b": b}
