"""AWQ baseline (Lin et al., 2024): activation-aware weight scaling.

Salient input channels (large mean |x|) are protected by scaling the
weight columns up before quantization and folding the inverse scale into
the activation path: ``W ≈ dequant(Q(W·diag(s))) · diag(s)⁻¹`` so the
runtime computes ``y = (x/s… )`` — concretely we emit
``col_scale = 1/s`` and codes for ``W·diag(s)``. The exponent α of
``s = (mean|x| / gmean)^α`` is grid-searched against the Gram-form
reconstruction loss, as in the reference implementation.
"""

from __future__ import annotations

import numpy as np

from . import dequant, recon_loss_np, rtn_parts


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0):
    h = np.asarray(stats["h"], np.float64)
    mean_abs = np.asarray(stats["mean_abs"], np.float64)
    mean_abs = np.maximum(mean_abs, 1e-8)
    # normalize to geometric mean 1 so scales stay O(1)
    s_base = mean_abs / np.exp(np.mean(np.log(mean_abs)))

    best = None
    for alpha in np.linspace(0.0, 1.0, 11):
        s = np.power(s_base, alpha)
        s = np.clip(s, 1e-4, 1e4)
        codes, scales, zeros = rtn_parts(w * s[None, :], bits, group)
        w_eff = dequant(codes, scales, zeros, group) / s[None, :]
        loss = recon_loss_np(w_eff, w, h)
        if best is None or loss < best[0]:
            best = (loss, alpha, codes, scales, zeros, s)

    _, alpha, codes, scales, zeros, s = best
    return {
        "codes": codes,
        "scales": scales,
        "zeros": zeros,
        "col_scale": (1.0 / s).astype(np.float32),
    }
