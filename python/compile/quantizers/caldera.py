"""CALDERA-style baseline (Saha et al., 2024): calibration-aware
alternating quantized + low-rank decomposition.

Alternates between quantizing the residual and solving the H-weighted
low-rank problem  min_Σ tr((E − Σ) H (E − Σ)ᵀ)  via SVD in the
H^{1/2}-whitened space. With limited calibration data H is rank-deficient,
so the whitening uses a pseudo-inverse — components in the near-null space
of H are unconstrained by the objective. That is precisely the ill-posed
optimization of the paper's §3.1, and this implementation inherits it
faithfully (see `ablation_overfit`).
"""

from __future__ import annotations

import numpy as np

from . import dequant, rtn_parts, sym_eigh


def _weighted_lowrank(e: np.ndarray, lam: np.ndarray, u: np.ndarray, rank: int,
                      rel_floor: float = 1e-10) -> np.ndarray:
    """argmin_{rank-r Σ} tr((E−Σ) H (E−Σ)ᵀ) with H = U diag(λ) Uᵀ.

    Solution: whiten with λ^{1/2}, truncated SVD, un-whiten with λ^{-1/2}.
    Eigenvalues below `rel_floor·λmax` are floored (pseudo-inverse): the
    corresponding directions are *unconstrained* by the calibration data.
    """
    lmax = float(lam.max()) if lam.size else 1.0
    lam_f = np.maximum(lam, rel_floor * max(lmax, 1e-12))
    sqrt_l = np.sqrt(lam_f)
    ew = (e @ u) * sqrt_l[None, :]
    uu, ss, vvt = np.linalg.svd(ew, full_matrices=False)
    sw = (uu[:, :rank] * ss[:rank]) @ vvt[:rank]
    return (sw / sqrt_l[None, :]) @ u.T


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0,
                   iters: int = 4):
    h = np.asarray(stats["h"], np.float64)
    lam, u = sym_eigh(h)
    sigma = np.zeros_like(w)
    codes = scales = zeros = None
    for _ in range(iters):
        codes, scales, zeros = rtn_parts(w - sigma, bits, group)
        q = dequant(codes, scales, zeros, group)
        sigma = _weighted_lowrank(w - q, lam, u, rank)
    # factor Σ for the runtime sub-branch format
    uu, ss, vvt = np.linalg.svd(sigma, full_matrices=False)
    b = (uu[:, :rank] * ss[:rank]).astype(np.float32)
    a = vvt[:rank].astype(np.float32)
    return {"codes": codes, "scales": scales, "zeros": zeros, "a": a, "b": b}
