"""LoftQ-style baseline (Li et al., 2023): alternating SVD sub-branch.

Data-free alternation:  Σ₀ = 0;  repeat  Q_t = RTN(W − Σ_{t−1}),
Σ_t = SVD_r(W − Q_t).  The final reconstruction is Q + Σ — the
*conventional* (non-feedback) sub-branch form the paper's §3.1 analyses.
"""

from __future__ import annotations

import numpy as np

from . import dequant, rtn_parts


def quantize_layer(w: np.ndarray, stats, bits: int, group: int, rank: int, seed: int = 0,
                   iters: int = 4):
    sigma = np.zeros_like(w)
    codes = scales = zeros = None
    for _ in range(iters):
        codes, scales, zeros = rtn_parts(w - sigma, bits, group)
        q = dequant(codes, scales, zeros, group)
        e = w - q
        u, s, vt = np.linalg.svd(e, full_matrices=False)
        sigma = (u[:, :rank] * s[:rank]) @ vt[:rank]
    b = (u[:, :rank] * s[:rank]).astype(np.float32)
    a = vt[:rank].astype(np.float32)
    return {"codes": codes, "scales": scales, "zeros": zeros, "a": a, "b": b}
