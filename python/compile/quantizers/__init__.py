"""The quantizer zoo: the paper's baselines plus FBQuant.

Every method implements::

    quantize_layer(w, stats, bits, group, rank, seed) -> dict

with ``w`` the float weights ``[out, in]`` (numpy), ``stats`` the
calibration statistics for this linear (``{"h": [in,in], "mean_abs":
[in]}``, see `calibrate.capture_stats`), and returns numpy tensors:

* ``codes``  int8 ``[out, in]`` — quantization codes (pre-packing),
* ``scales``/``zeros`` f32 ``[out, in/group]``,
* optional ``a`` ``[r, in]`` / ``b`` ``[out, r]`` — the low-rank
  sub-branch Σ = B·A,
* optional ``col_scale`` f32 ``[in]`` — multiplier applied to the layer
  *input* at runtime (AWQ's activation-aware scaling, folded kernel-side).

The reconstructed weight every method is judged on (and that the rust
engine executes) is::

    W' = dequant(codes) ⊙ col_scaleᵀ? … specifically
    y  = (x * col_scale) @ dequant(codes).T + ((x * col_scale) @ A.T) @ B.T

(col_scale defaults to ones; the sub-branch, when present, sees the scaled
input too — both branches read the same activation buffer, exactly like
the fused kernel.)
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from ..kernels import ref as kref
import jax.numpy as jnp


def rtn_parts(w: np.ndarray, bits: int, group: int):
    """Plain RTN codes/scales/zeros for float weights."""
    wj = jnp.asarray(w, jnp.float32)
    scale, zero = kref.quant_params(wj, bits, group)
    codes = kref.quantize(wj, bits, group, scale, zero)
    return np.asarray(codes), np.asarray(scale), np.asarray(zero)


def dequant(codes: np.ndarray, scales: np.ndarray, zeros: np.ndarray, group: int) -> np.ndarray:
    return np.asarray(kref.dequantize(jnp.asarray(codes), jnp.asarray(scales), jnp.asarray(zeros), group))


def effective_weight(q: Dict[str, np.ndarray], group: int) -> np.ndarray:
    """The float weight matrix the runtime actually applies (for analysis
    and tests): W_eff = (dequant + BᵀA) ⊙ col_scale (per input column)."""
    w = dequant(q["codes"], q["scales"], q["zeros"], group)
    if "a" in q and q.get("a") is not None:
        w = w + q["b"] @ q["a"]
    if "col_scale" in q and q.get("col_scale") is not None:
        w = w * q["col_scale"][None, :]
    return w


def recon_loss_np(w_eff: np.ndarray, w: np.ndarray, h: np.ndarray) -> float:
    """tr((W−W') H (W−W')ᵀ), normalised by tr(W H Wᵀ)."""
    d = w - w_eff
    num = float(np.einsum("oi,ij,oj->", d, h, d))
    den = float(np.einsum("oi,ij,oj->", w, h, w)) + 1e-12
    return num / den


def sym_eigh(h: np.ndarray):
    """Eigendecomposition of the (symmetrised, slightly damped) Gram."""
    hs = 0.5 * (h + h.T)
    lam, u = np.linalg.eigh(hs)
    return np.maximum(lam, 0.0), u


# registry is populated lazily to avoid import cycles
def get(method: str) -> Callable:
    from . import rtn, gptq, awq, omniquant, loftq, svdquant, caldera, eora, fbquant

    table = {
        "rtn": rtn.quantize_layer,
        "gptq": gptq.quantize_layer,
        "awq": awq.quantize_layer,
        "omniquant": omniquant.quantize_layer,
        "loftq": loftq.quantize_layer,
        "svdquant": svdquant.quantize_layer,
        "caldera": caldera.quantize_layer,
        "eora": eora.quantize_layer,
        "fbquant": fbquant.quantize_layer,
    }
    return table[method]


METHODS = ["rtn", "gptq", "awq", "omniquant", "loftq", "svdquant", "caldera", "eora", "fbquant"]
# methods that carry a sub-branch at runtime
SUB_BRANCH_METHODS = {"loftq", "svdquant", "caldera", "eora", "fbquant"}
