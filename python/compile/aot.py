"""AOT lowering: JAX graphs → HLO text artifacts + manifest.

The interchange format is HLO *text* (NOT `.serialize()`): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla_extension 0.5.1
backing the rust `xla` crate rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (under ``artifacts/hlo/``), all taking weights as *runtime
parameters* so one compiled executable serves every quantization method of
matching shape — the rust coordinator swaps `.fbqw` payloads without
recompiling:

* ``score_<model>_fp``     tokens[B,T] → logits[B,T,V]        (FP weights)
* ``score_<model>_q``      tokens[B,T] → logits[B,T,V]        (codes/scales/
                           zeros/a/b/col_scale per linear)
* ``prefill_<model>_<p>_b<B>`` tokens[B,Tp] → (logits[B,V], kv_k, kv_v)
* ``decode_<model>_<p>_b<B>``  (tokens[B,1], pos, kv) → (logits[B,V], kv')
* ``kernel_fused_m<M>`` / ``kernel_unfused_m<M>`` — the §4.3 Pallas fused
  kernel vs the conventional 4-kernel pipeline as standalone computations
  (runtime microbench + cross-language correctness target)

``manifest.json`` records for each artifact the ordered input tensors
(name/dtype/shape) and outputs, so the rust runtime can marshal literals
positionally. A ``selftest`` archive with golden inputs/outputs enables an
end-to-end numerics assertion from rust.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import pack
from .model import MODELS, Config, decode_step, forward, make_quantized_linear
from .quantize_all import default_rank

GROUP = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Weight-parameter plumbing
# ---------------------------------------------------------------------------

def fp_param_order(cfg: Config) -> List[str]:
    """Deterministic order of the float parameter tensors."""
    names = ["tok_emb", "lm_head"]
    if not cfg.rope:
        names.append("pos_emb")
    for l in range(cfg.n_layers):
        p = f"l{l}."
        names += [p + "attn_norm.w", p + "mlp_norm.w"]
        if not cfg.rms:
            names += [p + "attn_norm.b", p + "mlp_norm.b"]
        for lname in cfg.linear_names():
            names.append(p + lname + ".w")
            if (lname in ("q", "k", "v") and cfg.qkv_bias) or (
                lname in ("fc", "proj") and cfg.mlp_bias
            ):
                names.append(p + lname + ".b")
    names.append("final_norm.w")
    if not cfg.rms:
        names.append("final_norm.b")
    return names


def fp_param_spec(cfg: Config, name: str) -> Tuple[Tuple[int, ...], str]:
    if name in ("tok_emb", "lm_head"):
        return (cfg.vocab, cfg.d_model), "f32"
    if name == "pos_emb":
        return (cfg.max_seq, cfg.d_model), "f32"
    base = name.split(".")[-2] if "." in name else name
    field = name.split(".")[-1]
    if "norm" in name:
        return (cfg.d_model,), "f32"
    lname = name.split(".")[1]
    out, cin = cfg.linear_shape(lname)
    if field == "w":
        return (out, cin), "f32"
    return (out,), "f32"


def q_param_order(cfg: Config, rank: int) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Quantized-path parameters: float leftovers + per-linear q tensors.

    Returns (name, shape, dtype) in feed order. Codes are fed UNPACKED as
    int32 [out, in] (the rust runtime unpacks the nibble archive on load —
    packing is a storage/bandwidth format, not a compute format on this CPU
    substrate; i32 because the rust `xla` crate's Literal supports
    i32/i64/u32/u64/f32/f64 only)."""
    entries: List[Tuple[str, Tuple[int, ...], str]] = []
    for name in fp_param_order(cfg):
        parts = name.split(".")
        if len(parts) >= 2 and parts[0].startswith("l") and parts[-1] == "w" and parts[1] in cfg.linear_names():
            l, lname = parts[0], parts[1]
            out, cin = cfg.linear_shape(lname)
            prefix = f"{l}.{lname}"
            entries.append((prefix + "/codes", (out, cin), "i32"))
            entries.append((prefix + "/scales", (out, cin // GROUP), "f32"))
            entries.append((prefix + "/zeros", (out, cin // GROUP), "f32"))
            entries.append((prefix + "/a", (rank, cin), "f32"))
            entries.append((prefix + "/b", (out, rank), "f32"))
            entries.append((prefix + "/col_scale", (cin,), "f32"))
        else:
            entries.append((name, *[fp_param_spec(cfg, name)][0]))
    # fix tuple structure: fp entries need (name, shape, dtype)
    fixed = []
    for e in entries:
        if len(e) == 3:
            fixed.append(e)
        else:  # (name, (shape, dtype))
            name, (shape, dtype) = e
            fixed.append((name, shape, dtype))
    return fixed


_DT = {"f32": jnp.float32, "i8": jnp.int8, "i32": jnp.int32, "u32": jnp.uint32}


def _specs(entries):
    return [jax.ShapeDtypeStruct(shape, _DT[dt]) for _, shape, dt in entries]


def _rebuild_params(cfg: Config, entries, args):
    """Split flat args into (float params dict, qweights dict)."""
    params: Dict[str, jnp.ndarray] = {}
    qweights: Dict[str, Dict[str, jnp.ndarray]] = {}
    for (name, _, _), arr in zip(entries, args):
        if "/" in name:
            prefix, field = name.split("/")
            qweights.setdefault(prefix, {})[field] = arr
        else:
            params[name] = arr
    return params, qweights


# ---------------------------------------------------------------------------
# Graph builders
# ---------------------------------------------------------------------------

def build_score(cfg: Config, quantized: bool, batch: int, seq: int, rank: int):
    if quantized:
        entries = q_param_order(cfg, rank)

        def fn(tokens, *wargs):
            params, qweights = _rebuild_params(cfg, entries, wargs)
            linear_fn = make_quantized_linear(qweights, group=GROUP)
            return (forward(cfg, params, tokens, linear_fn=linear_fn),)

    else:
        entries = [(n, *fp_param_spec(cfg, n)) for n in fp_param_order(cfg)]

        def fn(tokens, *wargs):
            params, _ = _rebuild_params(cfg, entries, wargs)
            return (forward(cfg, params, tokens),)

    data_inputs = [("tokens", (batch, seq), "i32")]
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, seq), jnp.int32), *_specs(entries)
    )
    outputs = [("logits", (batch, seq, cfg.vocab), "f32")]
    return lowered, data_inputs + entries, outputs


def _kv_shape(cfg: Config, batch: int):
    return (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)


def build_step(cfg: Config, quantized: bool, batch: int, t_step: int, rank: int):
    """Prefill (t_step > 1) or decode (t_step == 1) graph with KV cache."""
    if quantized:
        entries = q_param_order(cfg, rank)
    else:
        entries = [(n, *fp_param_spec(cfg, n)) for n in fp_param_order(cfg)]

    kv_shape = _kv_shape(cfg, batch)

    def fn(tokens, pos0, kv_k, kv_v, *wargs):
        params, qweights = _rebuild_params(cfg, entries, wargs)
        linear_fn = make_quantized_linear(qweights, group=GROUP) if quantized else None
        kwargs = {"linear_fn": linear_fn} if linear_fn else {}
        logits, nk, nv = decode_step(cfg, params, tokens, pos0, kv_k, kv_v, **kwargs)
        return (logits[:, -1, :], nk, nv)

    data_inputs = [
        ("tokens", (batch, t_step), "i32"),
        ("pos0", (), "i32"),
        ("kv_k", kv_shape, "f32"),
        ("kv_v", kv_shape, "f32"),
    ]
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, t_step), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        *_specs(entries),
    )
    outputs = [
        ("logits", (batch, cfg.vocab), "f32"),
        ("kv_k", kv_shape, "f32"),
        ("kv_v", kv_shape, "f32"),
    ]
    return lowered, data_inputs + entries, outputs


def build_kernel(fused: bool, m: int, k: int, n: int, r: int):
    """Standalone §4.3 kernel artifact (pallas, interpret=True)."""
    from .kernels import fused_qmm as fq

    gk = k // GROUP

    def fn(x, codes, scales, zeros, a, b):
        f = fq.fused_qmm if fused else fq.unfused_qmm
        return (f(x, codes, scales, zeros, a, b, group=GROUP),)

    inputs = [
        ("x", (m, k), "f32"),
        ("codes", (n, k), "i32"),
        ("scales", (n, gk), "f32"),
        ("zeros", (n, gk), "f32"),
        ("a", (r, k), "f32"),
        ("b", (n, r), "f32"),
    ]
    lowered = jax.jit(fn).lower(*_specs(inputs))
    return lowered, inputs, [("y", (m, n), "f32")]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def emit(outdir: str, name: str, lowered, inputs, outputs, manifest: list, kind: str,
         extra: dict | None = None):
    path = os.path.join(outdir, "hlo", f"{name}.hlo.txt")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    manifest.append(
        {
            "name": name,
            "path": f"hlo/{name}.hlo.txt",
            "kind": kind,
            "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
            "outputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in outputs],
            **(extra or {}),
        }
    )
    print(f"  wrote {name} ({len(text) / 1024:.0f} KiB)", flush=True)


def selftest_archive(outdir: str, cfg: Config) -> None:
    """Golden input/output pair for the rust runtime integration test."""
    fp_path = os.path.join(outdir, "models", f"{cfg.name}_fp.fbqw")
    tensors, _ = pack.read_fbqw(fp_path)
    params = {k: jnp.asarray(v) for k, v in tensors.items()}
    rng = np.random.default_rng(7)
    tokens = rng.integers(1, 256, size=(1, 16)).astype(np.int32)
    logits = np.asarray(forward(cfg, params, jnp.asarray(tokens)))
    pack.write_fbqw(
        os.path.join(outdir, "hlo", "selftest.fbqw"),
        {"tokens": tokens, "logits": logits.astype(np.float32)},
        meta={"kind": "selftest", "model": cfg.name, "batch": 1, "seq": 16},
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--score-models", default="all")
    ap.add_argument("--serve-models", default="llamoid-tiny,llamoid-small")
    ap.add_argument("--score-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    manifest: list = []
    score_models = list(MODELS) if args.score_models == "all" else args.score_models.split(",")

    for mname in score_models:
        cfg = MODELS[mname]
        rank = default_rank(cfg)
        print(f"[score] {mname}")
        for quantized in (False, True):
            tag = "q" if quantized else "fp"
            lowered, inputs, outputs = build_score(cfg, quantized, args.score_batch, args.seq, rank)
            emit(args.out, f"score_{mname}_{tag}", lowered, inputs, outputs, manifest,
                 "score", {"model": mname, "quantized": quantized,
                           "batch": args.score_batch, "seq": args.seq,
                           "rank": rank, "group": GROUP})

    for mname in args.serve_models.split(","):
        cfg = MODELS[mname]
        rank = default_rank(cfg)
        print(f"[serve] {mname}")
        for quantized in (False, True):
            tag = "q" if quantized else "fp"
            for batch in (1, 4):
                # multiple prefill chunk lengths: the coordinator chunks a
                # prompt greedily (128s, then 32s, then single decode
                # steps), since pos0 is a shared scalar per batch.
                for t_step in (128, 32):
                    lowered, inputs, outputs = build_step(cfg, quantized, batch, t_step, rank)
                    emit(args.out, f"prefill_{mname}_{tag}_b{batch}_t{t_step}", lowered,
                         inputs, outputs, manifest, "prefill",
                         {"model": mname, "quantized": quantized, "batch": batch,
                          "t_step": t_step, "rank": rank, "group": GROUP})
                lowered, inputs, outputs = build_step(cfg, quantized, batch, 1, rank)
                emit(args.out, f"decode_{mname}_{tag}_b{batch}", lowered, inputs, outputs,
                     manifest, "decode", {"model": mname, "quantized": quantized,
                                          "batch": batch, "t_step": 1,
                                          "rank": rank, "group": GROUP})

    # §4.3 kernel microbench artifacts (modest shape: interpret-mode pallas
    # lowers to plain HLO; the fused/unfused structural difference survives)
    m, k, n, r = 32, 512, 512, 64
    for fused in (True, False):
        tag = "fused" if fused else "unfused"
        lowered, inputs, outputs = build_kernel(fused, m, k, n, r)
        emit(args.out, f"kernel_{tag}_m{m}", lowered, inputs, outputs, manifest,
             "kernel", {"fused": fused, "m": m, "k": k, "n": n, "rank": r, "group": GROUP})

    selftest_archive(args.out, MODELS["llamoid-tiny"])

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"version": 1, "group": GROUP, "artifacts": manifest}, f, indent=1)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
