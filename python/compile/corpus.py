"""Deterministic synthetic corpus + evaluation suites.

Offline stand-in for WikiText2 + the seven lm-eval zero-shot benchmarks
(see DESIGN.md §2 for the substitution argument). A seeded generator
produces an English-like corpus with learnable structure:

* topical articles (6 topics biasing content-word choice),
* singular/plural subject–verb agreement,
* arithmetic facts ("four plus three equals seven."),
* local word-order and punctuation regularities,
* repeated-name copy patterns (induction).

From the same distribution we derive:

* `corpus_train` / `corpus_val` token streams (byte-level),
* `calib` — 128 sequences × 256 tokens, sentence-aligned (the paper's
  128-sample calibration protocol, scaled to our context length),
* seven multiple-choice suites scored exactly like lm-eval harness
  (length-normalised log-likelihood), one per structural regularity,
* `judge` — 80 prompt/gold-continuation pairs for the Fig-6 pairwise
  comparison protocol.

Everything is written as `.fbqw` archives consumed by the rust evaluator.
"""

from __future__ import annotations

import os
from typing import Dict, List, Tuple

import numpy as np

from . import pack, tokenizer

SEED = 20250710

TOPICS = ["sea", "forest", "city", "music", "garden", "winter"]

NOUNS: Dict[str, List[str]] = {
    "sea": ["crab", "wave", "sailor", "reef", "shell", "tide", "gull", "harbor"],
    "forest": ["fox", "pine", "trail", "owl", "moss", "deer", "clearing", "stream"],
    "city": ["tram", "market", "lamp", "bridge", "courier", "plaza", "tower", "crowd"],
    "music": ["drum", "chord", "singer", "flute", "rhythm", "stage", "anthem", "string"],
    "garden": ["rose", "bee", "hedge", "gardener", "tulip", "pond", "vine", "sparrow"],
    "winter": ["snow", "sled", "skater", "frost", "lantern", "storm", "icicle", "cabin"],
}

ADJS: Dict[str, List[str]] = {
    "sea": ["salty", "blue", "restless", "deep"],
    "forest": ["green", "quiet", "ancient", "shaded"],
    "city": ["busy", "bright", "narrow", "loud"],
    "music": ["soft", "steady", "clear", "bold"],
    "garden": ["fragrant", "sunny", "tidy", "wild"],
    "winter": ["cold", "white", "still", "pale"],
}

# verb -> (singular form, plural form); intransitive continuations per topic.
VERBS: List[Tuple[str, str]] = [
    ("drifts", "drift"),
    ("waits", "wait"),
    ("turns", "turn"),
    ("rests", "rest"),
    ("moves", "move"),
    ("shines", "shine"),
    ("falls", "fall"),
    ("calls", "call"),
]

PLACES: Dict[str, List[str]] = {
    "sea": ["in the sea", "near the shore", "under the waves", "by the harbor"],
    "forest": ["in the forest", "under the pines", "along the trail", "by the stream"],
    "city": ["in the city", "on the bridge", "near the plaza", "by the tower"],
    "music": ["on the stage", "in the hall", "near the drums", "by the strings"],
    "garden": ["in the garden", "by the pond", "near the hedge", "under the vine"],
    "winter": ["in the snow", "by the cabin", "under the frost", "near the lantern"],
}

NUM_WORDS = [
    "zero", "one", "two", "three", "four", "five", "six", "seven", "eight",
    "nine", "ten", "eleven", "twelve", "thirteen", "fourteen", "fifteen",
    "sixteen", "seventeen", "eighteen", "nineteen", "twenty",
]

NAMES = ["mara", "toby", "iris", "felix", "nell", "orin", "puck", "sable"]


def plural(noun: str) -> str:
    if noun.endswith("s") or noun.endswith("sh"):
        return noun + "es"
    return noun + "s"


class Gen:
    """Sentence/article generator over a seeded numpy RNG."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)

    def choice(self, xs):
        return xs[int(self.rng.integers(len(xs)))]

    def noun_phrase(self, topic: str, singular: bool) -> str:
        noun = self.choice(NOUNS[topic])
        form = noun if singular else plural(noun)
        if self.rng.random() < 0.5:
            return f"the {self.choice(ADJS[topic])} {form}"
        return f"the {form}"

    def sentence(self, topic: str) -> str:
        r = self.rng.random()
        if r < 0.08:
            # arithmetic fact (consistent world knowledge)
            a = int(self.rng.integers(0, 11))
            b = int(self.rng.integers(0, 10))
            return f"{NUM_WORDS[a]} plus {NUM_WORDS[b]} equals {NUM_WORDS[a + b]}."
        if r < 0.16:
            # name echo pattern (induction food)
            n1, n2 = self.choice(NAMES), self.choice(NAMES)
            v = self.choice(VERBS)
            return f"{n1} and {n2} {v[1]} together, then {n1} and {n2} {self.choice(VERBS)[1]} again."
        singular = self.rng.random() < 0.6
        np_ = self.noun_phrase(topic, singular)
        v = self.choice(VERBS)
        verb = v[0] if singular else v[1]
        place = self.choice(PLACES[topic])
        if self.rng.random() < 0.3:
            return f"{np_} {verb} {place}, and {self.noun_phrase(topic, True)} {self.choice(VERBS)[0]} there."
        return f"{np_} {verb} {place}."

    def article(self) -> str:
        topic = self.choice(TOPICS)
        n = int(self.rng.integers(4, 12))
        sents = []
        for _ in range(n):
            # mostly on-topic, occasional drift keeps it non-trivial
            t = topic if self.rng.random() < 0.85 else self.choice(TOPICS)
            sents.append(self.sentence(t))
        return f"= {topic} =\n" + " ".join(sents) + "\n\n"

    def text(self, min_bytes: int) -> str:
        parts = []
        total = 0
        while total < min_bytes:
            a = self.article()
            parts.append(a)
            total += len(a)
        return "".join(parts)


# ---------------------------------------------------------------------------
# Multiple-choice suites (lm-eval-style: pick argmax length-normalised ll).
# ---------------------------------------------------------------------------

def _mc_agree(g: Gen, nq: int):
    """Subject–verb agreement (BoolQ-ish binary choice)."""
    qs = []
    for _ in range(nq):
        topic = g.choice(TOPICS)
        singular = g.rng.random() < 0.5
        np_ = g.noun_phrase(topic, singular)
        v = g.choice(VERBS)
        place = g.choice(PLACES[topic])
        good = f"{v[0] if singular else v[1]} {place}."
        bad = f"{v[1] if singular else v[0]} {place}."
        opts = [good, bad]
        correct = 0
        if g.rng.random() < 0.5:
            opts = [bad, good]
            correct = 1
        qs.append((f"{np_} ", opts, correct))
    return qs


def _mc_topic(g: Gen, nq: int):
    """Topic tracking (ARC-challenge-ish 4-way)."""
    qs = []
    for _ in range(nq):
        topic = g.choice(TOPICS)
        ctx_sents = " ".join(g.sentence(topic) for _ in range(3))
        good_noun = g.choice(NOUNS[topic])
        others = [t for t in TOPICS if t != topic]
        bads = [g.choice(NOUNS[g.choice(others)]) for _ in range(3)]
        v = g.choice(VERBS)[0]
        place = g.choice(PLACES[topic])
        opts = [f"the {w} {v} {place}." for w in [good_noun] + bads]
        order = list(g.rng.permutation(4))
        correct = order.index(0)
        opts = [opts[i] for i in order]
        qs.append((f"= {topic} =\n{ctx_sents} ", opts, correct))
    return qs


def _mc_cloze(g: Gen, nq: int):
    """Sentence completion with well-formed vs corrupted endings (HellaSwag-ish)."""
    qs = []
    for _ in range(nq):
        topic = g.choice(TOPICS)
        np_ = g.noun_phrase(topic, True)
        v = g.choice(VERBS)[0]
        place = g.choice(PLACES[topic])
        good = f"{place}."
        # corruptions: reversed words, missing article, cross-topic place
        words = place.split()
        bad1 = " ".join(words[::-1]) + "."
        bad2 = " ".join(w for w in words if w != "the") + "."
        bad3 = g.choice(PLACES[g.choice([t for t in TOPICS if t != topic])]) + "."
        opts = [good, bad1, bad2, bad3]
        order = list(g.rng.permutation(4))
        correct = order.index(0)
        opts = [opts[i] for i in order]
        qs.append((f"{np_} {v} ", opts, correct))
    return qs


def _mc_arith(g: Gen, nq: int):
    """Memorised arithmetic facts (MMLU-ish knowledge)."""
    qs = []
    for _ in range(nq):
        a = int(g.rng.integers(0, 11))
        b = int(g.rng.integers(0, 10))
        good = NUM_WORDS[a + b]
        wrong = set()
        while len(wrong) < 3:
            w = NUM_WORDS[int(g.rng.integers(0, 21))]
            if w != good:
                wrong.add(w)
        opts = [f"{w}." for w in [good] + sorted(wrong)]
        order = list(g.rng.permutation(4))
        correct = order.index(0)
        opts = [opts[i] for i in order]
        qs.append((f"{NUM_WORDS[a]} plus {NUM_WORDS[b]} equals ", opts, correct))
    return qs


def _mc_copy(g: Gen, nq: int):
    """Induction / copy pattern (PIQA-ish binary)."""
    qs = []
    for _ in range(nq):
        n1, n2 = g.choice(NAMES), g.choice(NAMES)
        while n2 == n1:
            n2 = g.choice(NAMES)
        v1, v2 = g.choice(VERBS)[1], g.choice(VERBS)[1]
        ctx = f"{n1} and {n2} {v1} together, then {n1} and "
        good, bad = f"{n2} {v2} again.", f"{g.choice([n for n in NAMES if n not in (n1, n2)])} {v2} again."
        opts, correct = ([good, bad], 0) if g.rng.random() < 0.5 else ([bad, good], 1)
        qs.append((ctx, opts, correct))
    return qs


def _mc_order(g: Gen, nq: int):
    """Adjective–noun word order (WinoGrande-ish binary)."""
    qs = []
    for _ in range(nq):
        topic = g.choice(TOPICS)
        adj, noun = g.choice(ADJS[topic]), g.choice(NOUNS[topic])
        v = g.choice(VERBS)[0]
        place = g.choice(PLACES[topic])
        good = f"the {adj} {noun} {v} {place}."
        bad = f"the {noun} {adj} {v} {place}."
        opts, correct = ([good, bad], 0) if g.rng.random() < 0.5 else ([bad, good], 1)
        qs.append(("", opts, correct))
    return qs


def _mc_punct(g: Gen, nq: int):
    """Well-formed sentence termination (ARC-easy-ish binary)."""
    qs = []
    for _ in range(nq):
        topic = g.choice(TOPICS)
        np_ = g.noun_phrase(topic, True)
        v = g.choice(VERBS)[0]
        place = g.choice(PLACES[topic])
        words = place.split()
        good = f"{place}."
        bad = " ".join(words[:-1]) + "."  # drop the head noun of the PP
        opts, correct = ([good, bad], 0) if g.rng.random() < 0.5 else ([bad, good], 1)
        qs.append((f"{np_} {v} ", opts, correct))
    return qs


TASKS = {
    "agree": (_mc_agree, 2),
    "topic": (_mc_topic, 4),
    "cloze": (_mc_cloze, 4),
    "arith": (_mc_arith, 4),
    "copy": (_mc_copy, 2),
    "order": (_mc_order, 2),
    "punct": (_mc_punct, 2),
}


def _pack_task(path: str, name: str, qs, n_options: int) -> None:
    ctx_flat, ctx_off = [], [0]
    opt_flat, opt_off = [], [0]
    correct = []
    for ctx, opts, c in qs:
        assert len(opts) == n_options
        ids = tokenizer.encode(ctx)
        ctx_flat.extend(ids)
        ctx_off.append(len(ctx_flat))
        for o in opts:
            oids = tokenizer.encode(o)
            opt_flat.extend(oids)
            opt_off.append(len(opt_flat))
        correct.append(c)
    pack.write_fbqw(
        path,
        {
            "ctx_flat": np.asarray(ctx_flat, np.uint8),
            "ctx_off": np.asarray(ctx_off, np.uint32),
            "opt_flat": np.asarray(opt_flat, np.uint8),
            "opt_off": np.asarray(opt_off, np.uint32),
            "correct": np.asarray(correct, np.uint32),
        },
        meta={"kind": "mc_task", "task": name, "n_questions": len(qs), "n_options": n_options},
    )


def _sentence_aligned_calib(text: str, n_seqs: int, seq_len: int, rng) -> np.ndarray:
    starts = [i + 2 for i, c in enumerate(text) if c == "." and i + 2 + seq_len < len(text)]
    idx = rng.choice(len(starts), size=n_seqs, replace=False)
    rows = []
    for i in idx:
        s = starts[int(i)]
        rows.append(tokenizer.encode(text[s : s + seq_len * 2])[:seq_len])
    return np.asarray(rows, np.uint8)


def build(outdir: str, train_bytes: int = 2_000_000, val_bytes: int = 40_000,
          calib_seqs: int = 128, calib_len: int = 256, nq: int = 80) -> None:
    os.makedirs(os.path.join(outdir, "tasks"), exist_ok=True)
    g = Gen(SEED)
    train_text = g.text(train_bytes)
    val_text = Gen(SEED + 1).text(val_bytes)
    judge_gen = Gen(SEED + 2)
    task_gen = Gen(SEED + 3)

    pack.write_fbqw(
        os.path.join(outdir, "corpus_train.fbqw"),
        {"tokens": np.asarray(tokenizer.encode(train_text), np.uint8)},
        meta={"kind": "tokens", "split": "train"},
    )
    pack.write_fbqw(
        os.path.join(outdir, "corpus_val.fbqw"),
        {"tokens": np.asarray(tokenizer.encode(val_text), np.uint8)},
        meta={"kind": "tokens", "split": "val"},
    )
    calib = _sentence_aligned_calib(train_text, calib_seqs, calib_len, np.random.default_rng(SEED + 4))
    pack.write_fbqw(
        os.path.join(outdir, "calib.fbqw"),
        {"tokens": calib},
        meta={"kind": "calib", "n_seqs": calib_seqs, "seq_len": calib_len},
    )

    for name, (fn, n_opt) in TASKS.items():
        qs = fn(task_gen, nq)
        _pack_task(os.path.join(outdir, "tasks", f"{name}.fbqw"), name, qs, n_opt)

    # Fig-6 judge set: 80 prompts with gold continuations (held-out dist).
    ctx_flat, ctx_off, gold_flat, gold_off = [], [0], [], [0]
    for _ in range(nq):
        topic = judge_gen.choice(TOPICS)
        ctx_sents = " ".join(judge_gen.sentence(topic) for _ in range(2))
        gold = judge_gen.sentence(topic)
        ids = tokenizer.encode(f"= {topic} =\n{ctx_sents} ")
        ctx_flat.extend(ids)
        ctx_off.append(len(ctx_flat))
        gids = tokenizer.encode(gold)
        gold_flat.extend(gids)
        gold_off.append(len(gold_flat))
    pack.write_fbqw(
        os.path.join(outdir, "judge.fbqw"),
        {
            "ctx_flat": np.asarray(ctx_flat, np.uint8),
            "ctx_off": np.asarray(ctx_off, np.uint32),
            "gold_flat": np.asarray(gold_flat, np.uint8),
            "gold_off": np.asarray(gold_off, np.uint32),
        },
        meta={"kind": "judge", "n_questions": nq},
    )

    tokenizer.write_spec(os.path.join(outdir, "vocab.json"))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/data")
    ap.add_argument("--train-bytes", type=int, default=2_000_000)
    args = ap.parse_args()
    build(args.out, train_bytes=args.train_bytes)
    print(f"corpus + tasks written to {args.out}")
