"""Build-time pretraining of the tiny model grid.

Pure-JAX Adam (no optax offline) with linear warmup + cosine decay,
next-token cross-entropy over random corpus windows. Checkpoints are
written as `.fbqw` archives consumed by both the quantizer zoo and the
rust engine.

Usage:  python -m compile.train --out ../artifacts [--model llamoid-tiny]
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import pack
from .model import MODELS, Config, forward, init_params, loss_fn

# steps tuned for a single CPU core; tiny models saturate on this corpus.
STEPS = {
    "llamoid-tiny": 500,
    "llamoid-small": 350,
    "llamoid-base": 280,
    "gptoid-tiny": 500,
    "gptoid-small": 350,
    "qwenoid-tiny": 500,
}
BATCH = 16
SEQ = 128
PEAK_LR = 3e-3
WARMUP = 50


def lr_at(step: int, total: int) -> float:
    if step < WARMUP:
        return PEAK_LR * (step + 1) / WARMUP
    t = (step - WARMUP) / max(1, total - WARMUP)
    return PEAK_LR * 0.5 * (1.0 + np.cos(np.pi * t)) + 1e-5


def adam_init(params: Dict[str, jnp.ndarray]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def make_step(cfg: Config):
    @jax.jit
    def step(params, opt, batch, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        # global-norm clip at 1.0
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.95, 1e-8
        new_m, new_v, new_p = {}, {}, {}
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)
        for k, g in grads.items():
            g = g * clip
            m = b1 * opt["m"][k] + (1 - b1) * g
            v = b2 * opt["v"][k] + (1 - b2) * g * g
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_p[k] = params[k] - lr * upd
            new_m[k], new_v[k] = m, v
        return new_p, {"m": new_m, "v": new_v, "t": t}, loss, gnorm

    return step


def batches(tokens: np.ndarray, rng: np.random.Generator, batch: int, seq: int):
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([tokens[i : i + seq + 1] for i in idx]).astype(np.int32)


def eval_ppl(cfg: Config, params, val: np.ndarray, seq: int = 256, max_tokens: int = 16_384) -> float:
    """Byte-level perplexity on the first `max_tokens` of the val stream."""
    fwd = jax.jit(lambda p, t: forward(cfg, p, t))
    total_ll, total_n = 0.0, 0
    n_seqs = min(max_tokens // seq, (len(val) - 1) // seq)
    for i in range(n_seqs):
        chunk = val[i * seq : i * seq + seq + 1].astype(np.int32)
        logits = fwd(params, chunk[None, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, chunk[None, 1:, None], axis=-1)
        total_ll += float(jnp.sum(ll))
        total_n += seq
    return float(np.exp(-total_ll / total_n))


def train_model(cfg: Config, train_tokens: np.ndarray, val_tokens: np.ndarray,
                outpath: str, steps: int | None = None, seed: int = 0) -> float:
    steps = steps or STEPS.get(cfg.name, 600)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    step_fn = make_step(cfg)
    rng = np.random.default_rng(seed + 1)
    gen = batches(train_tokens, rng, BATCH, SEQ)
    t0 = time.time()
    for s in range(steps):
        batch = jnp.asarray(next(gen))
        params, opt, loss, gnorm = step_fn(params, opt, batch, lr_at(s, steps))
        if s % 100 == 0 or s == steps - 1:
            print(
                f"[{cfg.name}] step {s:4d}/{steps} loss={float(loss):.4f} "
                f"gnorm={float(gnorm):.2f} ({time.time() - t0:.0f}s)",
                flush=True,
            )
    ppl = eval_ppl(cfg, params, val_tokens)
    print(f"[{cfg.name}] done: val byte-ppl={ppl:.3f} params={cfg.n_params()/1e6:.2f}M", flush=True)
    tensors = {k: np.asarray(v, np.float32) for k, v in params.items()}
    meta = {"kind": "weights", "scheme": "fp", "config": cfg.to_meta(), "val_ppl": ppl, "steps": steps}
    pack.write_fbqw(outpath, tensors, meta)
    return ppl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="all")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    data_dir = os.path.join(args.out, "data")
    train_tokens, _ = pack.read_fbqw(os.path.join(data_dir, "corpus_train.fbqw"))
    val_tokens, _ = pack.read_fbqw(os.path.join(data_dir, "corpus_val.fbqw"))
    train_tokens = train_tokens["tokens"]
    val_tokens = val_tokens["tokens"]

    names = list(MODELS) if args.model == "all" else [args.model]
    os.makedirs(os.path.join(args.out, "models"), exist_ok=True)
    for name in names:
        outpath = os.path.join(args.out, "models", f"{name}_fp.fbqw")
        if os.path.exists(outpath):
            print(f"[{name}] checkpoint exists, skipping")
            continue
        train_model(MODELS[name], train_tokens, val_tokens, outpath,
                    steps=args.steps or None)


if __name__ == "__main__":
    main()
