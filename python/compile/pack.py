"""The `.fbqw` tensor-archive format (writer + reader, python side).

One container format is used for everything that crosses the python→rust
boundary: model weights (float and quantized), calibration/validation token
streams, and zero-shot task suites. The rust reader lives in
`rust/src/quant/formats.rs`; both sides are round-trip tested.

Layout (little endian):

    magic   b"FBQW"
    version u32 (currently 1)
    hdr_len u64
    header  utf-8 JSON: {"meta": {...}, "tensors": [
                {"name": str, "dtype": "f32|i32|i8|u8|u32",
                 "shape": [..], "offset": int, "nbytes": int}, ...]}
    payload tensors at 64-byte-aligned offsets (relative to payload start)
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Tuple

import numpy as np

MAGIC = b"FBQW"
VERSION = 1
ALIGN = 64

_DTYPES = {
    "f32": np.float32,
    "i32": np.int32,
    "i8": np.int8,
    "u8": np.uint8,
    "u32": np.uint32,
}
_DTYPE_NAMES = {np.dtype(v): k for k, v in _DTYPES.items()}


def _dtype_name(arr: np.ndarray) -> str:
    try:
        return _DTYPE_NAMES[arr.dtype]
    except KeyError:
        raise ValueError(f"unsupported dtype {arr.dtype} (use one of {list(_DTYPES)})")


def write_fbqw(path: str, tensors: Dict[str, np.ndarray], meta: Dict[str, Any] | None = None) -> None:
    """Write a tensor archive. `tensors` preserves insertion order."""
    entries: List[Dict[str, Any]] = []
    offset = 0
    blobs: List[Tuple[int, bytes]] = []
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        # align
        if offset % ALIGN:
            offset += ALIGN - (offset % ALIGN)
        entries.append(
            {
                "name": name,
                "dtype": _dtype_name(arr),
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        blobs.append((offset, raw))
        offset += len(raw)

    header = json.dumps({"meta": meta or {}, "tensors": entries}).encode("utf-8")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(VERSION).tobytes())
        f.write(np.uint64(len(header)).tobytes())
        f.write(header)
        payload_start = f.tell()
        for off, raw in blobs:
            f.seek(payload_start + off)
            f.write(raw)
    os.replace(tmp, path)


def read_fbqw(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read a tensor archive back into numpy arrays."""
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version = int(np.frombuffer(f.read(4), np.uint32)[0])
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        hdr_len = int(np.frombuffer(f.read(8), np.uint64)[0])
        header = json.loads(f.read(hdr_len).decode("utf-8"))
        payload_start = f.tell()
        tensors: Dict[str, np.ndarray] = {}
        for e in header["tensors"]:
            f.seek(payload_start + e["offset"])
            raw = f.read(e["nbytes"])
            arr = np.frombuffer(raw, _DTYPES[e["dtype"]]).reshape(e["shape"]).copy()
            tensors[e["name"]] = arr
    return tensors, header.get("meta", {})
