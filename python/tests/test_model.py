"""Model-definition tests: shapes, families, decode/forward consistency,
quantized path wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    MODELS, Config, decode_step, forward, init_params, loss_fn,
    make_quantized_linear,
)
from compile.kernels import ref as kref


SMALL = Config("test-llamoid", "llamoid", d_model=32, n_layers=2, n_heads=2, d_ff=48, max_seq=64)
SMALL_GPT = Config("test-gptoid", "gptoid", d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=64)
SMALL_QWEN = Config("test-qwenoid", "qwenoid", d_model=32, n_layers=2, n_heads=2, d_ff=48, max_seq=64)


@pytest.mark.parametrize("cfg", [SMALL, SMALL_GPT, SMALL_QWEN], ids=lambda c: c.family)
def test_forward_shapes_and_loss(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 256, size=(2, 17)))
    logits = forward(cfg, params, tokens)
    assert logits.shape == (2, 17, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = loss_fn(cfg, params, tokens)
    # untrained byte model: loss near ln(256) ≈ 5.55
    assert 4.0 < float(loss) < 7.0


@pytest.mark.parametrize("cfg", [SMALL, SMALL_GPT, SMALL_QWEN], ids=lambda c: c.family)
def test_decode_matches_forward(cfg):
    """Prefill + incremental decode must reproduce the full-sequence logits."""
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    T = 12
    tokens = jnp.asarray(rng.integers(1, 256, size=(1, T)))
    full = forward(cfg, params, tokens)

    L, B, H, hd, Tm = cfg.n_layers, 1, cfg.n_heads, cfg.head_dim, cfg.max_seq
    kv_k = jnp.zeros((L, B, Tm, H, hd))
    kv_v = jnp.zeros((L, B, Tm, H, hd))
    # prefill the first 5 tokens, then decode one at a time
    logits_p, kv_k, kv_v = decode_step(cfg, params, tokens[:, :5], 0, kv_k, kv_v)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, :5]), rtol=2e-3, atol=2e-4)
    for t in range(5, T):
        step_logits, kv_k, kv_v = decode_step(cfg, params, tokens[:, t : t + 1], t, kv_k, kv_v)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-4
        )


def test_param_count_matches_config():
    for cfg in [SMALL, SMALL_GPT, SMALL_QWEN]:
        params = init_params(cfg, jax.random.PRNGKey(0))
        # count only the tensors n_params() models (norm vectors excluded)
        total = sum(
            int(np.prod(v.shape)) for k, v in params.items()
            if not ("norm" in k or k.endswith(".b"))
        )
        assert total == cfg.n_params()


def test_model_grid_is_well_formed():
    for name, cfg in MODELS.items():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.head_dim % 2 == 0  # rope half-split
        for lname in cfg.linear_names():
            out, cin = cfg.linear_shape(lname)
            assert cin % 128 == 0, f"{name}.{lname}: in={cin} not group-128 aligned"
            assert cin % 8 == 0  # nibble packing


def _quantize_params(cfg, params, bits=4, group=16, rank=4):
    qweights = {}
    for l in range(cfg.n_layers):
        for lname in cfg.linear_names():
            prefix = f"l{l}.{lname}"
            w = params[prefix + ".w"]
            scale, zero = kref.quant_params(w, bits, group)
            codes = kref.quantize(w, bits, group, scale, zero)
            qweights[prefix] = {"codes": codes, "scales": scale, "zeros": zero}
    return qweights


def test_quantized_forward_close_to_float():
    cfg = SMALL
    params = init_params(cfg, jax.random.PRNGKey(2))
    # 6-bit: the finest grid whose codes fit the int8 code tensor (0..63)
    qweights = _quantize_params(cfg, params, bits=6, group=16)
    linear_fn = make_quantized_linear(qweights, group=16)
    tokens = jnp.asarray(np.random.default_rng(2).integers(0, 256, size=(1, 9)))
    lf = np.asarray(forward(cfg, params, tokens)).ravel()
    lq = np.asarray(forward(cfg, params, tokens, linear_fn=linear_fn)).ravel()
    # an untrained 2-layer model amplifies per-weight error; assert strong
    # agreement rather than elementwise closeness
    cos = float(np.dot(lf, lq) / (np.linalg.norm(lf) * np.linalg.norm(lq)))
    assert cos > 0.995, f"cosine {cos}"
    assert float(np.max(np.abs(lf - lq))) < 0.75


def test_quantized_forward_pallas_matches_ref_path():
    cfg = SMALL
    params = init_params(cfg, jax.random.PRNGKey(3))
    qweights = _quantize_params(cfg, params, bits=4, group=16)
    tokens = jnp.asarray(np.random.default_rng(3).integers(0, 256, size=(1, 8)))
    l_ref = forward(cfg, params, tokens, linear_fn=make_quantized_linear(qweights, group=16))
    l_pal = forward(
        cfg, params, tokens,
        linear_fn=make_quantized_linear(qweights, group=16, use_pallas=True),
    )
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref), rtol=1e-3, atol=1e-3)
