"""Quantizer-zoo behaviour on a controlled synthetic layer.

The invariants mirror the paper's claims:

* every calibration-aware method beats or matches RTN on the Gram loss,
* FBQuant's reconstruction deviation obeys the s/2 bound (Eq. 13),
* CALDERA-lite with rank-deficient H produces *unbounded* weight
  deviations (the §3.1 ill-posedness) while its calibration loss stays
  competitive — the overfitting signature,
* GPTQ strictly improves on RTN.
"""

import numpy as np
import pytest

from compile import quantizers
from compile.kernels import ref as kref
import jax.numpy as jnp

OUT, CIN, GROUP, RANK, BITS = 24, 64, 16, 6, 3


@pytest.fixture(scope="module")
def layer(rng):
    w = rng.normal(0, 0.5, size=(OUT, CIN))
    # a few salient input channels: ordinary weights hit by large
    # activations (AWQ's regime — their quantization error matters most)
    x = rng.normal(size=(400, CIN))
    x[:, :4] *= 6.0
    h = x.T @ x
    stats = {"h": h, "mean_abs": np.abs(x).mean(axis=0)}
    return w, stats


@pytest.fixture(scope="module")
def results(layer):
    w, stats = layer
    out = {}
    for m in quantizers.METHODS:
        q = quantizers.get(m)(w, stats, BITS, GROUP, RANK, seed=0)
        w_eff = quantizers.effective_weight(q, GROUP)
        loss = quantizers.recon_loss_np(w_eff, w, np.asarray(stats["h"]))
        out[m] = (q, w_eff, loss)
    return out


def test_all_methods_produce_valid_codes(results):
    for m, (q, _, _) in results.items():
        assert q["codes"].dtype == np.int8
        assert q["codes"].min() >= 0
        assert q["codes"].max() <= (1 << BITS) - 1, m
        assert q["scales"].shape == (OUT, CIN // GROUP)


def test_calibrated_methods_beat_rtn(results):
    rtn_loss = results["rtn"][2]
    for m in ["gptq", "awq", "omniquant", "caldera", "eora", "fbquant"]:
        assert results[m][2] <= rtn_loss * 1.05, f"{m}: {results[m][2]:.4e} vs rtn {rtn_loss:.4e}"


def test_gptq_strictly_improves(results):
    assert results["gptq"][2] < results["rtn"][2] * 0.9


def test_fbquant_among_best(results):
    """FBQuant materially beats RTN and the data-free sub-branch methods on
    the calibration loss (its *raw* calib loss can trail CALDERA/GPTQ —
    boundedness, not loss-chasing, is its contribution)."""
    fbq = results["fbquant"][2]
    assert fbq < results["rtn"][2] * 0.6
    assert fbq < results["loftq"][2]
    assert fbq < results["svdquant"][2]
    best = min(loss for _, _, loss in results.values())
    assert fbq <= best * 6.0


def test_fbquant_bound(results, layer):
    """Eq. 13: deviation of the reconstructed weights bounded by s/2."""
    w, _ = layer
    q, w_eff, _ = results["fbquant"]
    sigma = q["b"] @ q["a"]
    bound = np.asarray(kref.scale_bound(jnp.asarray(w, jnp.float32),
                                        jnp.asarray(sigma, jnp.float32), BITS, GROUP))
    dev = np.abs(w - w_eff)
    assert np.all(dev <= bound + 1e-4)


def test_subbranch_methods_have_rank_r_factors(results):
    for m in quantizers.SUB_BRANCH_METHODS:
        q = results[m][0]
        assert q["a"].shape == (RANK, CIN), m
        assert q["b"].shape == (OUT, RANK), m


def test_caldera_overfits_rank_deficient_calibration(rng):
    """§3.1 reproduced in miniature: with n << CIN calibration rows, the
    ill-posed objective lets CALDERA place huge mass in the null space of
    H (low calib loss, wild weights). FBQuant stays bounded by design."""
    w = rng.normal(0, 0.5, size=(OUT, CIN))
    x = rng.normal(size=(6, CIN))  # rank 6 << 64
    h = x.T @ x
    stats = {"h": h, "mean_abs": np.abs(x).mean(axis=0)}

    q_cal = quantizers.get("caldera")(w, stats, BITS, GROUP, RANK, seed=0)
    q_fbq = quantizers.get("fbquant")(w, stats, BITS, GROUP, RANK, seed=0)

    def dev_vs_own_bound(q):
        w_eff = quantizers.effective_weight(q, GROUP)
        sigma = q["b"] @ q["a"] if q.get("a") is not None else np.zeros_like(w)
        bound = np.asarray(kref.scale_bound(
            jnp.asarray(w, jnp.float32), jnp.asarray(sigma, jnp.float32), BITS, GROUP))
        dev = np.abs(w - w_eff)
        return float(np.max(dev / (bound + 1e-12)))

    # FBQuant respects its grid bound; CALDERA's conventional form exceeds
    # it (the unbounded Σ term of §3.1)
    assert dev_vs_own_bound(q_fbq) <= 1.0 + 1e-3
    assert dev_vs_own_bound(q_cal) > 1.0 + 1e-3

    # and CALDERA "wins" the ill-posed objective while doing so — the
    # overfit signature (low calib loss, out-of-grid weights)
    loss_cal = quantizers.recon_loss_np(quantizers.effective_weight(q_cal, GROUP), w, h)
    loss_fbq = quantizers.recon_loss_np(quantizers.effective_weight(q_fbq, GROUP), w, h)
    assert loss_cal < loss_fbq * 1.5


def test_awq_emits_col_scale_and_improves_salient(layer, results):
    q, _, _ = results["awq"]
    assert "col_scale" in q and q["col_scale"].shape == (CIN,)
    # activation-aware scaling strictly improves the weighted loss, and the
    # salient channels' activation-weighted error shrinks vs RTN
    w, stats = layer
    assert results["awq"][2] < results["rtn"][2] * 0.999
    ma = stats["mean_abs"]
    rtn_err = np.linalg.norm(((w - results["rtn"][1]) * ma[None, :])[:, :4])
    awq_err = np.linalg.norm(((w - results["awq"][1]) * ma[None, :])[:, :4])
    assert awq_err < rtn_err
