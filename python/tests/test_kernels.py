"""Pallas kernels vs the pure-jnp oracle (interpret=True on CPU).

Hypothesis sweeps shapes, group sizes, ranks and bit-widths; the fused and
un-fused pipelines must agree with `ref.qmm_ref` to float tolerance.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_qmm, quantize as kquant, ref as kref


def _mk(rng, m, k, n, r, bits, group):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    scale, zero = kref.quant_params(w, bits, group)
    codes = kref.quantize(w, bits, group, scale, zero)
    if r:
        a = jnp.asarray(rng.normal(size=(r, k)).astype(np.float32) * 0.1)
        b = jnp.asarray(rng.normal(size=(n, r)).astype(np.float32) * 0.1)
    else:
        a = b = None
    return x, codes, scale, zero, a, b


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 3, 16]),
    k=st.sampled_from([32, 64]),
    n=st.sampled_from([16, 48]),
    r=st.sampled_from([0, 4, 8]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_qmm_matches_ref(m, k, n, r, bits, seed):
    rng = np.random.default_rng(seed)
    group = 16
    x, codes, scale, zero, a, b = _mk(rng, m, k, n, r, bits, group)
    got = fused_qmm.fused_qmm(x, codes, scale, zero, a, b, group=group,
                              block_m=8, block_n=16)
    want = kref.qmm_ref(x, codes, scale, zero, a, b, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([1, 5]),
    r=st.sampled_from([0, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_unfused_pipeline_matches_fused(m, r, seed):
    """The 4-kernel pipeline and the fused kernel compute the same thing —
    the paper's fusion is a pure performance transformation."""
    rng = np.random.default_rng(seed)
    group, k, n, bits = 16, 64, 32, 4
    x, codes, scale, zero, a, b = _mk(rng, m, k, n, r, bits, group)
    yf = fused_qmm.fused_qmm(x, codes, scale, zero, a, b, group=group,
                             block_m=8, block_n=16)
    yu = fused_qmm.unfused_qmm(x, codes, scale, zero, a, b, group=group,
                               block_m=8, block_n=16)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yu), rtol=1e-4, atol=1e-4)


def test_fused_qmm_ragged_grid(rng):
    """M, N not divisible by the block sizes exercises pallas padding."""
    group, k, n, m, bits = 16, 64, 40, 13, 4
    x, codes, scale, zero, a, b = _mk(rng, m, k, n, 8, bits, group)
    got = fused_qmm.fused_qmm(x, codes, scale, zero, a, b, group=group,
                              block_m=8, block_n=16)
    want = kref.qmm_ref(x, codes, scale, zero, a, b, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    out=st.sampled_from([8, 24, 128]),
    ng=st.sampled_from([1, 2, 4]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_kernel_matches_ref(out, ng, bits, seed):
    rng = np.random.default_rng(seed)
    group = 16
    w = jnp.asarray(rng.normal(size=(out, group * ng)).astype(np.float32))
    codes, scales, zeros = kquant.quantize_pallas(w, bits=bits, group=group, block_rows=8)
    scale_r, zero_r = kref.quant_params(w, bits, group)
    codes_r = kref.quantize(w, bits, group, scale_r, zero_r)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scale_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(zeros), np.asarray(zero_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_r))


def test_traffic_model_shape():
    """The analytic model reproduces Fig. 4's qualitative shape."""
    from compile.kernels import traffic

    rows = traffic.fig4_rows()
    prefill = next(r for r in rows if r["phase"] == "prefill")
    decode = next(r for r in rows if r["phase"] == "decode")
    # MACs overhead is the paper's 6.25%
    assert abs(prefill["macs_overhead"] - 0.0625) < 1e-9
    # naive sub-branch hurts decode far more than prefill
    assert decode["int4_sub"] > 2.0
    assert prefill["int4_sub"] < 1.6
    # fusion recovers most of the decode overhead
    assert decode["int4_fused"] < 0.5 * decode["int4_sub"]
    # weight-only quantization beats FP16 at decode (Fig. 1 regime)
    assert decode["fp16"] > 1.5
    saved = traffic.extra_latency_saved()
    assert 0.5 < saved < 1.0
