"""Calibration statistics + the two per-layer optimisation loops."""

import jax
import numpy as np
import pytest

from compile.calibrate import capture_stats, fbquant_optimize, omniquant_optimize, recon_loss
from compile.model import Config, init_params, forward
from compile.kernels import ref as kref
import jax.numpy as jnp

CFG = Config("test-cap", "llamoid", d_model=32, n_layers=2, n_heads=2, d_ff=48, max_seq=64)


@pytest.fixture(scope="module")
def captured():
    params = init_params(CFG, jax.random.PRNGKey(0))
    tokens = np.random.default_rng(0).integers(0, 256, size=(6, 24)).astype(np.uint8)
    return params, tokens, capture_stats(CFG, params, tokens, batch=3)


def test_capture_covers_all_linears(captured):
    _, _, stats = captured
    expected = {f"l{l}.{n}" for l in range(2) for n in CFG.linear_names()}
    assert set(stats) == expected


def test_h_is_psd_and_correct_shape(captured):
    _, _, stats = captured
    for prefix, s in stats.items():
        cin = CFG.linear_shape(prefix.split(".")[1])[1]
        assert s["h"].shape == (cin, cin)
        lam = np.linalg.eigvalsh(0.5 * (s["h"] + s["h"].T))
        assert lam.min() > -1e-3 * max(lam.max(), 1.0)
        assert s["mean_abs"].shape == (cin,)
        assert int(s["n"][0]) == 6 * 24


def test_h_matches_manual_gram(captured):
    """Cross-check the q-projection's H against an explicit recompute."""
    params, tokens, stats = captured
    from compile.model import embed, norm

    x = embed(CFG, params, jnp.asarray(tokens.astype(np.int32)))
    h_in = norm(CFG, params, "l0.attn_norm", x)
    x2 = np.asarray(h_in).reshape(-1, CFG.d_model)
    np.testing.assert_allclose(stats["l0.q"]["h"], x2.T @ x2, rtol=1e-3, atol=1e-2)


def test_fbquant_optimize_reduces_loss(rng):
    w = rng.normal(0, 0.5, size=(16, 32))
    x = rng.normal(size=(100, 32))
    h = x.T @ x
    a, b, hist = fbquant_optimize(w, h, bits=3, group=16, rank=4, steps=60, lr=5e-3)
    assert hist[-1] < hist[0] * 0.9, f"no improvement: {hist[0]:.4e} -> {hist[-1]:.4e}"
    assert a.shape == (4, 32) and b.shape == (16, 4)
    assert np.isfinite(a).all() and np.isfinite(b).all()


def test_omniquant_optimize_reduces_loss(rng):
    w = rng.normal(0, 0.5, size=(16, 32))
    # heavy-tailed weights: clipping should help
    w[rng.random(w.shape) < 0.02] *= 8.0
    x = rng.normal(size=(100, 32))
    h = x.T @ x
    lo, hi, hist = omniquant_optimize(w, h, bits=3, group=16, steps=60, lr=1e-2)
    assert hist[-1] <= hist[0]
    assert np.all((lo > 0) & (lo <= 1)) and np.all((hi > 0) & (hi <= 1))


def test_recon_loss_zero_for_exact(rng):
    w = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    h = jnp.eye(16)
    assert float(recon_loss(w, w, h)) == 0.0
