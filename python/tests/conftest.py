import os
import sys

# allow `import compile.*` when pytest runs from python/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest
from hypothesis import settings

# one CPU core: keep hypothesis sweeps small but meaningful
settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
