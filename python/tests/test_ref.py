"""Oracle self-consistency: the ref quantizer's mathematical invariants,
including the paper's central bound (Eq. 13)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import ref as kref


def _w(rng, out, cin, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, size=(out, cin)).astype(np.float32))


@given(
    out=st.integers(1, 12),
    g=st.sampled_from([8, 16, 32]),
    ng=st.integers(1, 4),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rtn_error_bounded_by_half_scale(out, g, ng, bits, seed):
    """|w - Q(w)| <= s/2 per element (the quantizer covers [min,max]∪{0})."""
    rng = np.random.default_rng(seed)
    w = _w(rng, out, g * ng)
    scale, zero = kref.quant_params(w, bits, g)
    wq = kref.dequantize(kref.quantize(w, bits, g, scale, zero), scale, zero, g)
    bound = jnp.repeat(scale, g, axis=1) / 2
    assert jnp.all(jnp.abs(w - wq) <= bound + 1e-6)


@given(
    out=st.integers(1, 10),
    ng=st.integers(1, 3),
    bits=st.sampled_from([3, 4]),
    rank=st.integers(1, 6),
    sigma_scale=st.sampled_from([0.01, 0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fbq_bound_holds_for_any_sigma(out, ng, bits, rank, sigma_scale, seed):
    """Paper Eq. 13: |w - W_F| <= s/2 REGARDLESS of the sub-branch Σ —
    even adversarially large Σ cannot break the feedback bound."""
    g = 16
    rng = np.random.default_rng(seed)
    w = _w(rng, out, g * ng)
    b = jnp.asarray(rng.normal(0, sigma_scale, size=(out, rank)).astype(np.float32))
    a = jnp.asarray(rng.normal(0, sigma_scale, size=(rank, g * ng)).astype(np.float32))
    sigma = b @ a
    w_f = kref.fbq_reconstruct(w, sigma, bits, g)
    bound = kref.scale_bound(w, sigma, bits, g)
    assert jnp.all(jnp.abs(w - w_f) <= bound + 1e-5)


def test_conventional_subbranch_is_unbounded(rng):
    """Contrast (paper §3.1): W' = Q(W) + Σ deviates arbitrarily with Σ."""
    w = _w(rng, 4, 32)
    sigma = jnp.ones((4, 32)) * 100.0
    w_rec = kref.quantize_dequantize(w, 4, 16) + sigma
    assert float(jnp.max(jnp.abs(w - w_rec))) > 50.0


def test_qmm_ref_matches_dense(rng):
    w = _w(rng, 24, 32)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.normal(size=(24, 4)).astype(np.float32) * 0.1)
    scale, zero = kref.quant_params(w, 4, 16)
    codes = kref.quantize(w, 4, 16, scale, zero)
    y = kref.qmm_ref(x, codes, scale, zero, a, b, group=16)
    wd = kref.dequantize(codes, scale, zero, 16)
    expect = x @ (wd + b @ a).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_quantize_codes_in_range(rng):
    w = _w(rng, 8, 64, scale=3.0)
    for bits in (2, 3, 4):
        codes = kref.quantize(w, bits, 16)
        assert int(codes.min()) >= 0
        assert int(codes.max()) <= (1 << bits) - 1


def test_fbq_ste_gradient_flows_through_sigma(rng):
    """§4.2: with the detach, dL/dA and dL/dB are the -2ΔH form, nonzero."""
    import jax

    w = _w(rng, 6, 32)
    a = jnp.asarray(rng.normal(size=(3, 32)).astype(np.float32) * 0.05)
    b = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32) * 0.05)
    h = jnp.eye(32)

    def loss(a, b):
        w_f = kref.fbq_reconstruct_ste(w, a, b, 4, 16)
        d = w - w_f
        return jnp.einsum("oi,ij,oj->", d, h, d)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a, b)
    assert float(jnp.max(jnp.abs(ga))) > 0
    assert float(jnp.max(jnp.abs(gb))) > 0

    # without the detach the gradient is identically zero (paper Eq. 17)
    def loss_nodetach(a, b):
        sigma = b @ a
        # STE on the quantizer: dQ/dW ≈ I, so Q contributes -I and +I cancels
        q = kref.quantize_dequantize(w - sigma, 4, 16)
        q = (w - sigma) + jax.lax.stop_gradient(q - (w - sigma))
        w_f = q + sigma
        d = w - w_f
        return jnp.einsum("oi,ij,oj->", d, h, d)

    ga0, gb0 = jax.grad(loss_nodetach, argnums=(0, 1))(a, b)
    assert float(jnp.max(jnp.abs(ga0))) < 1e-6
    assert float(jnp.max(jnp.abs(gb0))) < 1e-6
