"""Round-trip tests for the .fbqw archive and the nibble packing."""

import numpy as np
from hypothesis import given, strategies as st

from compile import pack
from compile.quantize_all import pack_codes, unpack_codes


def test_fbqw_roundtrip(tmp_path, rng):
    tensors = {
        "a": rng.normal(size=(3, 5)).astype(np.float32),
        "b": rng.integers(-100, 100, size=(7,)).astype(np.int32),
        "c": rng.integers(0, 255, size=(4, 8)).astype(np.uint8),
        "d": rng.integers(0, 2**31, size=(2, 3)).astype(np.uint32),
        "empty_ok": np.zeros((0,), np.float32),
    }
    meta = {"kind": "test", "nested": {"x": [1, 2, 3]}, "s": "héllo"}
    p = str(tmp_path / "t.fbqw")
    pack.write_fbqw(p, tensors, meta)
    back, meta2 = pack.read_fbqw(p)
    assert meta2 == meta
    assert list(back) == list(tensors)  # order preserved
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        np.testing.assert_array_equal(back[k], tensors[k])


def test_fbqw_alignment(tmp_path, rng):
    tensors = {f"t{i}": rng.normal(size=(i + 1,)).astype(np.float32) for i in range(5)}
    p = str(tmp_path / "a.fbqw")
    pack.write_fbqw(p, tensors)
    back, _ = pack.read_fbqw(p)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_fbqw_bad_magic(tmp_path):
    p = tmp_path / "bad.fbqw"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    try:
        pack.read_fbqw(str(p))
        assert False, "should raise"
    except ValueError as e:
        assert "magic" in str(e)


@given(
    out=st.integers(1, 16),
    groups_of8=st.integers(1, 8),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_codes(out, groups_of8, bits, seed):
    rng = np.random.default_rng(seed)
    cin = groups_of8 * 8
    codes = rng.integers(0, 2**bits, size=(out, cin)).astype(np.int8)
    packed = pack_codes(codes)
    assert packed.dtype == np.uint32
    assert packed.shape == (out, cin // 8)
    np.testing.assert_array_equal(unpack_codes(packed, cin), codes)
