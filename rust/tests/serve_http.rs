//! End-to-end tests for the HTTP/SSE serving front end on synthesized
//! checkpoints (no build artifacts needed).
//!
//! The gates:
//! * the SSE token stream is byte-identical to `submit_wait` on the same
//!   seeded backend,
//! * a mid-stream client disconnect cancels the request — the slot is
//!   reclaimed, the KV page pool reconciles to zero pages in use, and
//!   the cancellation is counted,
//! * status mapping: 400 for caller errors, 429 for shed load,
//! * the `/healthz` and `/metrics` routes answer.

use fbquant::coordinator::backend::{Backend, NativeBackend};
use fbquant::coordinator::batcher::BatcherConfig;
use fbquant::coordinator::request::GenRequest;
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::serve::{client, Server, ServeConfig};
use fbquant::testing::{synth_checkpoint, SynthSpec};
use fbquant::util::json::Json;
use std::time::{Duration, Instant};

fn spec() -> SynthSpec {
    SynthSpec { vocab: 64, max_seq: 64, ..SynthSpec::default() }
}

/// A deliberately heavier fixture for the disconnect test: each decode
/// step takes long enough that the client's RST reaches the server well
/// before the token budget runs out, so the cancellation path (not a
/// completed stream) is what the test exercises.
fn slow_spec() -> SynthSpec {
    SynthSpec { d: 128, n_layers: 4, d_ff: 256, vocab: 64, max_seq: 64, ..SynthSpec::default() }
}

fn start_server(
    tag: &'static str,
    spec: SynthSpec,
    kv: Option<(usize, usize)>,
    cfg: CoordinatorConfig,
) -> Server {
    let store = synth_checkpoint(tag, spec);
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let mut b = NativeBackend::new(NativeEngine::from_store(&store, SubMode::Fused)?, tag);
            if let Some((page, pages)) = kv {
                b = b.with_kv_pool(page, pages);
            }
            Ok(Box::new(b))
        },
        cfg,
    );
    Server::start(handle, &ServeConfig::default()).unwrap()
}

#[test]
fn sse_stream_matches_submit_wait() {
    let server = start_server("http_e2e_identity", spec(), None, CoordinatorConfig::default());
    let addr = server.local_addr();
    let prompt: Vec<u32> = (0..12).map(|i| (i * 5 % 64) as u32).collect();

    // reference: blocking in-process call on the same seeded backend
    let reference = server.client().submit_wait(GenRequest::new(0, prompt.clone(), 16)).unwrap();
    assert_eq!(reference.tokens.len(), 16);

    let body = client::gen_body(&GenRequest::new(0, prompt, 16));
    let o = client::post_generate(addr, &body, None).unwrap();
    assert_eq!(o.status, 200);
    assert_eq!(o.tokens, reference.tokens, "SSE stream diverged from submit_wait");

    // the done frame carries the same tokens the stream delivered
    let done = o.done.expect("stream ended without a done frame");
    let done_tokens: Vec<u32> = done
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("done frame without tokens")
        .iter()
        .map(|t| t.as_i64().unwrap() as u32)
        .collect();
    assert_eq!(done_tokens, o.tokens, "done payload disagrees with streamed frames");

    // the admission id travels as a header and inside the done payload,
    // and the done frame carries the phase timing breakdown
    let rid = o.request_id.expect("200 without an X-Request-Id header");
    assert_eq!(done.get("id").and_then(Json::as_i64), Some(rid as i64));
    assert!(done.get("queue_us").and_then(Json::as_f64).is_some_and(|v| v >= 0.0));
    assert!(done.get("prefill_us").and_then(Json::as_f64).is_some_and(|v| v > 0.0));

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 2);
    assert_eq!(metrics.cancellations, 0);
}

#[test]
fn mid_stream_disconnect_frees_slot_and_kv_pages() {
    // page_size 8 with a 6-token prompt: no page ever fills during the
    // prompt, so nothing is published to the prefix cache and a clean
    // cancel must reconcile the pool to exactly zero pages in use
    let server = start_server(
        "http_e2e_disconnect",
        slow_spec(),
        Some((8, 64)),
        CoordinatorConfig::default(),
    );
    let addr = server.local_addr();
    let prompt: Vec<u32> = (0..6).map(|i| (i * 7 % 64) as u32).collect();

    let body = client::gen_body(&GenRequest::new(0, prompt.clone(), 40));
    let o = client::post_generate(addr, &body, Some(3)).unwrap();
    assert_eq!(o.status, 200);
    assert_eq!(o.tokens.len(), 3, "client should have hung up after 3 tokens");
    assert!(o.done.is_none(), "disconnected stream cannot carry a done frame");

    // the serving loop notices the dead sink on a later emit; poll the
    // live metrics until the cancellation lands
    let handle = server.client();
    let deadline = Instant::now() + Duration::from_secs(10);
    let kv = loop {
        let m = handle.metrics().unwrap();
        if m.cancellations >= 1 {
            break m.kv_pool.expect("paged backend must report kv stats");
        }
        assert!(Instant::now() < deadline, "cancellation never recorded");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(kv.pages_in_use, 0, "cancelled request leaked KV pages");
    assert!(kv.pages_total >= 64);

    // the freed slot serves a fresh request end to end
    let r2 = handle.submit_wait(GenRequest::new(0, prompt, 4)).unwrap();
    assert_eq!(r2.tokens.len(), 4);

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.cancellations, 1);
    assert_eq!(metrics.requests_done, 1, "the cancelled request must not count as done");
    let kv = metrics.kv_pool.expect("final snapshot must carry kv stats");
    assert_eq!(kv.pages_in_use, 0, "pool did not reconcile after drain");
}

#[test]
fn routes_and_caller_errors_map_to_400() {
    let server = start_server("http_e2e_routes", spec(), None, CoordinatorConfig::default());
    let addr = server.local_addr();

    let (code, body) = client::get(addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("version").and_then(Json::as_str), Some(env!("CARGO_PKG_VERSION")));
    assert!(j.get("uptime_s").and_then(Json::as_f64).is_some_and(|v| v >= 0.0));
    assert!(j.get("degrade_level").is_some());

    let (code, body) = client::get(addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("requests_in").is_some(), "metrics missing requests_in: {body}");
    assert!(j.get("ttft").is_some());

    // the same snapshot in prometheus text exposition
    let (code, body) = client::get(addr, "/metrics?format=prometheus").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("# TYPE fbq_requests_total counter"), "not an exposition: {body}");
    assert!(body.contains("fbq_latency_seconds_bucket"), "histograms missing: {body}");

    // the trace dump always answers, even with the recorder off
    let (code, body) = client::get(addr, "/debug/trace").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("traceEvents").and_then(Json::as_arr).is_some(), "bad dump: {body}");

    let (code, _) = client::get(addr, "/no/such/route").unwrap();
    assert_eq!(code, 404);

    // malformed body: prompt is not an array
    let bad = Json::obj(vec![("prompt", "hi".into()), ("max_new_tokens", 4usize.into())]);
    let o = client::post_generate(addr, &bad, None).unwrap();
    assert_eq!(o.status, 400);
    assert!(o.error.is_some());

    // valid JSON but prompt + budget exceed the model context: the
    // coordinator rejects it, and the rejection is not an overload
    let long = client::gen_body(&GenRequest::new(0, vec![1; 60], 40));
    let o = client::post_generate(addr, &long, None).unwrap();
    assert_eq!(o.status, 400, "context overflow must map to 400, got {:?}", o.error);

    server.shutdown().unwrap();
}

#[test]
fn admin_shutdown_is_honoured_from_loopback() {
    let server = start_server("http_e2e_shutdown", spec(), None, CoordinatorConfig::default());
    let addr = server.local_addr();
    assert!(!server.shutdown_requested());

    // wrong method: the route is POST-only
    let (code, _) = client::get(addr, "/admin/shutdown").unwrap();
    assert_eq!(code, 404);
    assert!(!server.shutdown_requested(), "a GET must not trigger shutdown");

    let (code, body) = post_empty(addr, "/admin/shutdown");
    assert_eq!(code, 200, "loopback shutdown refused: {body}");
    assert!(body.contains("shutting_down"), "unexpected body: {body}");
    let deadline = Instant::now() + Duration::from_secs(5);
    while !server.shutdown_requested() {
        assert!(Instant::now() < deadline, "shutdown flag never raised");
        std::thread::sleep(Duration::from_millis(5));
    }
    // the flag is advisory: the server keeps serving until the embedder
    // acts on it
    let (code, _) = client::get(addr, "/healthz").unwrap();
    assert_eq!(code, 200, "server died before the embedder shut it down");
    server.shutdown().unwrap();
}

#[cfg(unix)]
#[test]
fn sigterm_triggers_the_same_graceful_drain_path() {
    use fbquant::util::signal;
    extern "C" {
        fn raise(sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;

    let server = start_server("http_e2e_sigterm", spec(), None, CoordinatorConfig::default());
    let addr = server.local_addr();
    // Install before raising: with the handler latched in, SIGTERM below
    // sets a flag instead of killing the whole test process.
    signal::hook_termination();

    // a request completes normally before the signal arrives
    let body = client::gen_body(&GenRequest::new(0, vec![1, 2, 3], 4));
    let o = client::post_generate(addr, &body, None).unwrap();
    assert_eq!(o.status, 200);

    let raiser = std::thread::spawn(|| {
        std::thread::sleep(Duration::from_millis(50));
        unsafe {
            raise(SIGTERM);
        }
    });

    // the exact polling loop `fbquant serve` runs before draining
    let deadline = Instant::now() + Duration::from_secs(5);
    while !signal::termination_requested() && !server.shutdown_requested() {
        assert!(Instant::now() < deadline, "SIGTERM never latched the termination flag");
        std::thread::sleep(Duration::from_millis(5));
    }
    raiser.join().unwrap();
    assert!(signal::termination_requested());

    // the drain path still runs to completion and keeps finished work
    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 1, "graceful drain lost a completed request");
}

/// Bare empty-body POST (the admin routes take no payload).
fn post_empty(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
    )
    .unwrap();
    s.flush().unwrap();
    let mut buf = String::new();
    let _ = s.read_to_string(&mut buf);
    let code =
        buf.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    (code, buf)
}

#[test]
fn shed_load_maps_to_429() {
    // max_queue 0: every admission sheds — the deterministic overload
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_queue: 0, ..BatcherConfig::default() },
        ..CoordinatorConfig::default()
    };
    let server = start_server("http_e2e_shed", spec(), None, cfg);
    let addr = server.local_addr();

    let body = client::gen_body(&GenRequest::new(0, vec![1, 2, 3], 4));
    let o = client::post_generate(addr, &body, None).unwrap();
    assert_eq!(o.status, 429, "shed request must answer 429, got {:?}", o.error);
    assert!(o.error.unwrap().contains("shed"));
    assert!(o.request_id.is_some(), "shed responses still carry X-Request-Id");

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests_shed, 1);
    assert_eq!(metrics.requests_done, 0);
}
