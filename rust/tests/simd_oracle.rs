//! SIMD-vs-scalar bit-exactness oracles.
//!
//! The crate's lane kernels (`tensor::simd`) promise that the vector
//! path performs **identical float operations in identical order** to
//! the scalar reference, so every result — from a bare dot product to a
//! full multi-slot engine decode — must agree *exactly* (`==` on f32,
//! no epsilon) between `Path::Scalar` and `Path::Simd`. On builds
//! without the `simd` feature or hardware, the Simd path falls back to
//! scalar and these tests pass trivially; under `--features simd` on
//! AVX2/NEON hosts they pin the vector kernels bit-for-bit.
//!
//! Edge shapes deliberately use odd word counts per row (cin ∈
//! {24, 40, 104} — `pack_codes` requires cin % 8 == 0, so "odd" means a
//! non-power-of-two number of packed words) with tiny groups, odd row
//! counts, and slot counts straddling the kernel's stack/heap scratch
//! boundary.

use fbquant::engine::kernels::{QuantLinear, Traffic, Workspace};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::tensor::simd::{self, Path};
use fbquant::util::Pcg64;
use std::sync::Mutex;

/// `force_path` is process-global: tests that flip it hold this lock
/// and restore the default on exit (even on panic) so parallel tests in
/// this binary never observe a pinned path.
static PATH_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` once under forced-scalar and once under forced-simd,
/// returning both results. The default path is restored afterwards.
fn run_both<R>(mut f: impl FnMut() -> R) -> (R, R) {
    let _g = PATH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::force_path(None);
        }
    }
    let _restore = Restore;
    simd::force_path(Some(Path::Scalar));
    let scalar = f();
    simd::force_path(Some(Path::Simd));
    let vector = f();
    (scalar, vector)
}

fn randn(rng: &mut Pcg64, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * s).collect()
}

/// Quantize a random dense weight into a `QuantLinear` at the given
/// edge shape (group 8 so every cin that is a multiple of 8 works).
fn mk_layer(
    out: usize,
    cin: usize,
    bits: u8,
    rank: usize,
    col_scale: bool,
    seed: u64,
) -> QuantLinear {
    let mut rng = Pcg64::seeded(seed);
    let w = randn(&mut rng, out * cin, 0.3);
    let p = groupwise::quant_params(&w, out, cin, bits, 8);
    let codes = groupwise::quantize(&w, out, cin, &p);
    QuantLinear {
        out,
        cin,
        bits,
        group: 8,
        packed: pack_codes(&codes, out, cin),
        scales: p.scales,
        zeros: p.zeros,
        rank,
        a: (rank > 0).then(|| randn(&mut rng, rank * cin, 0.02)),
        b: (rank > 0).then(|| randn(&mut rng, out * rank, 0.02)),
        col_scale: col_scale.then(|| (0..cin).map(|_| 0.5 + rng.next_f32()).collect()),
        bias: None,
    }
}

/// The bare dot product takes an explicit path — no global state, no
/// lock — and must agree bitwise at every length class (sub-word,
/// exact-word, tails of every residue).
#[test]
fn dot_is_bit_identical_across_paths() {
    let mut rng = Pcg64::seeded(101);
    for n in [1usize, 3, 7, 8, 9, 24, 40, 104, 129, 257] {
        let a = randn(&mut rng, n, 1.0);
        let b = randn(&mut rng, n, 1.0);
        assert_eq!(
            simd::dot_path(&a, &b, Path::Scalar).to_bits(),
            simd::dot_path(&a, &b, Path::Simd).to_bits(),
            "dot diverged at n={n}"
        );
    }
}

/// Every quantized kernel variant — single-row `gemv` and the
/// weight-stationary `gemv_multi`, at bits ∈ {2, 3, 4} × odd-word-count
/// cin × {no-sub, sub+col_scale} × every `SubMode` — produces exactly
/// equal outputs on the scalar and vector paths. m straddles the
/// kernel's stack-scratch boundary (16) and stays odd elsewhere.
#[test]
fn quantized_kernels_are_bit_identical_scalar_vs_simd() {
    let mut seed = 0x51d0u64;
    for &bits in &[2u8, 3, 4] {
        for &cin in &[24usize, 40, 104] {
            for &(rank, cs) in &[(0usize, false), (5, true)] {
                seed += 1;
                let ql = mk_layer(7, cin, bits, rank, cs, seed);
                let mut rng = Pcg64::seeded(seed ^ 0xfeed);
                for &m in &[1usize, 3, 16, 17] {
                    let xs = randn(&mut rng, m * cin, 1.0);
                    for mode in [SubMode::None, SubMode::Unfused, SubMode::Fused] {
                        let (ys_scalar, ys_simd) = run_both(|| {
                            let mut ys = vec![0f32; m * ql.out];
                            let mut ws = Workspace::default();
                            let mut t = Traffic::default();
                            ql.gemv_multi(&xs, m, &mut ys, mode, &mut ws, &mut t);
                            ys
                        });
                        assert_eq!(
                            ys_scalar, ys_simd,
                            "bits={bits} cin={cin} rank={rank} cs={cs} m={m} mode={mode:?}"
                        );
                    }
                }
            }
        }
    }
}

/// End-to-end oracle: a full multi-slot greedy decode (prefill + 12
/// batched steps) on a synthesized checkpoint returns bit-identical
/// logits whether the engine runs the scalar or the vector path — the
/// whole stack (attention, lm-head, fused quantized layers, the worker
/// pool) preserves the canonical lane order.
#[test]
fn engine_decode_is_bit_identical_scalar_vs_simd() {
    use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
    use fbquant::testing::{synth_checkpoint, SynthSpec};

    let store = synth_checkpoint(
        "simd_oracle",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    let decode_all = || -> Vec<Vec<f32>> {
        let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
        let mut backend = NativeBackend::new(engine, "simd-oracle").with_max_slots(3);
        let mut state = backend.open_batch(3).unwrap();
        let mut cur = vec![0u32; 3];
        let mut all: Vec<Vec<f32>> = Vec::new();
        for slot in 0..3 {
            let prompt: Vec<u32> =
                (0..6 + slot).map(|i| ((slot * 7 + i * 3) % 50) as u32).collect();
            let lg = backend.prefill_slot(&mut state, slot, &prompt).unwrap();
            cur[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
            all.push(lg);
        }
        for _ in 0..12 {
            let toks: Vec<SlotToken> =
                (0..3).map(|s| SlotToken { slot: s, token: cur[s] }).collect();
            let lg = backend.decode(&mut state, &toks).unwrap();
            for (s, l) in lg.iter().enumerate() {
                cur[s] = fbquant::tensor::ops::argmax(l) as u32;
            }
            all.extend(lg);
        }
        all
    };
    let (scalar, vector) = run_both(decode_all);
    assert_eq!(scalar, vector, "decode logits diverged between scalar and simd paths");
}

/// Under `--features simd` on a capable host the vector path must be
/// the *default* (no forcing), so the rest of this suite — and every
/// other e2e test binary in the feature-matrix CI job — genuinely
/// exercises the vector kernels.
#[cfg(feature = "simd")]
#[test]
fn simd_is_the_default_path_when_available() {
    let _g = PATH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    simd::force_path(None);
    if simd::available() {
        assert_eq!(simd::active(), Path::Simd);
    } else {
        assert_eq!(simd::active(), Path::Scalar);
    }
}
