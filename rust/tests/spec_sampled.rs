//! Distribution-conformance harness for stochastic speculative decoding.
//!
//! The hard invariant: **stochastic-speculative decode is
//! distribution-identical to plain sampled decode**. Rejection-sampling
//! acceptance (accept `d ~ q` with probability `min(1, p(d)/q(d))`,
//! resample rejections from the normalized residual `max(0, p − q)`)
//! provably preserves the target distribution; this harness pins the
//! implementation to the theorem statistically, over seeded trials, for
//! every K × draft-mode × KV-cache combination.
//!
//! Per case: fix a context `[prompt, t0]`, compute the target
//! distribution `p` exactly (plain decode logits through the shared
//! `sampler::distribution` definition), then compare
//! * the empirical distribution of the speculative step's **first
//!   committed token** over ≥10k fresh-slot trials against exact `p`
//!   (total-variation ε gate + merged-cell chi-square gate), and against
//! * the empirical distribution of plain sampled decode over the same
//!   logits (two-sample TV gate).
//!
//! A pair-level case extends the gate to the joint distribution of the
//! first TWO committed tokens (exercising KV rollback and the
//! conditional chain), and coordinator-level cases cover mixed
//! greedy/sampled/degraded traffic with per-mode metric reconciliation.
//!
//! All fixtures are synthesized tiny checkpoints
//! (`fbquant::testing::synth`) — no build artifacts needed — and every
//! RNG is seeded, so the gates are deterministic.

use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken, SpecSlot};
use fbquant::coordinator::request::{GenRequest, SamplingParams};
use fbquant::coordinator::sampler::{distribution, Sampler};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::WeightStore;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::testing::{synth_checkpoint, SynthSpec};

fn argmax(l: &[f32]) -> u32 {
    fbquant::tensor::ops::argmax(l) as u32
}

/// Tiny geometry: 1 layer, d=16, vocab=16 — the conformance loops run
/// hundreds of thousands of engine rows, so every MAC counts. The
/// sizable `sub_scale` makes the bare-branch draft genuinely differ from
/// the target, exercising the rejection + residual paths.
fn conformance_spec() -> SynthSpec {
    SynthSpec {
        d: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 24,
        vocab: 16,
        max_seq: 32,
        group: 8,
        rank: 4,
        sub_scale: 0.4,
        col_scale: false,
    }
}

fn plain_backend(store: &WeightStore, paged: bool) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "plain").with_max_slots(4);
    if !paged {
        b = b.with_dense();
    }
    b
}

fn spec_backend(
    store: &WeightStore,
    paged: bool,
    k: usize,
    draft: DraftMode,
    slots: usize,
) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "spec")
        .with_max_slots(slots)
        .with_speculative(SpeculativeConfig::new(k, draft));
    if !paged {
        b = b.with_dense();
    }
    b
}

// ---------------------------------------------------------------------------
// statistics
// ---------------------------------------------------------------------------

/// Total variation between an empirical count vector and exact probs.
fn tv_vs_exact(counts: &[usize], probs: &[f64], n: usize) -> f64 {
    counts
        .iter()
        .zip(probs)
        .map(|(&c, &p)| (c as f64 / n as f64 - p).abs())
        .sum::<f64>()
        / 2.0
}

/// Total variation between two empirical count vectors.
fn tv_two_sample(a: &[usize], b: &[usize], na: usize, nb: usize) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&ca, &cb)| (ca as f64 / na as f64 - cb as f64 / nb as f64).abs())
        .sum::<f64>()
        / 2.0
}

/// Pearson chi-square goodness-of-fit against exact probs, with cells of
/// expected count < 5 pooled into one bucket (the standard small-cell
/// correction). Returns `(statistic, degrees_of_freedom)`; df can be 0
/// for near-degenerate distributions (caller skips the gate then).
fn chi_square_merged(counts: &[usize], probs: &[f64], n: usize) -> (f64, usize) {
    let mut stat = 0.0;
    let mut cells = 0usize;
    let (mut pooled_obs, mut pooled_exp) = (0.0f64, 0.0f64);
    for (&c, &p) in counts.iter().zip(probs) {
        if p <= 0.0 {
            continue; // support violations are asserted separately
        }
        let e = p * n as f64;
        if e < 5.0 {
            pooled_obs += c as f64;
            pooled_exp += e;
        } else {
            stat += (c as f64 - e) * (c as f64 - e) / e;
            cells += 1;
        }
    }
    if pooled_exp > 0.0 {
        stat += (pooled_obs - pooled_exp) * (pooled_obs - pooled_exp) / pooled_exp;
        cells += 1;
    }
    (stat, cells.saturating_sub(1))
}

/// Upper chi-square critical value via the Wilson–Hilferty cube
/// approximation; `z` is the standard-normal quantile of the target
/// confidence (4.265 ≈ 1 − 1e-5).
fn chi2_crit(df: usize, z: f64) -> f64 {
    let d = df as f64;
    let t = 1.0 - 2.0 / (9.0 * d) + z * (2.0 / (9.0 * d)).sqrt();
    d * t * t * t
}

const CHI2_Z: f64 = 4.265; // alpha ≈ 1e-5 per gate; every RNG is seeded

// ---------------------------------------------------------------------------
// the conformance core
// ---------------------------------------------------------------------------

const TRIALS: usize = 10_000;
const SLOTS_PER_ROUND: usize = 4;

/// One conformance case: first-committed-token distribution of
/// stochastic speculative decode vs plain sampled decode on the fixed
/// context `[prompt, t0]`.
fn conformance_case(
    store: &WeightStore,
    paged: bool,
    k: usize,
    draft: DraftMode,
    params: &SamplingParams,
    seed: u64,
    label: &str,
) {
    let vocab = store.cfg.vocab;
    let prompt: Vec<u32> = (0..3).map(|i| ((i * 7 + 2) % vocab) as u32).collect();

    // exact target distribution after [prompt, t0], from the plain path
    let mut pb = plain_backend(store, paged);
    let mut pstate = pb.open_batch(1).unwrap();
    let l0 = pb.prefill_slot(&mut pstate, 0, &prompt).unwrap();
    let t0 = argmax(&l0);
    let l1 = pb.decode(&mut pstate, &[SlotToken { slot: 0, token: t0 }]).unwrap().remove(0);
    let p_exact = distribution(&l1, params);

    // plain-sampled empirical distribution over the same logits row
    let mut sampler = Sampler::new(seed ^ 0x9e37_79b9);
    let mut plain_counts = vec![0usize; vocab];
    for _ in 0..TRIALS {
        plain_counts[sampler.sample(&l1, params) as usize] += 1;
    }

    // stochastic-speculative empirical distribution: fresh slot per
    // trial, batched SLOTS_PER_ROUND trials per engine round
    let mut sb = spec_backend(store, paged, k, draft, SLOTS_PER_ROUND);
    let mut sstate = sb.open_batch(SLOTS_PER_ROUND).unwrap();
    let mut spec_counts = vec![0usize; vocab];
    let mut done = 0usize;
    while done < TRIALS {
        let n = SLOTS_PER_ROUND.min(TRIALS - done);
        let admissions: Vec<(usize, &[u32])> = (0..n).map(|s| (s, prompt.as_slice())).collect();
        sb.prefill_slots(&mut sstate, &admissions).unwrap();
        let reqs: Vec<SpecSlot> = (0..n)
            .map(|s| SpecSlot { slot: s, token: t0, sampling: params.clone() })
            .collect();
        let steps = sb.decode_speculative(&mut sstate, &reqs).unwrap();
        for sp in &steps {
            assert!(sp.proposed >= 1, "{label}: draft window collapsed without pressure");
            let first = sp.accepted.first().copied().unwrap_or(sp.next);
            spec_counts[first as usize] += 1;
        }
        for s in 0..n {
            sb.release_slot(&mut sstate, s).unwrap();
        }
        done += n;
    }

    // hard support gate: speculation must never emit a token the target
    // distribution excludes (top-k/top-p truncation included)
    for (i, &c) in spec_counts.iter().enumerate() {
        assert!(
            c == 0 || p_exact[i] > 0.0,
            "{label}: token {i} emitted {c} times outside the target support"
        );
    }
    let tve = tv_vs_exact(&spec_counts, &p_exact, TRIALS);
    assert!(tve < 0.06, "{label}: TV(spec, exact target) = {tve:.4} (counts {spec_counts:?})");
    let tv2 = tv_two_sample(&spec_counts, &plain_counts, TRIALS, TRIALS);
    assert!(tv2 < 0.08, "{label}: TV(spec, plain sampled) = {tv2:.4}");
    let (stat, df) = chi_square_merged(&spec_counts, &p_exact, TRIALS);
    if df >= 1 {
        let crit = chi2_crit(df, CHI2_Z);
        assert!(stat < crit, "{label}: chi2 = {stat:.1} > crit {crit:.1} (df {df})");
    }
}

/// The temperature / top-p / top-k points the combos rotate through.
fn param_points() -> [SamplingParams; 3] {
    [
        SamplingParams { temperature: 0.9, ..SamplingParams::default() },
        SamplingParams { temperature: 1.2, top_p: 0.9, ..SamplingParams::default() },
        SamplingParams { temperature: 0.8, top_k: 8, top_p: 0.95, ..SamplingParams::default() },
    ]
}

fn conformance_sweep(tag: &str, draft: DraftMode, paged: bool) {
    // one synth tag per #[test]: tests run in parallel and the synth
    // checkpoint is written to a shared temp path per tag
    let store = synth_checkpoint(tag, conformance_spec());
    let points = param_points();
    for (i, &k) in [1usize, 2, 4].iter().enumerate() {
        let params = &points[i % points.len()];
        conformance_case(
            &store,
            paged,
            k,
            draft,
            params,
            0xc0f0 + i as u64,
            &format!(
                "k={k} draft={draft:?} paged={paged} temp={} top_k={} top_p={}",
                params.temperature, params.top_k, params.top_p
            ),
        );
    }
}

#[test]
fn stochastic_conformance_nosub_paged() {
    conformance_sweep("spec_conf_np", DraftMode::NoSub, true);
}

#[test]
fn stochastic_conformance_nosub_dense() {
    conformance_sweep("spec_conf_nd", DraftMode::NoSub, false);
}

#[test]
fn stochastic_conformance_shadow2_paged() {
    conformance_sweep("spec_conf_sp", DraftMode::Shadow { bits: 2 }, true);
}

#[test]
fn stochastic_conformance_shadow2_dense() {
    conformance_sweep("spec_conf_sd", DraftMode::Shadow { bits: 2 }, false);
}

#[test]
fn stochastic_conformance_temperature_top_p_sweep() {
    // every temperature/top-p point gets its own ≥10k-trial gate on one
    // fixed combo (K=2, bare-branch draft, paged KV)
    let store = synth_checkpoint("spec_conf_sweep", conformance_spec());
    for (i, params) in param_points().iter().enumerate() {
        conformance_case(
            &store,
            true,
            2,
            DraftMode::NoSub,
            params,
            0x5eed + i as u64,
            &format!(
                "sweep temp={} top_k={} top_p={}",
                params.temperature, params.top_k, params.top_p
            ),
        );
    }
}

// ---------------------------------------------------------------------------
// joint (pair) conformance: the first TWO committed tokens
// ---------------------------------------------------------------------------

#[test]
fn stochastic_pair_conformance_follows_the_target_chain() {
    // The marginal gate above cannot see a bug that emits the right
    // first token but corrupts the post-acceptance state (bad KV
    // rollback, draft-mirror drift). The joint distribution of the
    // first two committed tokens can: compute the exact target joint
    // p(x1, x2) = p1(x1) · p2(x2 | x1) from plain decode logits, and
    // gate the speculative pair counts against it.
    let store = synth_checkpoint("spec_conf_pair", conformance_spec());
    let vocab = store.cfg.vocab;
    let params = SamplingParams { temperature: 1.0, ..SamplingParams::default() };
    let prompt: Vec<u32> = (0..3).map(|i| ((i * 7 + 2) % vocab) as u32).collect();

    // exact chain from the plain path: l1 after [prompt, t0]; l2[x1]
    // after [prompt, t0, x1] for every x1 in p1's support
    let (t0, p1, p2s) = {
        let mut pb = plain_backend(&store, true);
        let mut st = pb.open_batch(1).unwrap();
        let l0 = pb.prefill_slot(&mut st, 0, &prompt).unwrap();
        let t0 = argmax(&l0);
        let l1 = pb.decode(&mut st, &[SlotToken { slot: 0, token: t0 }]).unwrap().remove(0);
        let p1 = distribution(&l1, &params);
        let mut p2s: Vec<Option<Vec<f64>>> = vec![None; vocab];
        for x1 in 0..vocab {
            if p1[x1] <= 0.0 {
                continue;
            }
            let mut st = pb.open_batch(1).unwrap();
            pb.prefill_slot(&mut st, 0, &prompt).unwrap();
            pb.decode(&mut st, &[SlotToken { slot: 0, token: t0 }]).unwrap();
            let l2 = pb
                .decode(&mut st, &[SlotToken { slot: 0, token: x1 as u32 }])
                .unwrap()
                .remove(0);
            p2s[x1] = Some(distribution(&l2, &params));
        }
        (t0, p1, p2s)
    };
    let mut p_joint = vec![0f64; vocab * vocab];
    for x1 in 0..vocab {
        if let Some(p2) = &p2s[x1] {
            for x2 in 0..vocab {
                p_joint[x1 * vocab + x2] = p1[x1] * p2[x2];
            }
        }
    }

    // speculative pairs: run spec steps until two tokens committed
    let trials = 10_000usize;
    let mut sb = spec_backend(&store, true, 2, DraftMode::NoSub, SLOTS_PER_ROUND);
    let mut ss = sb.open_batch(SLOTS_PER_ROUND).unwrap();
    let mut pair_counts = vec![0usize; vocab * vocab];
    let mut done = 0usize;
    while done < trials {
        let n = SLOTS_PER_ROUND.min(trials - done);
        let admissions: Vec<(usize, &[u32])> = (0..n).map(|s| (s, prompt.as_slice())).collect();
        sb.prefill_slots(&mut ss, &admissions).unwrap();
        let reqs: Vec<SpecSlot> = (0..n)
            .map(|s| SpecSlot { slot: s, token: t0, sampling: params.clone() })
            .collect();
        let steps = sb.decode_speculative(&mut ss, &reqs).unwrap();
        let mut streams: Vec<Vec<u32>> = steps
            .iter()
            .map(|sp| {
                let mut v = sp.accepted.clone();
                v.push(sp.next);
                v
            })
            .collect();
        // slots whose first step committed a single token need a second
        // step (fed with that step's bonus/correction token)
        let pending: Vec<SpecSlot> = (0..n)
            .filter(|&s| streams[s].len() < 2)
            .map(|s| SpecSlot {
                slot: s,
                token: *streams[s].last().unwrap(),
                sampling: params.clone(),
            })
            .collect();
        if !pending.is_empty() {
            let steps2 = sb.decode_speculative(&mut ss, &pending).unwrap();
            for (req, sp) in pending.iter().zip(&steps2) {
                streams[req.slot].extend_from_slice(&sp.accepted);
                streams[req.slot].push(sp.next);
            }
        }
        for stream in streams.iter().take(n) {
            assert!(stream.len() >= 2, "a speculative step commits at least one token");
            pair_counts[stream[0] as usize * vocab + stream[1] as usize] += 1;
        }
        for s in 0..n {
            sb.release_slot(&mut ss, s).unwrap();
        }
        done += n;
    }

    for (cell, &c) in pair_counts.iter().enumerate() {
        assert!(
            c == 0 || p_joint[cell] > 0.0,
            "pair ({}, {}) emitted outside the target joint support",
            cell / vocab,
            cell % vocab
        );
    }
    let tvj = tv_vs_exact(&pair_counts, &p_joint, trials);
    assert!(tvj < 0.12, "TV(spec pairs, exact joint) = {tvj:.4}");
    let (stat, df) = chi_square_merged(&pair_counts, &p_joint, trials);
    if df >= 1 {
        let crit = chi2_crit(df, CHI2_Z);
        assert!(stat < crit, "pair chi2 = {stat:.1} > crit {crit:.1} (df {df})");
    }
}

// ---------------------------------------------------------------------------
// coordinator-level mixed traffic + degrade
// ---------------------------------------------------------------------------

#[test]
fn mixed_traffic_per_mode_metrics_reconcile_with_emitted_tokens() {
    // greedy + sampled requests with uneven prompt/generation lengths
    // over a 3-slot pool: admissions and releases interleave randomly
    // (seeded), both acceptance modes share verify passes, and the
    // per-mode ServeMetrics must reconcile with what the streams
    // actually carried.
    let store = synth_checkpoint("spec_sampled_mixed", conformance_spec());
    let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
    let mut sb = NativeBackend::new(engine, "mixed")
        .with_max_slots(3)
        .with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub));
    let n = 12usize;
    let reqs: Vec<GenRequest> = (0..n as u64)
        .map(|i| {
            let plen = 2 + (i as usize * 5) % 4;
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i as usize * 13 + j * 7) % 16) as u32).collect();
            let mut r = GenRequest::new(i + 1, prompt, 1 + (i as usize * 7) % 9);
            if i % 3 != 0 {
                r.params = SamplingParams {
                    temperature: 0.8 + 0.1 * (i % 3) as f32,
                    top_k: if i % 2 == 0 { 8 } else { 0 },
                    ..SamplingParams::default()
                };
            }
            r
        })
        .collect();
    let budgets: Vec<usize> = reqs.iter().map(|r| r.max_new_tokens).collect();
    let (rs, ms) =
        Coordinator::run_closed_loop(&mut sb, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(rs.len(), n);
    for (r, &budget) in rs.iter().zip(&budgets) {
        assert_eq!(r.tokens.len(), budget, "request {} lost tokens", r.id);
    }
    // stream-level reconciliation
    let emitted: usize = rs.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(ms.tokens_generated, emitted, "metrics disagree with streams");
    // per-mode sums equal the legacy totals
    assert_eq!(ms.spec_steps, ms.spec_greedy.steps + ms.spec_sampled.steps);
    assert_eq!(ms.spec_proposed, ms.spec_greedy.proposed + ms.spec_sampled.proposed);
    assert_eq!(ms.spec_accepted, ms.spec_greedy.accepted + ms.spec_sampled.accepted);
    assert!(ms.spec_greedy.steps > 0, "greedy slots never speculated");
    assert!(ms.spec_sampled.steps > 0, "sampled slots never speculated");
    // every emitted token is either a scheduling-step commit or an
    // accepted-draft commit; each spec step implies one same-step commit
    // and each finished request at most one commit-only step
    let committed = ms.spec_greedy.committed + ms.spec_sampled.committed;
    assert!(committed <= ms.spec_accepted, "committed counts exceed acceptance");
    let step_commits = ms.tokens_generated - committed;
    assert!(
        step_commits >= ms.spec_steps,
        "fewer step commits ({step_commits}) than spec steps ({})",
        ms.spec_steps
    );
    assert!(
        step_commits <= ms.spec_steps + ms.requests_done,
        "step commits ({step_commits}) exceed spec steps + finishes"
    );
}

#[test]
fn shared_pool_pressure_degrades_one_slot_without_perturbing_neighbors() {
    // Draft mirrors alias the target's pages in the ONE shared pool and
    // only allocate for the window they append (a copy-on-write of the
    // boundary page). Size that pool so both targets fit (one page each,
    // all lens stay under one 16-position page) with exactly ONE spare
    // page: each step the first slot's draft grabs the spare for its
    // boundary CoW and speculates normally, the second slot's window
    // reservation fails and it degrades to k = 0 — it must still decode
    // correctly (greedy identity with the plain backend) and the
    // speculating neighbor must be unaffected. End-of-step rollback
    // returns the spare, so the pattern repeats deterministically.
    let store = synth_checkpoint(
        "spec_sampled_pressure",
        SynthSpec { rank: 4, ..SynthSpec::default() },
    );
    let k = 2usize;
    let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
    let mut sb = NativeBackend::new(engine, "pressure")
        .with_max_slots(2)
        .with_speculative(SpeculativeConfig::new(k, DraftMode::NoSub))
        .with_kv_pool(16, 3);
    let mut ss = sb.open_batch(2).unwrap();
    let mut pb = plain_backend(&store, true);
    let mut ps = pb.open_batch(2).unwrap();
    let mut cur = vec![0u32; 2];
    let mut last = vec![0u32; 2];
    for slot in 0..2 {
        let prompt: Vec<u32> = (0..4).map(|i| ((slot * 9 + i * 5) % 50) as u32).collect();
        let ls = sb.prefill_slot(&mut ss, slot, &prompt).unwrap();
        let lp = pb.prefill_slot(&mut ps, slot, &prompt).unwrap();
        assert_eq!(ls, lp);
        cur[slot] = argmax(&ls);
        last[slot] = argmax(&lp);
    }
    let mut stream_s: Vec<Vec<u32>> = vec![Vec::new(); 2];
    let mut stream_p: Vec<Vec<u32>> = vec![Vec::new(); 2];
    for _ in 0..3 {
        let reqs: Vec<SpecSlot> = (0..2).map(|s| SpecSlot::greedy(s, cur[s])).collect();
        let steps = sb.decode_speculative(&mut ss, &reqs).unwrap();
        assert_eq!(steps[0].proposed, k, "slot 0 lost its draft window");
        assert_eq!(
            steps[1].proposed, 0,
            "slot 1 should degrade to k = 0 under draft-pool pressure"
        );
        for (slot, sp) in steps.iter().enumerate() {
            stream_s[slot].extend_from_slice(&sp.accepted);
            stream_s[slot].push(sp.next);
            cur[slot] = sp.next;
            for _ in 0..sp.accepted.len() + 1 {
                let lg = pb
                    .decode(&mut ps, &[SlotToken { slot, token: last[slot] }])
                    .unwrap();
                let t = argmax(&lg[0]);
                stream_p[slot].push(t);
                last[slot] = t;
            }
        }
    }
    for slot in 0..2 {
        assert_eq!(
            stream_p[slot], stream_s[slot],
            "slot {slot} diverged from plain greedy under shared-pool pressure"
        );
    }
    // one pool, one ledger: the draft-side events (aliases, the failed
    // window reservations) land in the target pool's stats
    let stats = sb.kv_stats(&ss).expect("paged backend exposes pool stats");
    assert!(stats.alloc_failures > 0, "pressure never hit the shared pool");
    assert!(stats.pages_aliased > 0, "draft mirrors never aliased the target");
    assert!(stats.peak_pages_in_use <= 3, "pool exceeded its budget");
}
