//! Property-based tests of coordinator invariants: batching, routing,
//! slot-pool generation-state management and event streaming.

use fbquant::coordinator::backend::{
    validate_batch, Backend, BatchState, SlotToken,
};
use fbquant::coordinator::batcher::{Batcher, BatcherConfig};
use fbquant::coordinator::request::{GenEvent, GenRequest};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::KvCache;
use fbquant::model::Config;
use fbquant::prop_assert_ok;
use fbquant::testing::check;
use fbquant::util::json::Json;
use std::time::{Duration, Instant};

fn tiny_cfg(vocab: usize, max_seq: usize) -> Config {
    Config::from_json(
        &Json::parse(&format!(
            r#"{{"name":"fake","family":"llamoid","d_model":8,"n_layers":1,
                 "n_heads":2,"d_ff":8,"vocab":{vocab},"max_seq":{max_seq}}}"#
        ))
        .unwrap(),
    )
    .unwrap()
}

/// Deterministic fake backend over the slot-pool API: next token =
/// (last + 1) mod vocab. Occupancy is tracked through real (tiny)
/// `KvCache` slots so release/admit bookkeeping is exercised.
struct CountingBackend {
    cfg: Config,
    prefills: usize,
    decodes: usize,
}

impl CountingBackend {
    fn new(vocab: usize, max_seq: usize) -> Self {
        CountingBackend { cfg: tiny_cfg(vocab, max_seq), prefills: 0, decodes: 0 }
    }

    fn logits_for(&self, last: u32) -> Vec<f32> {
        let mut l = vec![0f32; self.cfg.vocab];
        l[(last as usize + 1) % self.cfg.vocab] = 9.0;
        l
    }
}

impl Backend for CountingBackend {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn continuous(&self) -> bool {
        true
    }

    fn open_batch(&mut self, capacity: usize) -> anyhow::Result<BatchState> {
        Ok(BatchState::Native { slots: (0..capacity).map(|_| None).collect() })
    }

    fn prefill_slot(&mut self, state: &mut BatchState, slot: usize, prompt: &[u32])
        -> anyhow::Result<Vec<f32>> {
        let BatchState::Native { slots } = state else {
            anyhow::bail!("foreign state");
        };
        if slots[slot].is_some() {
            anyhow::bail!("slot {slot} already occupied");
        }
        slots[slot] = Some(KvCache::new(1, 4, 1, 1));
        self.prefills += 1;
        Ok(self.logits_for(*prompt.last().unwrap()))
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[SlotToken])
        -> anyhow::Result<Vec<Vec<f32>>> {
        let BatchState::Native { slots } = state else {
            anyhow::bail!("foreign state");
        };
        self.decodes += 1;
        let mut out = Vec::with_capacity(tokens.len());
        for st in tokens {
            if slots[st.slot].is_none() {
                anyhow::bail!("decode on free slot {}", st.slot);
            }
            out.push(self.logits_for(st.token));
        }
        Ok(out)
    }

    fn release_slot(&mut self, state: &mut BatchState, slot: usize) -> anyhow::Result<()> {
        let BatchState::Native { slots } = state else {
            anyhow::bail!("foreign state");
        };
        if slots[slot].is_none() {
            anyhow::bail!("double release of slot {slot}");
        }
        slots[slot] = None;
        Ok(())
    }

    fn name(&self) -> String {
        "counting".into()
    }
}

#[test]
fn prop_batcher_conserves_and_aligns_requests() {
    prop_assert_ok!(check("batcher_conserve", 100, |g| {
        let n = g.usize_range(1, 24);
        let max_queue = 64;
        let mut batcher = Batcher::new(BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(0),
            max_queue,
            ..BatcherConfig::default()
        });
        let mut ids = Vec::new();
        for i in 0..n {
            let plen = *g.pick(&[8usize, 16, 32]);
            let req = GenRequest::new(i as u64 + 1, vec![1; plen], 4);
            ids.push(req.id);
            if !batcher.submit(req).admitted() {
                return Err("queue rejected under capacity".into());
            }
        }
        let mut seen = Vec::new();
        let deadline = Instant::now() + Duration::from_millis(10);
        while !batcher.is_empty() {
            let Some(batch) = batcher.next_batch(deadline) else {
                return Err("batcher stalled with non-empty queue".into());
            };
            if batch.requests.is_empty() || batch.requests.len() > 4 {
                return Err(format!("bad batch size {}", batch.requests.len()));
            }
            if batch.capacity < batch.requests.len() {
                return Err("capacity below occupancy".into());
            }
            let plen = batch.requests[0].prompt.len();
            if batch.requests.iter().any(|r| r.prompt.len() != plen) {
                return Err("batch not prompt-length aligned".into());
            }
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        let mut want = ids.clone();
        want.sort_unstable();
        if seen != want {
            return Err("requests lost or duplicated by batching".into());
        }
        Ok(())
    }));
}

#[test]
fn prop_closed_loop_serves_every_request_exactly_once() {
    prop_assert_ok!(check("closed_loop", 30, |g| {
        let n = g.usize_range(1, 10);
        let vocab = 16usize;
        let mut backend = CountingBackend::new(vocab, 256);
        let mut requests = Vec::new();
        for i in 0..n {
            let plen = *g.pick(&[4usize, 8]);
            let gen = g.usize_range(1, 6);
            let prompt = g.vec_u32(plen, vocab);
            requests.push(GenRequest::new(i as u64 + 1, prompt, gen));
        }
        let expected: Vec<(u64, usize, u32)> = requests
            .iter()
            .map(|r| (r.id, r.max_new_tokens, *r.prompt.last().unwrap()))
            .collect();
        let (responses, metrics) =
            Coordinator::run_closed_loop(&mut backend, requests, &CoordinatorConfig::default())
                .map_err(|e| e.to_string())?;
        if responses.len() != n {
            return Err(format!("{} responses for {n} requests", responses.len()));
        }
        if metrics.requests_done != n {
            return Err("metrics lost requests".into());
        }
        if metrics.admissions != n {
            return Err("admission accounting broken".into());
        }
        for (r, (id, want_len, last)) in responses.iter().zip(expected) {
            if r.id != id {
                return Err("response order broken".into());
            }
            if r.tokens.len() != want_len {
                return Err(format!("id {id}: {} tokens, wanted {want_len}", r.tokens.len()));
            }
            // the counting backend generates last+1, last+2, ...
            for (k, &t) in r.tokens.iter().enumerate() {
                if t != ((last as usize + k + 1) % vocab) as u32 {
                    return Err("generation sequence corrupted by batching".into());
                }
            }
        }
        Ok(())
    }));
}

#[test]
fn prop_stop_token_halts_generation() {
    prop_assert_ok!(check("stop_token", 30, |g| {
        let vocab = 8usize;
        let mut backend = CountingBackend::new(vocab, 256);
        let start = g.rng.below(vocab) as u32;
        let stop = ((start as usize + 3) % vocab) as u32; // reached after 3 tokens
        let mut req = GenRequest::new(1, vec![start], 20);
        req.stop_token = Some(stop);
        let (responses, _) =
            Coordinator::run_closed_loop(&mut backend, vec![req], &CoordinatorConfig::default())
                .map_err(|e| e.to_string())?;
        let toks = &responses[0].tokens;
        if toks.len() != 3 {
            return Err(format!("expected 3 tokens up to stop, got {}", toks.len()));
        }
        if *toks.last().unwrap() != stop {
            return Err("did not stop on stop token".into());
        }
        Ok(())
    }));
}

#[test]
fn validate_batch_rejects_overlong_requests() {
    let backend = CountingBackend::new(16, 32);
    let ok = GenRequest::new(1, vec![1; 16], 8);
    let too_long = GenRequest::new(2, vec![1; 30], 8);
    assert!(validate_batch(&backend, std::slice::from_ref(&ok)).is_ok());
    assert!(validate_batch(&backend, &[too_long]).is_err());
}

#[test]
fn validate_batch_rejects_oversized_batches() {
    // max_batch = 4: a 5-request batch must be rejected, not silently
    // mis-executed
    let backend = CountingBackend::new(16, 256);
    let reqs: Vec<GenRequest> =
        (0..5).map(|i| GenRequest::new(i as u64 + 1, vec![1; 8], 4)).collect();
    let err = validate_batch(&backend, &reqs).unwrap_err().to_string();
    assert!(err.contains("max batch"), "unexpected error: {err}");
    assert!(validate_batch(&backend, &reqs[..4]).is_ok());
}

#[test]
fn validate_batch_rejects_misaligned_prompts() {
    let backend = CountingBackend::new(16, 256);
    let reqs = vec![
        GenRequest::new(1, vec![1; 8], 4),
        GenRequest::new(2, vec![1; 16], 4),
    ];
    assert!(validate_batch(&backend, &reqs).is_err());
}

/// Continuous admission must not starve: a stream of short prompts ahead
/// of one long prompt is served in arrival order.
#[test]
fn continuous_admission_is_arrival_ordered() {
    let mut backend = CountingBackend::new(16, 256);
    let mut requests: Vec<GenRequest> =
        (0..8).map(|i| GenRequest::new(i as u64 + 1, vec![1; 16], 4)).collect();
    requests.insert(4, GenRequest::new(99, vec![1; 32], 4));
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut backend, requests, &CoordinatorConfig::default())
            .unwrap();
    assert_eq!(responses.len(), 9);
    assert!(responses.iter().any(|r| r.id == 99), "length-32 request starved");
    assert_eq!(metrics.requests_done, 9);
}

/// The acceptance property of continuous batching: on a mixed workload
/// with uneven finish times, the slot pool stays strictly fuller than
/// lock-step aligned groups do — with identical results.
#[test]
fn continuous_occupancy_beats_batch_sync() {
    let run = |continuous: bool| {
        let mut backend = CountingBackend::new(16, 256);
        // four distinct prompt lengths, two requests each: the aligned
        // batcher can only form half-empty groups, while the continuous
        // pool packs all lengths together and stays full
        let requests: Vec<GenRequest> = (0..8u64)
            .map(|i| GenRequest::new(i + 1, vec![1; 8 + 4 * (i as usize % 4)], 8))
            .collect();
        let cfg = CoordinatorConfig { continuous, ..CoordinatorConfig::default() };
        Coordinator::run_closed_loop(&mut backend, requests, &cfg).unwrap()
    };
    let (cont_r, cont_m) = run(true);
    let (sync_r, sync_m) = run(false);
    assert_eq!(cont_r.len(), 8);
    assert_eq!(sync_r.len(), 8);
    // same deterministic outputs under both disciplines
    for (a, b) in cont_r.iter().zip(&sync_r) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "scheduling changed greedy output");
    }
    assert_eq!(cont_m.tokens_generated, sync_m.tokens_generated);
    assert!(
        cont_m.mean_slot_occupancy() > sync_m.mean_slot_occupancy(),
        "continuous occupancy {:.3} not above batch-sync {:.3}",
        cont_m.mean_slot_occupancy(),
        sync_m.mean_slot_occupancy()
    );
    // continuous: everything flows through one long-lived pool
    assert_eq!(cont_m.pools_opened, 1);
    assert_eq!(cont_m.admissions, 8);
    assert_eq!(cont_m.batches_formed, 0);
    // lock-step: multiple aligned groups instead
    assert!(sync_m.batches_formed >= 2);
    assert!(
        cont_m.decode_steps < sync_m.decode_steps,
        "continuous should need fewer batched steps ({} vs {})",
        cont_m.decode_steps,
        sync_m.decode_steps
    );
}

/// Streaming integration: tokens arrive incrementally (TTFT event before
/// `Done`), and a single long-lived pool absorbs more admissions than it
/// has slots.
#[test]
fn spawned_coordinator_streams_tokens_incrementally() {
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(CountingBackend::new(16, 256)))
        },
        CoordinatorConfig::default(),
    );
    let rxs: Vec<_> = (0..6)
        .map(|_| handle.submit(GenRequest::new(0, vec![3, 4, 5], 5)))
        .collect();
    for rx in rxs {
        let mut streamed: Vec<u32> = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(30)) {
            match ev {
                GenEvent::Token { index, token, .. } => {
                    // incremental: each token event arrives before the
                    // request's terminal event, in order
                    assert_eq!(index, streamed.len(), "out-of-order token event");
                    assert!(done.is_none(), "token after Done");
                    streamed.push(token);
                }
                GenEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
                GenEvent::Error { message, .. } => panic!("unexpected error: {message}"),
            }
        }
        let r = done.expect("stream ended without Done");
        assert_eq!(r.tokens.len(), 5);
        assert_eq!(r.tokens, streamed, "streamed tokens disagree with final response");
        // counting backend: 6, 7, 8, ... after prompt [3, 4, 5]
        assert_eq!(r.tokens, vec![6, 7, 8, 9, 10]);
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 6);
    // >1 admission into a single long-lived batch: 6 requests through a
    // 4-slot pool opened exactly once (how many overlapped in time is
    // scheduling-dependent; the closed-loop occupancy test pins that)
    assert_eq!(metrics.pools_opened, 1);
    assert_eq!(metrics.admissions, 6);
}

/// Shed requests must receive a terminal event instead of leaking their
/// sink (the caller would otherwise block forever).
#[test]
fn overloaded_queue_sheds_with_terminal_error_event() {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(5),
            max_queue: 2,
            ..BatcherConfig::default()
        },
        ..CoordinatorConfig::default()
    };
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(CountingBackend::new(16, 256)))
        },
        cfg,
    );
    // flood: pool (4) + queue (2) can hold 6; the rest must shed
    let rxs: Vec<_> = (0..32)
        .map(|_| handle.submit(GenRequest::new(0, vec![1; 8], 6)))
        .collect();
    let mut done = 0usize;
    let mut shed = 0usize;
    for rx in rxs {
        let mut terminal = false;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(30)) {
            match ev {
                GenEvent::Done(_) => {
                    done += 1;
                    terminal = true;
                    break;
                }
                GenEvent::Error { .. } => {
                    shed += 1;
                    terminal = true;
                    break;
                }
                GenEvent::Token { .. } => {}
            }
        }
        assert!(terminal, "a request got neither Done nor Error");
    }
    assert_eq!(done + shed, 32);
    // how many squeeze through before the queue fills is timing-dependent;
    // what matters is that nothing hangs and the books balance
    assert!(done >= 1, "nothing was served under overload");
    assert!(shed >= 1, "queue of 2 absorbed 32 requests");
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, done);
    assert_eq!(metrics.requests_shed, shed);
}

/// Invalid requests are rejected with a terminal error, not executed.
#[test]
fn invalid_requests_get_terminal_error() {
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(CountingBackend::new(16, 32)))
        },
        CoordinatorConfig::default(),
    );
    // prompt + gen exceeds max_seq 32
    let rx = handle.submit(GenRequest::new(0, vec![1; 30], 8));
    match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
        GenEvent::Error { message, .. } => {
            assert!(message.contains("max_seq"), "unexpected message: {message}");
        }
        other => panic!("expected Error, got {other:?}"),
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 0);
    assert_eq!(metrics.requests_shed, 1);
}
