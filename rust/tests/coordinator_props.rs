//! Property-based tests of coordinator invariants: batching, routing,
//! and generation-state management.

use fbquant::coordinator::backend::{Backend, BatchState};
use fbquant::coordinator::batcher::{Batcher, BatcherConfig};
use fbquant::coordinator::request::GenRequest;
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::model::Config;
use fbquant::prop_assert_ok;
use fbquant::testing::check;
use fbquant::util::json::Json;
use std::time::{Duration, Instant};

fn tiny_cfg(vocab: usize, max_seq: usize) -> Config {
    Config::from_json(
        &Json::parse(&format!(
            r#"{{"name":"fake","family":"llamoid","d_model":8,"n_layers":1,
                 "n_heads":2,"d_ff":8,"vocab":{vocab},"max_seq":{max_seq}}}"#
        ))
        .unwrap(),
    )
    .unwrap()
}

/// Deterministic fake backend: next token = (last + 1) mod vocab.
struct CountingBackend {
    cfg: Config,
    prefills: usize,
    decodes: usize,
}

impl CountingBackend {
    fn new(vocab: usize, max_seq: usize) -> Self {
        CountingBackend { cfg: tiny_cfg(vocab, max_seq), prefills: 0, decodes: 0 }
    }

    fn logits_for(&self, last: u32) -> Vec<f32> {
        let mut l = vec![0f32; self.cfg.vocab];
        l[(last as usize + 1) % self.cfg.vocab] = 9.0;
        l
    }
}

impl Backend for CountingBackend {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn prefill(&mut self, prompts: &[&[u32]], _capacity: usize) -> anyhow::Result<(BatchState, Vec<Vec<f32>>)> {
        self.prefills += 1;
        let pos = prompts[0].len();
        let logits = prompts.iter().map(|p| self.logits_for(*p.last().unwrap())).collect();
        Ok((BatchState::Native { kvs: Vec::new(), pos }, logits))
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[u32]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.decodes += 1;
        if let BatchState::Native { pos, .. } = state {
            *pos += 1;
        }
        Ok(tokens.iter().map(|&t| self.logits_for(t)).collect())
    }

    fn name(&self) -> String {
        "counting".into()
    }
}

#[test]
fn prop_batcher_conserves_and_aligns_requests() {
    prop_assert_ok!(check("batcher_conserve", 100, |g| {
        let n = g.usize_range(1, 24);
        let max_queue = 64;
        let mut batcher = Batcher::new(BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(0),
            max_queue,
        });
        let mut ids = Vec::new();
        for i in 0..n {
            let plen = *g.pick(&[8usize, 16, 32]);
            let req = GenRequest::new(i as u64 + 1, vec![1; plen], 4);
            ids.push(req.id);
            if !batcher.submit(req) {
                return Err("queue rejected under capacity".into());
            }
        }
        let mut seen = Vec::new();
        let deadline = Instant::now() + Duration::from_millis(10);
        while !batcher.is_empty() {
            let Some(batch) = batcher.next_batch(deadline) else {
                return Err("batcher stalled with non-empty queue".into());
            };
            if batch.requests.is_empty() || batch.requests.len() > 4 {
                return Err(format!("bad batch size {}", batch.requests.len()));
            }
            if batch.capacity < batch.requests.len() {
                return Err("capacity below occupancy".into());
            }
            let plen = batch.requests[0].prompt.len();
            if batch.requests.iter().any(|r| r.prompt.len() != plen) {
                return Err("batch not prompt-length aligned".into());
            }
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort_unstable();
        let mut want = ids.clone();
        want.sort_unstable();
        if seen != want {
            return Err("requests lost or duplicated by batching".into());
        }
        Ok(())
    }));
}

#[test]
fn prop_closed_loop_serves_every_request_exactly_once() {
    prop_assert_ok!(check("closed_loop", 30, |g| {
        let n = g.usize_range(1, 10);
        let vocab = 16usize;
        let mut backend = CountingBackend::new(vocab, 256);
        let mut requests = Vec::new();
        for i in 0..n {
            let plen = *g.pick(&[4usize, 8]);
            let gen = g.usize_range(1, 6);
            let prompt = g.vec_u32(plen, vocab);
            requests.push(GenRequest::new(i as u64 + 1, prompt, gen));
        }
        let expected: Vec<(u64, usize, u32)> = requests
            .iter()
            .map(|r| (r.id, r.max_new_tokens, *r.prompt.last().unwrap()))
            .collect();
        let (responses, metrics) =
            Coordinator::run_closed_loop(&mut backend, requests, &CoordinatorConfig::default())
                .map_err(|e| e.to_string())?;
        if responses.len() != n {
            return Err(format!("{} responses for {n} requests", responses.len()));
        }
        if metrics.requests_done != n {
            return Err("metrics lost requests".into());
        }
        for (r, (id, want_len, last)) in responses.iter().zip(expected) {
            if r.id != id {
                return Err("response order broken".into());
            }
            if r.tokens.len() != want_len {
                return Err(format!("id {id}: {} tokens, wanted {want_len}", r.tokens.len()));
            }
            // the counting backend generates last+1, last+2, ...
            for (k, &t) in r.tokens.iter().enumerate() {
                if t != ((last as usize + k + 1) % vocab) as u32 {
                    return Err("generation sequence corrupted by batching".into());
                }
            }
        }
        Ok(())
    }));
}

#[test]
fn prop_stop_token_halts_generation() {
    prop_assert_ok!(check("stop_token", 30, |g| {
        let vocab = 8usize;
        let mut backend = CountingBackend::new(vocab, 256);
        let start = g.rng.below(vocab) as u32;
        let stop = ((start as usize + 3) % vocab) as u32; // reached after 3 tokens
        let mut req = GenRequest::new(1, vec![start], 20);
        req.stop_token = Some(stop);
        let (responses, _) =
            Coordinator::run_closed_loop(&mut backend, vec![req], &CoordinatorConfig::default())
                .map_err(|e| e.to_string())?;
        let toks = &responses[0].tokens;
        if toks.len() != 3 {
            return Err(format!("expected 3 tokens up to stop, got {}", toks.len()));
        }
        if *toks.last().unwrap() != stop {
            return Err("did not stop on stop token".into());
        }
        Ok(())
    }));
}

#[test]
fn validate_batch_rejects_overlong_requests() {
    let cfg = tiny_cfg(16, 32);
    let ok = GenRequest::new(1, vec![1; 16], 8);
    let too_long = GenRequest::new(2, vec![1; 30], 8);
    assert!(fbquant::coordinator::backend::validate_batch(&cfg, &[ok]).is_ok());
    assert!(fbquant::coordinator::backend::validate_batch(&cfg, &[too_long]).is_err());
}
