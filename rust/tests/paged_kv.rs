//! Paged KV pool tests: bit-identity of the paged gathers against the
//! dense `KvCache` over random prompt/decode interleavings, plus page
//! refcounting, prefix adoption, copy-on-write divergence, exhaustion
//! shedding and cache eviction. The end-to-end generation equivalence
//! and admission-shed tests run when checkpoint artifacts are present.

use fbquant::coordinator::backend::NativeBackend;
use fbquant::coordinator::request::GenRequest;
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::kv::{KvCache, KvPagePool, KvPoolConfig, KvSlot, PagedKv, PagedKvRef};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::{ByteTokenizer, WeightStore};
use fbquant::prop_assert_ok;
use fbquant::testing::check;

/// Deterministic KV value so recomputation and shared pages must agree.
fn val(tok: u32, pos: usize, l: usize, i: usize, sign: f32) -> f32 {
    sign * (tok as f32 + 0.25 * pos as f32 + 10.0 * l as f32 + 0.01 * i as f32)
}

/// Write positions `from..tokens.len()` through the `KvSlot` interface.
fn fill(slot: &mut dyn KvSlot, tokens: &[u32], from: usize, n_layers: usize, stride: usize) {
    for pos in from..tokens.len() {
        for l in 0..n_layers {
            let kt: Vec<f32> = (0..stride).map(|i| val(tokens[pos], pos, l, i, 1.0)).collect();
            let vt: Vec<f32> = (0..stride).map(|i| val(tokens[pos], pos, l, i, -1.0)).collect();
            slot.write(l, pos, &kt, &vt);
        }
        slot.advance(1);
    }
}

#[test]
fn prop_paged_gathers_match_dense_over_random_interleavings() {
    prop_assert_ok!(check("paged_dense_equiv", 50, |g| {
        let n_layers = g.usize_range(1, 2);
        let n_heads = g.usize_range(1, 3);
        let head_dim = *g.pick(&[2usize, 4]);
        let page_size = *g.pick(&[1usize, 2, 3, 4, 8]);
        let max_seq = 24usize;
        let stride = n_heads * head_dim;
        let mut dense = KvCache::new(n_layers, max_seq, n_heads, head_dim);
        let mut pool =
            KvPagePool::new(KvPoolConfig::new(n_layers, n_heads, head_dim, page_size, 64));
        let mut kv = pool.new_kv(max_seq);
        let total = g.usize_range(1, max_seq);
        let mut pos = 0usize;
        while pos < total {
            // a prompt chunk or a single decode append
            let chunk = g.usize_range(1, (total - pos).min(5));
            pool.ensure_range(&mut kv, pos, pos + chunk).map_err(|e| e.to_string())?;
            for p in pos..pos + chunk {
                for l in 0..n_layers {
                    let kt = g.vec_f32(stride, 1.0);
                    let vt = g.vec_f32(stride, 1.0);
                    dense.write(l, p, &kt, &vt);
                    let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv };
                    bound.write(l, p, &kt, &vt);
                }
            }
            dense.advance(chunk);
            {
                let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv };
                bound.advance(chunk);
            }
            pos += chunk;
            // the attention gathers over the whole history must be
            // bit-identical after every interleaving step
            let q = g.vec_f32(head_dim, 1.0);
            let weights = g.vec_f32(pos, 1.0);
            let bound = PagedKvRef { pool: &mut pool, kv: &mut kv };
            if dense.len != bound.len() {
                return Err(format!("len diverged: {} vs {}", dense.len, bound.len()));
            }
            for l in 0..n_layers {
                for h in 0..n_heads {
                    let mut sd = vec![0f32; pos];
                    let mut sp = vec![0f32; pos];
                    dense.score_keys(l, h, &q, 0.25, &mut sd);
                    bound.score_keys(l, h, &q, 0.25, &mut sp);
                    if sd != sp {
                        return Err(format!("scores diverge at l{l} h{h} len {pos}"));
                    }
                    let mut od = vec![0f32; head_dim];
                    let mut op = vec![0f32; head_dim];
                    dense.accumulate_values(l, h, &weights, &mut od);
                    bound.accumulate_values(l, h, &weights, &mut op);
                    if od != op {
                        return Err(format!("values diverge at l{l} h{h} len {pos}"));
                    }
                    for j in 0..pos {
                        if dense.k_at(l, j, h) != bound.k_at(l, j, h)
                            || dense.v_at(l, j, h) != bound.v_at(l, j, h)
                        {
                            return Err(format!("raw kv diverged at l{l} p{j} h{h}"));
                        }
                    }
                }
            }
        }
        Ok(())
    }));
}

#[test]
fn adopted_prefix_reads_identical_to_recomputed_dense() {
    let (n_layers, n_heads, head_dim, ps) = (2usize, 2usize, 3usize, 4usize);
    let stride = n_heads * head_dim;
    let max_seq = 32usize;
    let mut pool = KvPagePool::new(KvPoolConfig::new(n_layers, n_heads, head_dim, ps, 32));

    // first admission writes and publishes a 12-token (3-page) prompt
    let prompt_a: Vec<u32> = (0..12).map(|i| 100 + i as u32).collect();
    let mut kv1 = pool.new_kv(max_seq);
    pool.ensure_range(&mut kv1, 0, prompt_a.len()).unwrap();
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        fill(&mut bound, &prompt_a, 0, n_layers, stride);
    }
    pool.register_prefix(&kv1, &prompt_a);

    // second admission shares the first 8 tokens (2 pages) then diverges
    let mut prompt_b = prompt_a[..8].to_vec();
    prompt_b.extend([7u32, 8, 9, 10, 11, 12]);
    let mut kv2 = pool.new_kv(max_seq);
    let reused = pool.adopt_prefix(&mut kv2, &prompt_b);
    assert_eq!(reused, 8, "two full pages should be adopted");
    pool.ensure_range(&mut kv2, reused, prompt_b.len()).unwrap();
    pool.record_reuse(reused);
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv2 };
        fill(&mut bound, &prompt_b, reused, n_layers, stride);
    }

    // a dense cache recomputing prompt_b from scratch must agree bit for
    // bit with the view that reused shared pages
    let mut dense = KvCache::new(n_layers, max_seq, n_heads, head_dim);
    fill(&mut dense, &prompt_b, 0, n_layers, stride);
    let bound = PagedKvRef { pool: &mut pool, kv: &mut kv2 };
    assert_eq!(bound.len(), prompt_b.len());
    for l in 0..n_layers {
        for h in 0..n_heads {
            for pos in 0..prompt_b.len() {
                assert_eq!(dense.k_at(l, pos, h), bound.k_at(l, pos, h), "k l{l} p{pos} h{h}");
                assert_eq!(dense.v_at(l, pos, h), bound.v_at(l, pos, h), "v l{l} p{pos} h{h}");
            }
        }
    }
    let stats = pool.stats();
    assert_eq!(stats.prefix_hits, 1);
    assert_eq!(stats.prefix_tokens_reused, 8);
}

#[test]
fn refcounts_track_sharing_and_release() {
    let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
    let prompt: Vec<u32> = (0..8).collect();
    let mut kv1 = pool.new_kv(16);
    pool.ensure_range(&mut kv1, 0, 8).unwrap();
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        bound.advance(8);
    }
    pool.register_prefix(&kv1, &prompt);
    let pages: Vec<u32> = kv1.page_ids().to_vec();
    assert_eq!(pages.len(), 2);
    // page 0 is shared by the slot and the k=1 and k=2 cache entries;
    // page 1 by the slot and the k=2 entry
    assert_eq!(pool.page_refcount(pages[0]), 3);
    assert_eq!(pool.page_refcount(pages[1]), 2);

    let longer: Vec<u32> = (0..9).collect();
    let mut kv2 = pool.new_kv(16);
    let reused = pool.adopt_prefix(&mut kv2, &longer);
    assert_eq!(reused, 8);
    assert_eq!(pool.page_refcount(pages[0]), 4);
    assert_eq!(pool.page_refcount(pages[1]), 3);

    pool.release_kv(&mut kv2);
    assert_eq!(pool.page_refcount(pages[0]), 3);
    assert_eq!(kv2.n_pages(), 0);

    pool.release_kv(&mut kv1);
    assert_eq!(pool.page_refcount(pages[0]), 2);
    assert_eq!(pool.page_refcount(pages[1]), 1);
    assert_eq!(pool.pages_in_use(), 2, "cached pages stay resident after release");
}

#[test]
fn truncate_interacts_safely_with_prefix_sharing() {
    // speculative rollback (KvPagePool::truncate_kv) on views that share
    // pages with the prefix cache: releases drop one reference only,
    // cached entries stay adoptable, and writes past a shrink point on a
    // still-shared boundary page go through copy-on-write
    let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
    let prompt: Vec<u32> = (0..8).collect();
    let mut kv1 = pool.new_kv(16);
    pool.ensure_range(&mut kv1, 0, 8).unwrap();
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        bound.advance(8);
    }
    pool.register_prefix(&kv1, &prompt);
    let pages: Vec<u32> = kv1.page_ids().to_vec();
    assert_eq!(pool.page_refcount(pages[0]), 3, "slot + k=1 + k=2 cache entries");
    assert_eq!(pool.page_refcount(pages[1]), 2, "slot + k=2 cache entry");

    // rollback 8 -> 4: the dropped page keeps the cache's reference and
    // does NOT return to the free list
    pool.truncate_kv(&mut kv1, 4);
    assert_eq!(kv1.len(), 4);
    assert_eq!(kv1.n_pages(), 1);
    assert_eq!(pool.page_refcount(pages[0]), 3, "kept page untouched");
    assert_eq!(pool.page_refcount(pages[1]), 1, "cache still holds the dropped page");
    assert_eq!(pool.pages_in_use(), 2, "cached page stays resident after rollback");

    // the cached prefix remains adoptable after the shrink
    let longer: Vec<u32> = (0..9).collect();
    let mut kv2 = pool.new_kv(16);
    let reused = pool.adopt_prefix(&mut kv2, &longer);
    assert_eq!(reused, 8, "shrinking one view must not invalidate the cache");
    assert_eq!(pool.page_refcount(pages[0]), 4);
    assert_eq!(pool.page_refcount(pages[1]), 2);

    // rollback the adopted view onto the shared boundary page, then
    // extend past the shrink point: the write target is still shared, so
    // ensure_range must privatize it
    pool.truncate_kv(&mut kv2, 2);
    assert_eq!(kv2.n_pages(), 1);
    let cow_before = pool.stats().cow_copies;
    pool.ensure_range(&mut kv2, 2, 3).unwrap();
    assert_eq!(
        pool.stats().cow_copies,
        cow_before + 1,
        "write into a shared boundary page after rollback must copy-on-write"
    );
    assert_ne!(kv2.page_ids()[0], pages[0], "privatized away from the cached page");
    pool.release_kv(&mut kv2);

    // re-extending the truncated original maps a fresh page — the
    // cache's dropped page is never silently re-adopted
    pool.ensure_range(&mut kv1, 4, 6).unwrap();
    assert_eq!(kv1.n_pages(), 2);
    assert_ne!(kv1.page_ids()[1], pages[1]);
}

#[test]
fn cow_preserves_original_and_copies_prefix() {
    // a prompt of exactly one page admitted twice: the second admission
    // adopts the shared page and must privatize it before rewriting the
    // final position
    let (nl, nh, hd, ps) = (1usize, 1usize, 2usize, 4usize);
    let stride = nh * hd;
    let mut pool = KvPagePool::new(KvPoolConfig::new(nl, nh, hd, ps, 8));
    let prompt: Vec<u32> = vec![5, 6, 7, 8];
    let mut kv1 = pool.new_kv(16);
    pool.ensure_range(&mut kv1, 0, 4).unwrap();
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        fill(&mut bound, &prompt, 0, nl, stride);
    }
    pool.register_prefix(&kv1, &prompt);
    let p1 = kv1.page_ids()[0];

    let mut kv2 = pool.new_kv(16);
    let reused = pool.adopt_prefix(&mut kv2, &prompt);
    assert_eq!(reused, 3, "one position is always left for prefill logits");
    assert_eq!(kv2.page_ids()[0], p1, "adoption maps the shared page");
    pool.ensure_range(&mut kv2, 3, 4).unwrap();
    let p2 = kv2.page_ids()[0];
    assert_ne!(p1, p2, "divergent write must privatize the shared page");
    assert_eq!(pool.stats().cow_copies, 1);
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv2 };
        bound.write(0, 3, &vec![99.0; stride], &vec![-99.0; stride]);
        bound.advance(1);
    }

    // the original page is untouched by the divergent write
    {
        let bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        let want: Vec<f32> = (0..hd).map(|i| val(prompt[3], 3, 0, i, 1.0)).collect();
        assert_eq!(bound.k_at(0, 3, 0), &want[..]);
    }
    // the copy carried positions 0..3 over and holds the new position 3
    let bound = PagedKvRef { pool: &mut pool, kv: &mut kv2 };
    for pos in 0..3 {
        let want: Vec<f32> = (0..hd).map(|i| val(prompt[pos], pos, 0, i, 1.0)).collect();
        assert_eq!(bound.k_at(0, pos, 0), &want[..], "copied position {pos}");
    }
    assert_eq!(bound.k_at(0, 3, 0), &[99.0, 99.0]);
}

#[test]
fn exhaustion_fails_gracefully_and_recovers() {
    let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 2));
    let mut kv1 = pool.new_kv(32);
    pool.ensure_range(&mut kv1, 0, 8).unwrap();
    assert_eq!(pool.free_pages(), 0);

    let mut kv2 = pool.new_kv(32);
    let err = pool.ensure_range(&mut kv2, 0, 4).unwrap_err();
    assert!(err.to_string().contains("exhausted"), "unexpected error: {err}");
    assert_eq!(kv2.n_pages(), 0, "failed ensure must not leave pages mapped");
    assert_eq!(pool.stats().alloc_failures, 1);

    pool.release_kv(&mut kv1);
    pool.ensure_range(&mut kv2, 0, 4).unwrap();
    assert_eq!(kv2.n_pages(), 1, "released pages are reusable");
}

#[test]
fn prefix_cache_evicts_under_memory_pressure() {
    let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 2));
    let prompt: Vec<u32> = vec![1, 2, 3, 4];
    let mut kv1 = pool.new_kv(32);
    pool.ensure_range(&mut kv1, 0, 4).unwrap();
    {
        let mut bound = PagedKvRef { pool: &mut pool, kv: &mut kv1 };
        bound.advance(4);
    }
    pool.register_prefix(&kv1, &prompt);
    pool.release_kv(&mut kv1);
    assert_eq!(pool.pages_in_use(), 1, "the cache keeps its page resident");

    // a two-page demand can only be met by evicting the cached prefix
    let mut kv2 = pool.new_kv(32);
    pool.ensure_range(&mut kv2, 0, 8).unwrap();
    assert_eq!(kv2.n_pages(), 2);
    let stats = pool.stats();
    assert_eq!(stats.prefix_evictions, 1);
    assert_eq!(stats.cached_prefixes, 0);
    assert_eq!(stats.alloc_failures, 0, "eviction satisfied the demand");
}

#[test]
fn prop_draft_alias_rollback_interleavings_conserve_refcounts() {
    // The shared draft/target protocol, driven with random accept counts,
    // window sizes and pool pressure: after every step each page's pool
    // refcount must equal exactly the number of views holding it, and
    // releasing both views (in either order) must reconcile the pool to
    // zero pages in use.
    use std::collections::HashMap;
    prop_assert_ok!(check("draft_alias_refcounts", 60, |g| {
        let page_size = *g.pick(&[1usize, 2, 3, 4]);
        let n_pages = *g.pick(&[8usize, 12, 32]);
        let max_seq = 24usize;
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, page_size, n_pages));
        let mut target = pool.new_kv(max_seq);
        let mut draft = pool.new_kv(max_seq);

        let audit = |pool: &KvPagePool, target: &PagedKv, draft: &PagedKv| {
            let mut held: HashMap<u32, u32> = HashMap::new();
            for &p in target.page_ids().iter().chain(draft.page_ids()) {
                *held.entry(p).or_insert(0) += 1;
            }
            for (&p, &rc) in &held {
                if pool.page_refcount(p) != rc {
                    return Err(format!(
                        "page {p}: pool rc {}, views hold {rc}",
                        pool.page_refcount(p)
                    ));
                }
            }
            if pool.pages_in_use() != held.len() {
                return Err(format!(
                    "{} pages in use but the views hold {} distinct pages",
                    pool.pages_in_use(),
                    held.len()
                ));
            }
            Ok(())
        };

        let prompt = g.usize_range(1, 6);
        if pool.ensure_range(&mut target, 0, prompt).is_err() {
            return Ok(()); // a 1-position-per-page pool can be born too tight
        }
        {
            let mut bound = PagedKvRef { pool: &mut pool, kv: &mut target };
            bound.advance(prompt);
        }
        audit(&pool, &target, &draft)?;

        for _ in 0..g.usize_range(1, 6) {
            let len = target.len();
            if len + 4 > max_seq {
                break;
            }
            let k = g.usize_range(1, 3);
            // phase 0: the target reserves the verify window
            if pool.ensure_range(&mut target, len, len + 1 + k).is_err() {
                break; // pool too tight even for the verify pass
            }
            // phase 0b: incremental alias of the committed prefix, then a
            // CoW-extended private window for the draft's own writes
            pool.alias_kv(&mut draft, &target, len);
            let mut ks = k;
            if pool.ensure_range(&mut draft, len, len + k).is_err() {
                // degrade to k=0: fall back to the target's full pages so
                // no partial-boundary alias lingers into the verify write
                pool.retain_shared_prefix(&mut draft, &target);
                ks = 0;
            }
            audit(&pool, &target, &draft)?;

            // phase 3: accept a of the ks drafted tokens (+1 verifier
            // token), trim the unused reserve, roll the mirror back
            let a = if ks == 0 { 0 } else { g.usize_range(0, ks) };
            {
                let mut bound = PagedKvRef { pool: &mut pool, kv: &mut target };
                bound.advance(a + 1);
            }
            pool.truncate_kv(&mut target, len + a + 1);
            pool.retain_shared_prefix(&mut draft, &target);
            audit(&pool, &target, &draft)?;
        }

        if g.usize_range(0, 1) == 1 {
            pool.release_kv(&mut draft);
            pool.release_kv(&mut target);
        } else {
            pool.release_kv(&mut target);
            pool.release_kv(&mut draft);
        }
        if pool.pages_in_use() != 0 {
            return Err(format!("{} pages leaked after both releases", pool.pages_in_use()));
        }
        Ok(())
    }));
}

// ---------------------------------------------------------------------------
// End-to-end (needs checkpoint artifacts; skipped otherwise)
// ---------------------------------------------------------------------------

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = fbquant::artifacts_dir();
    root.join("manifest.json").exists().then_some(root)
}

#[test]
fn paged_backend_generation_matches_dense_backend() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fbquant", 4)).unwrap();
    let tok = ByteTokenizer::default();
    let prompts = [
        tok.encode("the green fox rests "),
        tok.encode("= sea =\nthe salty crab "),
        tok.encode("two plus three equals "),
    ];
    let run = |paged: bool| -> Vec<Vec<u32>> {
        let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
        let mut backend = NativeBackend::new(engine, "equiv");
        if !paged {
            backend = backend.with_dense();
        }
        let reqs: Vec<GenRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| GenRequest::new(i as u64 + 1, p.clone(), 16))
            .collect();
        let (responses, _) =
            Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default())
                .unwrap();
        responses.into_iter().map(|r| r.tokens).collect()
    };
    assert_eq!(run(true), run(false), "paged attention changed greedy generation");
}

#[test]
fn pool_exhaustion_sheds_admissions_with_terminal_error() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "rtn", 4)).unwrap();
    let engine = NativeEngine::from_store(&store, SubMode::None).unwrap();
    // 4 slots over a 4-page pool (16 positions per page): two 44-token
    // prompts fit (shared prefix + one copy-on-write page), the other
    // two must shed at admission — and the loop keeps serving
    let mut backend =
        NativeBackend::new(engine, "tiny-pool").with_max_slots(4).with_kv_pool(16, 4);
    let prompt: Vec<u32> = (0..44).map(|i| (40 + i % 50) as u32).collect();
    let reqs: Vec<GenRequest> =
        (0..4).map(|i| GenRequest::new(i as u64 + 1, prompt.clone(), 3)).collect();
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(responses.len() + metrics.requests_shed, 4, "requests lost");
    assert!(metrics.requests_shed >= 1, "tiny pool shed nothing");
    assert!(!responses.is_empty(), "pool served nothing");
    for r in &responses {
        assert_eq!(r.tokens.len(), 3);
    }
    let pool = metrics.kv_pool.expect("paged backend reports pool stats");
    assert!(pool.alloc_failures >= 1);
    assert!(pool.prefix_hits >= 1, "identical prompts should share pages");
}

#[test]
fn mid_decode_exhaustion_suspends_via_kv_swap_and_both_requests_complete() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "rtn", 4)).unwrap();
    let prompt: Vec<u32> = (0..30).map(|i| (40 + i % 50) as u32).collect();
    let reqs = |n: usize| -> Vec<GenRequest> {
        (0..n).map(|i| GenRequest::new(i as u64 + 1, prompt.clone(), 4)).collect()
    };
    // two 30-token prompts admit into a 4-page pool, but when decode
    // crosses the page boundary at position 32 only one new page exists:
    // the slot that cannot advance SUSPENDS — its KV swaps out to the
    // host parking buffer, the survivor runs to completion, and the
    // parked request swaps back in bit-exactly and finishes too. Nobody
    // dies; the preempt/resume transitions land in the class counters.
    let engine = NativeEngine::from_store(&store, SubMode::None).unwrap();
    let mut backend =
        NativeBackend::new(engine, "mid-decode").with_max_slots(2).with_kv_pool(16, 4);
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut backend, reqs(2), &CoordinatorConfig::default())
            .expect("mid-decode exhaustion must not abort the serving loop");
    assert_eq!(responses.len(), 2, "both requests should complete");
    assert_eq!(metrics.requests_done, 2);
    assert_eq!(metrics.requests_shed, 0, "the starved slot suspends, not sheds");
    for r in &responses {
        assert_eq!(r.tokens.len(), 4);
    }
    let std_class = metrics.classes[fbquant::coordinator::Priority::Standard.index()];
    assert!(std_class.preemptions >= 1, "no preemption recorded");
    assert_eq!(std_class.preemptions, std_class.resumes, "every park resumed");
    assert_eq!(metrics.parked, 0, "nothing left in the parking buffer");
    assert!(metrics.swapped_bytes > 0, "swap traffic metered");
    let pool = metrics.kv_pool.expect("paged backend reports pool stats");
    assert!(pool.alloc_failures >= 1);
    // after the drain the only pages still referenced are the (evictable)
    // cached prompt prefix — one full page for the shared 30-token prompt
    assert!(pool.pages_in_use <= 1, "slot pages leaked: {} in use", pool.pages_in_use);

    // exactness: the preempted-and-resumed streams must be identical to
    // an uncontended run of the same prompts on an ample pool
    let engine = NativeEngine::from_store(&store, SubMode::None).unwrap();
    let mut roomy = NativeBackend::new(engine, "roomy").with_max_slots(2).with_kv_pool(16, 64);
    let (calm, calm_metrics) =
        Coordinator::run_closed_loop(&mut roomy, reqs(2), &CoordinatorConfig::default()).unwrap();
    assert_eq!(calm_metrics.classes.iter().map(|c| c.preemptions).sum::<usize>(), 0);
    for (a, b) in responses.iter().zip(&calm) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "suspend/resume changed request {} output", a.id);
    }
}
