//! Cross-language integration: the PJRT runtime executing AOT artifacts
//! must reproduce (a) the golden JAX logits from the selftest archive and
//! (b) the native rust engine, on both the float and quantized paths.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use fbquant::engine::{NativeEngine, SubMode};
use fbquant::eval::scorer::{NativeScorer, PjrtScorer, Scorer};
use fbquant::model::WeightStore;
use fbquant::quant::formats::Archive;
use fbquant::runtime::ExecRegistry;

fn artifacts() -> Option<std::path::PathBuf> {
    let root = fbquant::artifacts_dir();
    root.join("manifest.json").exists().then_some(root)
}

#[test]
fn pjrt_fp_matches_jax_golden_and_native() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let selftest = Archive::load(&root.join("hlo/selftest.fbqw")).unwrap();
    let tokens: Vec<u32> = selftest.get("tokens").unwrap().as_i32().unwrap()
        .iter().map(|&t| t as u32).collect();
    let golden = selftest.get("logits").unwrap().as_f32().unwrap();
    let model = selftest.meta_str("model").unwrap().to_string();

    let store = WeightStore::load(&WeightStore::path_for(&root, &model, "fp", 4)).unwrap();
    let mut reg = ExecRegistry::open(&root).unwrap();
    let mut pjrt = PjrtScorer::new(&mut reg, &store).unwrap();
    let logits = pjrt.logits(&tokens).unwrap();
    assert_eq!(logits.len(), golden.len());
    let max_diff = logits
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "pjrt vs jax golden: max diff {max_diff}");

    // native engine against the same golden
    let mut native = NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused).unwrap());
    let nlogits = native.logits(&tokens).unwrap();
    let max_diff_native = nlogits
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff_native < 2e-2, "native vs jax golden: max diff {max_diff_native}");
}

#[test]
fn pjrt_quantized_matches_native_quantized() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fbquant", 4)).unwrap();
    let tokens: Vec<u32> = b"the salty crab drifts in the sea.".iter().map(|&b| b as u32).collect();

    let mut reg = ExecRegistry::open(&root).unwrap();
    let mut pjrt = PjrtScorer::new(&mut reg, &store).unwrap();
    let lp = pjrt.logits(&tokens).unwrap();

    let mut native = NativeScorer::new(NativeEngine::from_store(&store, SubMode::Fused).unwrap());
    let ln = native.logits(&tokens).unwrap();

    let max_diff = lp.iter().zip(&ln).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(max_diff < 2e-2, "pjrt-q vs native-q: max diff {max_diff}");
}

#[test]
fn pjrt_kernel_artifacts_fused_equals_unfused() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use fbquant::runtime::exec::Value;
    use fbquant::util::Pcg64;

    let mut reg = ExecRegistry::open(&root).unwrap();
    let fused = reg.load("kernel_fused_m32").unwrap();
    let unfused = reg.load("kernel_unfused_m32").unwrap();
    let spec = &fused.spec;
    let (m, k, n, r) = (32usize, 512usize, 512usize, 64usize);
    assert_eq!(spec.inputs[0].shape, vec![m, k]);

    let mut rng = Pcg64::seeded(99);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let codes: Vec<i32> = (0..n * k).map(|_| rng.below(16) as i32).collect();
    let gk = k / 128;
    let scales: Vec<f32> = (0..n * gk).map(|_| 0.01 + rng.next_f32() * 0.05).collect();
    let zeros: Vec<f32> = (0..n * gk).map(|_| rng.below(16) as f32).collect();
    let a: Vec<f32> = (0..r * k).map(|_| rng.normal() as f32 * 0.02).collect();
    let b: Vec<f32> = (0..n * r).map(|_| rng.normal() as f32 * 0.02).collect();
    let data = vec![
        Value::F32(x),
        Value::I32(codes),
        Value::F32(scales),
        Value::F32(zeros),
        Value::F32(a),
        Value::F32(b),
    ];
    let yf = fused.run(&data, &[]).unwrap();
    let yu = unfused.run(&data, &[]).unwrap();
    let yf = yf[0].as_f32().unwrap();
    let yu = yu[0].as_f32().unwrap();
    let max_diff = yf.iter().zip(yu).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "fused vs unfused kernel artifacts: {max_diff}");
}
