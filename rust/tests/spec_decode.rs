//! Self-speculative decoding equivalence and traffic tests.
//!
//! The hard invariant: **greedy speculative decode is token-identical to
//! non-speculative greedy decode** — for every draft depth K ∈ {1, 2, 4},
//! both draft modes (bare branch / 2-bit shadow), dense and paged KV,
//! fixed occupancies and random admission/release interleavings. The
//! plain backend is stepped one token at a time and must reproduce the
//! speculative backend's committed stream exactly.
//!
//! Traffic invariants: the verifier's weight bytes per step do not scale
//! with K (all K+1 positions ride one weight-stationary pass), and with
//! acceptance ≥ 1 token/step the combined (target + draft) weight bytes
//! per committed token beat the K=0 baseline.
//!
//! All fixtures are synthesized tiny checkpoints
//! (`fbquant::testing::synth`) — no build artifacts needed.

use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken, SpecSlot};
use fbquant::coordinator::request::{GenRequest, SamplingParams};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::kv::SlotBatch;
use fbquant::engine::native::EngineWs;
use fbquant::engine::{KvCache, NativeEngine, RowsWant, SubMode};
use fbquant::model::WeightStore;
use fbquant::prop_assert_ok;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::testing::{check, synth_checkpoint, SynthSpec};

fn argmax(l: &[f32]) -> u32 {
    fbquant::tensor::ops::argmax(l) as u32
}

fn plain_backend(store: &WeightStore, paged: bool) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "plain").with_max_slots(4);
    if !paged {
        b = b.with_dense();
    }
    b
}

fn spec_backend(store: &WeightStore, paged: bool, k: usize, draft: DraftMode) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "spec")
        .with_max_slots(4)
        .with_speculative(SpeculativeConfig::new(k, draft));
    if !paged {
        b = b.with_dense();
    }
    b
}

/// Advance the plain backend by `n` greedy single-token steps on `slot`,
/// appending to its stream.
fn plain_steps(
    pb: &mut NativeBackend,
    ps: &mut fbquant::coordinator::backend::BatchState,
    slot: usize,
    n: usize,
    last: &mut u32,
    stream: &mut Vec<u32>,
) {
    for _ in 0..n {
        let lg = pb.decode(ps, &[SlotToken { slot, token: *last }]).unwrap();
        let t = argmax(&lg[0]);
        stream.push(t);
        *last = t;
    }
}

#[test]
fn speculative_decode_is_token_identical_to_plain_greedy() {
    let store = synth_checkpoint(
        "spec_fixed",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    for paged in [false, true] {
        for &k in &[1usize, 2, 4] {
            for draft in [DraftMode::NoSub, DraftMode::Shadow { bits: 2 }] {
                let m = 3usize;
                let mut pb = plain_backend(&store, paged);
                let mut sb = spec_backend(&store, paged, k, draft);
                let mut ps = pb.open_batch(m).unwrap();
                let mut ss = sb.open_batch(m).unwrap();
                let mut last_p = vec![0u32; m];
                let mut cur_s = vec![0u32; m];
                let mut stream_p: Vec<Vec<u32>> = vec![Vec::new(); m];
                let mut stream_s: Vec<Vec<u32>> = vec![Vec::new(); m];
                for slot in 0..m {
                    let prompt: Vec<u32> =
                        (0..6 + slot).map(|i| ((slot * 11 + i * 7) % 50) as u32).collect();
                    let lp = pb.prefill_slot(&mut ps, slot, &prompt).unwrap();
                    let ls = sb.prefill_slot(&mut ss, slot, &prompt).unwrap();
                    assert_eq!(lp, ls, "prefill diverged (k={k} slot={slot})");
                    last_p[slot] = argmax(&lp);
                    cur_s[slot] = argmax(&ls);
                }
                for step in 0..5 {
                    let toks: Vec<SpecSlot> =
                        (0..m).map(|s| SpecSlot::greedy(s, cur_s[s])).collect();
                    let steps = sb.decode_speculative(&mut ss, &toks).unwrap();
                    assert_eq!(steps.len(), m);
                    for (slot, sp) in steps.iter().enumerate() {
                        assert!(sp.proposed <= k, "over-proposed");
                        assert!(sp.accepted.len() <= sp.proposed, "over-accepted");
                        stream_s[slot].extend_from_slice(&sp.accepted);
                        stream_s[slot].push(sp.next);
                        cur_s[slot] = sp.next;
                        plain_steps(
                            &mut pb,
                            &mut ps,
                            slot,
                            sp.accepted.len() + 1,
                            &mut last_p[slot],
                            &mut stream_p[slot],
                        );
                        assert_eq!(
                            stream_p[slot], stream_s[slot],
                            "streams diverged (paged={paged} k={k} draft={draft:?} \
                             slot={slot} step={step})"
                        );
                    }
                }
                if paged {
                    // the paged matrix must actually run the shared-pool
                    // path: draft mirrors alias target pages, no private
                    // draft pool exists to hide a 2× copy behind
                    let stats = sb.kv_stats(&ss).expect("paged backend exposes pool stats");
                    assert!(
                        stats.pages_aliased > 0,
                        "draft mirror never aliased the target (k={k} draft={draft:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn plain_and_speculative_steps_mix_freely_on_shared_paged_slots() {
    // Dense draft mirrors must be speculative-stepped for a slot's whole
    // lifetime (their draft KV trails the target via a pending catch-up
    // queue), but a shared paged mirror re-aliases the target at every
    // window — so plain decode() and decode_speculative() may interleave
    // on one slot, and the stream must stay token-identical to a plain
    // backend stepped the same way.
    let store = synth_checkpoint(
        "spec_mix_plain",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    let mut pb = plain_backend(&store, true);
    let mut sb = spec_backend(&store, true, 2, DraftMode::NoSub);
    let mut ps = pb.open_batch(1).unwrap();
    let mut ss = sb.open_batch(1).unwrap();
    let prompt: Vec<u32> = (0..6).map(|i| ((i * 7 + 3) % 50) as u32).collect();
    let lp = pb.prefill_slot(&mut ps, 0, &prompt).unwrap();
    let ls = sb.prefill_slot(&mut ss, 0, &prompt).unwrap();
    assert_eq!(lp, ls, "prefill diverged");
    let mut last_p = argmax(&lp);
    let mut cur_s = argmax(&ls);
    let mut stream_p = Vec::new();
    let mut stream_s = Vec::new();
    for round in 0..4 {
        // a speculative window...
        let steps = sb.decode_speculative(&mut ss, &[SpecSlot::greedy(0, cur_s)]).unwrap();
        let sp = &steps[0];
        stream_s.extend_from_slice(&sp.accepted);
        stream_s.push(sp.next);
        cur_s = sp.next;
        plain_steps(&mut pb, &mut ps, 0, sp.accepted.len() + 1, &mut last_p, &mut stream_p);
        // ...then a plain single-token step on the same slot
        let lg = sb.decode(&mut ss, &[SlotToken { slot: 0, token: cur_s }]).unwrap();
        let t = argmax(&lg[0]);
        stream_s.push(t);
        cur_s = t;
        plain_steps(&mut pb, &mut ps, 0, 1, &mut last_p, &mut stream_p);
        assert_eq!(stream_p, stream_s, "mixed stepping diverged at round {round}");
    }
}

#[test]
fn prop_speculative_token_identical_over_random_interleavings() {
    let store = synth_checkpoint(
        "spec_prop",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    for draft in [DraftMode::NoSub, DraftMode::Shadow { bits: 2 }] {
        for paged in [false, true] {
            prop_assert_ok!(check(&format!("spec_equiv_{paged}_{draft:?}"), 6, |g| {
                let cap = 3usize;
                let k = *g.pick(&[1usize, 2, 4]);
                let mut pb = plain_backend(&store, paged);
                let mut sb = spec_backend(&store, paged, k, draft);
                let mut ps = pb.open_batch(cap).map_err(|e| e.to_string())?;
                let mut ss = sb.open_batch(cap).map_err(|e| e.to_string())?;
                // per occupied slot: (plain last, spec cur, both streams)
                let mut live: Vec<Option<(u32, u32, Vec<u32>, Vec<u32>)>> = (0..cap)
                    .map(|_| None)
                    .collect();
                let n_ops = g.usize_range(6, 16);
                for _ in 0..n_ops {
                    match g.rng.below(4) {
                        0 | 1 => {
                            // admit into the first free slot, if any
                            if let Some(slot) = (0..cap).find(|&s| live[s].is_none()) {
                                let plen = g.usize_range(1, 6);
                                let prompt: Vec<u32> =
                                    (0..plen).map(|_| g.rng.below(50) as u32).collect();
                                let lp = pb
                                    .prefill_slot(&mut ps, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                let ls = sb
                                    .prefill_slot(&mut ss, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                if lp != ls {
                                    return Err(format!("prefill diverged at slot {slot}"));
                                }
                                let t = argmax(&lp);
                                live[slot] = Some((t, t, Vec::new(), Vec::new()));
                            }
                        }
                        2 => {
                            // release a random occupied slot
                            let occ: Vec<usize> =
                                (0..cap).filter(|&s| live[s].is_some()).collect();
                            if !occ.is_empty() {
                                let s = occ[g.rng.below(occ.len())];
                                pb.release_slot(&mut ps, s).map_err(|e| e.to_string())?;
                                sb.release_slot(&mut ss, s).map_err(|e| e.to_string())?;
                                live[s] = None;
                            }
                        }
                        _ => {
                            // retire long streams so max_seq stays distant,
                            // then one speculative step over the rest
                            for s in 0..cap {
                                let long =
                                    matches!(&live[s], Some((_, _, sp, _)) if sp.len() >= 20);
                                if long {
                                    pb.release_slot(&mut ps, s).map_err(|e| e.to_string())?;
                                    sb.release_slot(&mut ss, s).map_err(|e| e.to_string())?;
                                    live[s] = None;
                                }
                            }
                            let toks: Vec<SpecSlot> = (0..cap)
                                .filter_map(|s| {
                                    live[s]
                                        .as_ref()
                                        .map(|(_, cur, _, _)| SpecSlot::greedy(s, *cur))
                                })
                                .collect();
                            if toks.is_empty() {
                                continue;
                            }
                            let steps =
                                sb.decode_speculative(&mut ss, &toks).map_err(|e| e.to_string())?;
                            for (st, sp) in toks.iter().zip(&steps) {
                                let (last_p, cur_s, stream_p, stream_s) =
                                    live[st.slot].as_mut().expect("stepped slot is live");
                                stream_s.extend_from_slice(&sp.accepted);
                                stream_s.push(sp.next);
                                *cur_s = sp.next;
                                for _ in 0..sp.accepted.len() + 1 {
                                    let st_tok = SlotToken { slot: st.slot, token: *last_p };
                                    let lg = pb
                                        .decode(&mut ps, &[st_tok])
                                        .map_err(|e| e.to_string())?;
                                    let t = argmax(&lg[0]);
                                    stream_p.push(t);
                                    *last_p = t;
                                }
                                if stream_p != stream_s {
                                    return Err(format!(
                                        "streams diverged at slot {} (k={k})",
                                        st.slot
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
    }
}

#[test]
fn nosub_draft_on_sub_free_model_accepts_every_proposal() {
    // rank 0: the bare branch IS the model, so the draft chain equals
    // the verifier chain and every proposal must be accepted
    let store = synth_checkpoint("spec_rank0", SynthSpec { rank: 0, ..SynthSpec::default() });
    let mut sb = spec_backend(&store, true, 4, DraftMode::NoSub);
    let mut ss = sb.open_batch(2).unwrap();
    let mut cur = vec![0u32; 2];
    for slot in 0..2 {
        let prompt: Vec<u32> = (0..6).map(|i| ((slot * 13 + i * 5) % 50) as u32).collect();
        let lg = sb.prefill_slot(&mut ss, slot, &prompt).unwrap();
        cur[slot] = argmax(&lg);
    }
    for _ in 0..4 {
        let toks: Vec<SpecSlot> = (0..2).map(|s| SpecSlot::greedy(s, cur[s])).collect();
        let steps = sb.decode_speculative(&mut ss, &toks).unwrap();
        for (slot, sp) in steps.iter().enumerate() {
            assert_eq!(sp.proposed, 4, "full draft window expected");
            assert_eq!(
                sp.accepted.len(),
                4,
                "a sub-free model must accept its own bare-branch drafts"
            );
            cur[slot] = sp.next;
        }
    }
}

#[test]
fn verifier_weight_traffic_is_independent_of_k() {
    let store = synth_checkpoint(
        "spec_traffic",
        SynthSpec { d: 128, d_ff: 256, vocab: 96, group: 32, rank: 8, ..SynthSpec::default() },
    );
    let run = |k: usize| -> (u64, usize) {
        let mut b = spec_backend(&store, true, k, DraftMode::NoSub);
        let mut st = b.open_batch(2).unwrap();
        let mut cur = vec![0u32; 2];
        for slot in 0..2 {
            let prompt: Vec<u32> = (0..6).map(|i| ((slot * 13 + i * 5) % 96) as u32).collect();
            let lg = b.prefill_slot(&mut st, slot, &prompt).unwrap();
            cur[slot] = argmax(&lg);
        }
        b.reset_traffic();
        let mut committed = 0usize;
        for _ in 0..4 {
            let toks: Vec<SpecSlot> = (0..2).map(|s| SpecSlot::greedy(s, cur[s])).collect();
            let steps = b.decode_speculative(&mut st, &toks).unwrap();
            for (slot, sp) in steps.iter().enumerate() {
                committed += sp.accepted.len() + 1;
                cur[slot] = sp.next;
            }
        }
        (b.traffic().weight_bytes, committed)
    };
    let (w1, _) = run(1);
    let (w2, _) = run(2);
    let (w4, c4) = run(4);
    assert_eq!(w1, w2, "verifier weight bytes per step must not scale with K");
    assert_eq!(w1, w4, "verifier weight bytes per step must not scale with K");
    assert!(c4 >= 8, "4 steps over 2 slots commit at least one token each");
}

#[test]
fn weight_bytes_per_committed_token_beat_the_k0_baseline() {
    // all-zero A/B: the target still streams the sub-branch (full
    // verifier traffic) but the bare-branch draft chain matches it
    // exactly → acceptance is total, and the speculative win is the
    // deterministic (W_target + K·W_draft) / (K+1) < W_target
    let store = synth_checkpoint(
        "spec_wbpt",
        SynthSpec {
            d: 128,
            d_ff: 256,
            vocab: 96,
            group: 32,
            rank: 8,
            sub_scale: 0.0,
            ..SynthSpec::default()
        },
    );
    // K=0 baseline: plain greedy decode, weight bytes per token
    let mut pb = plain_backend(&store, true);
    let mut ps = pb.open_batch(1).unwrap();
    let prompt: Vec<u32> = (0..6).map(|i| ((i * 5) % 96) as u32).collect();
    let lg = pb.prefill_slot(&mut ps, 0, &prompt).unwrap();
    let mut last = argmax(&lg);
    pb.reset_traffic();
    let base_steps = 8usize;
    for _ in 0..base_steps {
        let lg = pb.decode(&mut ps, &[SlotToken { slot: 0, token: last }]).unwrap();
        last = argmax(&lg[0]);
    }
    let base_wbpt = pb.traffic().weight_bytes as f64 / base_steps as f64;

    let k = 4usize;
    let mut sb = spec_backend(&store, true, k, DraftMode::NoSub);
    let mut ss = sb.open_batch(1).unwrap();
    let lg = sb.prefill_slot(&mut ss, 0, &prompt).unwrap();
    let mut cur = argmax(&lg);
    sb.reset_traffic();
    let mut committed = 0usize;
    let mut proposed = 0usize;
    let mut accepted = 0usize;
    let spec_steps = 4usize;
    for _ in 0..spec_steps {
        let steps = sb.decode_speculative(&mut ss, &[SpecSlot::greedy(0, cur)]).unwrap();
        let sp = &steps[0];
        committed += sp.accepted.len() + 1;
        proposed += sp.proposed;
        accepted += sp.accepted.len();
        cur = sp.next;
    }
    assert_eq!(accepted, proposed, "zero sub-branch ⇒ total acceptance");
    assert!(
        accepted as f64 / spec_steps as f64 >= 1.0,
        "mean acceptance below 1 token/step"
    );
    let spec_weight =
        sb.traffic().weight_bytes + sb.draft_traffic().expect("speculative backend").weight_bytes;
    let spec_wbpt = spec_weight as f64 / committed as f64;
    assert!(
        spec_wbpt < base_wbpt,
        "speculative weight bytes/token {spec_wbpt:.0} not below baseline {base_wbpt:.0}"
    );
}

#[test]
fn coordinator_speculative_serving_is_token_identical_with_metrics() {
    let store = synth_checkpoint("spec_serve", SynthSpec { rank: 4, ..SynthSpec::default() });
    let make_reqs = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let plen = 4 + (i % 3) * 3;
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 17 + j * 5) % 50) as u32).collect();
                GenRequest::new(i as u64 + 1, prompt, 6 + (i % 5) * 3)
            })
            .collect()
    };
    let n = 7usize;
    let mut pb = plain_backend(&store, true);
    let (rp, _) =
        Coordinator::run_closed_loop(&mut pb, make_reqs(n), &CoordinatorConfig::default())
            .unwrap();
    let mut sb = spec_backend(&store, true, 2, DraftMode::NoSub);
    let (rs, ms) =
        Coordinator::run_closed_loop(&mut sb, make_reqs(n), &CoordinatorConfig::default())
            .unwrap();
    assert_eq!(rp.len(), n);
    assert_eq!(rs.len(), n);
    for (a, b) in rp.iter().zip(&rs) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "speculative serving changed greedy output");
    }
    assert!(ms.spec_steps > 0, "speculative path never engaged");
    assert!(ms.spec_tokens_per_step() >= 1.0);
    assert!(ms.weight_bytes > 0, "weight traffic not surfaced to metrics");
}

#[test]
fn mixed_greedy_and_sampled_requests_coexist_on_a_speculative_backend() {
    let store = synth_checkpoint("spec_mixed", SynthSpec { rank: 4, ..SynthSpec::default() });
    let mut sb = spec_backend(&store, true, 2, DraftMode::NoSub);
    let mut reqs = Vec::new();
    for i in 0..4u64 {
        let prompt: Vec<u32> = (0..6).map(|j| ((i as usize * 9 + j * 5) % 50) as u32).collect();
        let mut r = GenRequest::new(i + 1, prompt, 8);
        if i % 2 == 1 {
            // sampled requests speculate too, under rejection-sampling
            // acceptance (PR5) — both modes share the verify pass
            r.params =
                SamplingParams { temperature: 0.8, top_k: 8, ..SamplingParams::default() };
        }
        reqs.push(r);
    }
    let (rs, ms) =
        Coordinator::run_closed_loop(&mut sb, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(rs.len(), 4);
    for r in &rs {
        assert_eq!(r.tokens.len(), 8, "request {} lost tokens", r.id);
    }
    assert!(ms.spec_greedy.steps > 0, "greedy slots should take the speculative path");
    assert!(ms.spec_sampled.steps > 0, "sampled slots should take the speculative path");
    assert_eq!(ms.spec_steps, ms.spec_greedy.steps + ms.spec_sampled.steps);
    assert_eq!(ms.spec_accepted, ms.spec_greedy.accepted + ms.spec_sampled.accepted);
}

#[test]
fn argmax_only_verify_is_bit_identical_to_full_logits_rows() {
    // The PR4 regression guard: `RowsWant::Argmax` must reproduce the
    // argmax of the full-logits verify rows exactly (same dot products,
    // same first-max tie rule) while charging identical weight traffic —
    // the return shape is a materialization detail, never a result
    // change.
    let store = synth_checkpoint(
        "spec_amax",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
    let cfg = engine.cfg.clone();
    let mk_caches = || -> Vec<Option<KvCache>> {
        (0..2)
            .map(|_| Some(KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim())))
            .collect()
    };
    let prompts: Vec<Vec<u32>> = (0..2usize)
        .map(|s| (0..5 + s).map(|i| ((s * 7 + i * 3) % 50) as u32).collect())
        .collect();
    let groups: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut ws_a = EngineWs::default();
    let mut ws_b = EngineWs::default();
    let mut caches_a = mk_caches();
    let mut caches_b = mk_caches();
    {
        let mut sb = SlotBatch::select(&mut caches_a, &[0, 1]);
        engine.step_batch_multi(&groups, &mut sb, &mut ws_a, false);
    }
    {
        let mut sb = SlotBatch::select(&mut caches_b, &[0, 1]);
        engine.step_batch_multi(&groups, &mut sb, &mut ws_b, false);
    }
    // a K=2-shaped verify group per slot over identical KV states
    let vgroups: Vec<Vec<u32>> =
        (0..2usize).map(|s| (0..3).map(|j| ((s * 5 + j * 11) % 50) as u32).collect()).collect();
    let vg: Vec<&[u32]> = vgroups.iter().map(|g| g.as_slice()).collect();
    ws_a.traffic.reset();
    ws_b.traffic.reset();
    let full = {
        let mut sb = SlotBatch::select(&mut caches_a, &[0, 1]);
        engine.step_batch_multi_sel(&vg, &mut sb, &mut ws_a, &[RowsWant::All; 2])
    };
    let amax = {
        let mut sb = SlotBatch::select(&mut caches_b, &[0, 1]);
        engine.step_batch_multi_sel(&vg, &mut sb, &mut ws_b, &[RowsWant::Argmax; 2])
    };
    for (f, a) in full.into_iter().zip(amax) {
        let rows = f.into_rows();
        let ids = a.into_argmax();
        assert_eq!(rows.len(), ids.len());
        for (row, &id) in rows.iter().zip(&ids) {
            assert_eq!(
                argmax(row),
                id,
                "argmax-only verify diverged from the full-logits rows"
            );
        }
    }
    assert_eq!(
        ws_a.traffic.weight_bytes, ws_b.traffic.weight_bytes,
        "verify weight traffic must not depend on the return shape"
    );
}

#[test]
fn adaptive_k_keeps_greedy_serving_token_identical() {
    // greedy acceptance is argmax-vs-argmax at every K, so an adaptive
    // per-slot window changes only the weight traffic, never the stream
    let store = synth_checkpoint("spec_adapt", SynthSpec { rank: 4, ..SynthSpec::default() });
    let make_reqs = || -> Vec<GenRequest> {
        (0..5u64)
            .map(|i| {
                let plen = 4 + (i as usize % 3);
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i as usize * 17 + j * 5) % 50) as u32).collect();
                GenRequest::new(i + 1, prompt, 10)
            })
            .collect()
    };
    let mut pb = plain_backend(&store, true);
    let (rp, _) =
        Coordinator::run_closed_loop(&mut pb, make_reqs(), &CoordinatorConfig::default()).unwrap();
    let k_max = 4usize;
    let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
    let mut ab = NativeBackend::new(engine, "adaptive")
        .with_max_slots(4)
        .with_speculative(SpeculativeConfig::new(k_max, DraftMode::NoSub).with_adaptive());
    let (ra, ms) =
        Coordinator::run_closed_loop(&mut ab, make_reqs(), &CoordinatorConfig::default()).unwrap();
    assert_eq!(rp.len(), ra.len());
    for (a, b) in rp.iter().zip(&ra) {
        assert_eq!(a.tokens, b.tokens, "adaptive-K changed greedy output (req {})", a.id);
    }
    assert!(ms.spec_steps > 0);
    assert!(
        ms.spec_proposed <= ms.spec_steps * k_max,
        "adaptive windows exceeded k_max somewhere"
    );
}

/// With `--features simd` on a capable host this binary's speculative
/// conformance suite runs with the vector lane kernels active by
/// default — pin that here so the e2e coverage above is real, not a
/// silent scalar fallback (`tensor::simd` keeps both paths
/// bit-identical).
#[cfg(feature = "simd")]
#[test]
fn simd_feature_smoke() {
    use fbquant::tensor::simd;
    if simd::available() {
        assert_eq!(simd::active(), simd::Path::Simd);
    }
}
