//! Property-based tests over the quantization substrate (the in-repo
//! proptest substitute — see `fbquant::testing`).

use fbquant::prop_assert_ok;
use fbquant::quant::groupwise;
use fbquant::quant::pack::{pack_codes, unpack_codes};
use fbquant::quant::subbranch::{fbq_bound, fbq_reconstruct, SubBranch};
use fbquant::testing::check;

#[test]
fn prop_pack_unpack_roundtrip() {
    prop_assert_ok!(check("pack_roundtrip", 200, |g| {
        let rows = g.usize_range(1, 12);
        let cin = 8 * g.usize_range(1, 16);
        let codes: Vec<i8> = (0..rows * cin).map(|_| g.rng.below(16) as i8).collect();
        let packed = pack_codes(&codes, rows, cin);
        if unpack_codes(&packed, rows, cin) == codes {
            Ok(())
        } else {
            Err(format!("roundtrip failed rows={rows} cin={cin}"))
        }
    }));
}

#[test]
fn prop_rtn_error_bounded() {
    prop_assert_ok!(check("rtn_bound", 100, |g| {
        let out = g.usize_range(1, 8);
        let group = *g.pick(&[8usize, 16, 32]);
        let cin = group * g.usize_range(1, 4);
        let bits = *g.pick(&[2u8, 3, 4]);
        let scale = *g.pick(&[0.1f32, 1.0, 10.0]);
        let w = g.vec_f32(out * cin, scale);
        let p = groupwise::quant_params(&w, out, cin, bits, group);
        let wq = groupwise::dequantize(&groupwise::quantize(&w, out, cin, &p), out, cin, &p);
        let ngroups = cin / group;
        for r in 0..out {
            for c in 0..cin {
                let s = p.scales[r * ngroups + c / group];
                let err = (w[r * cin + c] - wq[r * cin + c]).abs();
                if err > s / 2.0 + 1e-5 {
                    return Err(format!("bits={bits} err={err} > s/2={}", s / 2.0));
                }
            }
        }
        Ok(())
    }));
}

#[test]
fn prop_fbq_bound_invariant_to_sigma_magnitude() {
    // The paper's Eq. 13 as a property: no matter how wild Σ is, the
    // feedback reconstruction stays within the quantizer grid bound.
    prop_assert_ok!(check("fbq_bound", 60, |g| {
        let out = g.usize_range(1, 6);
        let group = 16usize;
        let cin = group * g.usize_range(1, 3);
        let rank = g.usize_range(1, 4);
        let bits = *g.pick(&[2u8, 3, 4]);
        let sigma_scale = *g.pick(&[0.01f32, 0.5, 5.0, 100.0]);
        let w = g.vec_f32(out * cin, 1.0);
        let a = g.vec_f32(rank * cin, sigma_scale);
        let b = g.vec_f32(out * rank, sigma_scale);
        let sigma = SubBranch::new(a, b, rank, cin, out).dense_sigma();
        let wf = fbq_reconstruct(&w, &sigma, out, cin, bits, group);
        let bound = fbq_bound(&w, &sigma, out, cin, bits, group);
        for i in 0..w.len() {
            let dev = (w[i] - wf[i]).abs();
            if dev > bound[i] + 1e-4 {
                return Err(format!(
                    "dev {dev} > bound {} (sigma_scale={sigma_scale}, bits={bits})",
                    bound[i]
                ));
            }
        }
        Ok(())
    }));
}

#[test]
fn prop_quantized_gemv_matches_effective_dense() {
    use fbquant::engine::kernels::{QuantLinear, SubMode, Traffic, Workspace};

    prop_assert_ok!(check("qgemv_dense", 40, |g| {
        let group = 16usize;
        let cin = group * g.usize_range(1, 3);
        let out = 8 * g.usize_range(1, 3);
        let rank = g.usize_range(1, 4);
        let bits = *g.pick(&[3u8, 4]);
        let with_sub = g.bool();
        let with_cs = g.bool();

        let w = g.vec_f32(out * cin, 0.5);
        let p = groupwise::quant_params(&w, out, cin, bits, group);
        let codes = groupwise::quantize(&w, out, cin, &p);
        let a = with_sub.then(|| g.vec_f32(rank * cin, 0.05));
        let b = with_sub.then(|| g.vec_f32(out * rank, 0.05));
        let cs: Option<Vec<f32>> =
            with_cs.then(|| (0..cin).map(|_| 0.5 + g.rng.next_f32()).collect());

        let ql = QuantLinear {
            out,
            cin,
            bits,
            group,
            packed: pack_codes(&codes, out, cin),
            scales: p.scales.clone(),
            zeros: p.zeros.clone(),
            rank: if with_sub { rank } else { 0 },
            a: a.clone(),
            b: b.clone(),
            col_scale: cs.clone(),
            bias: None,
        };
        // effective dense weight
        let mut wd = groupwise::dequantize(&codes, out, cin, &p);
        if let (Some(a), Some(b)) = (&a, &b) {
            let sigma = SubBranch::new(a.clone(), b.clone(), rank, cin, out).dense_sigma();
            for (x, s) in wd.iter_mut().zip(sigma) {
                *x += s;
            }
        }
        if let Some(cs) = &cs {
            for r in 0..out {
                for c in 0..cin {
                    wd[r * cin + c] *= cs[c];
                }
            }
        }
        let x = g.vec_f32(cin, 1.0);
        let mut ws = Workspace::default();
        let mut t = Traffic::default();
        for mode in [SubMode::Fused, SubMode::Unfused] {
            let mut y = vec![0f32; out];
            ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
            for o in 0..out {
                let want: f32 = (0..cin).map(|c| wd[o * cin + c] * x[c]).sum();
                if (y[o] - want).abs() > 2e-3 {
                    return Err(format!("{mode:?} o={o}: {} vs {want}", y[o]));
                }
            }
        }
        Ok(())
    }));
}

#[test]
fn prop_dequantize_quantize_fixpoint() {
    // quantize(dequantize(codes)) == codes: dequantized values sit exactly
    // on grid points.
    prop_assert_ok!(check("quant_fixpoint", 60, |g| {
        let group = 16usize;
        let out = g.usize_range(1, 6);
        let cin = group * g.usize_range(1, 3);
        let bits = *g.pick(&[2u8, 3, 4]);
        let w = g.vec_f32(out * cin, 1.0);
        let p = groupwise::quant_params(&w, out, cin, bits, group);
        let codes = groupwise::quantize(&w, out, cin, &p);
        let wq = groupwise::dequantize(&codes, out, cin, &p);
        let codes2 = groupwise::quantize(&wq, out, cin, &p);
        if codes == codes2 {
            Ok(())
        } else {
            Err("re-quantization moved grid points".into())
        }
    }));
}
