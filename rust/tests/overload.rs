//! Overload-tier tests on synthesized checkpoints (no build artifacts
//! needed): exactness of KV swap-out preemption, priority scheduling
//! conservation laws, and a seeded chaos/soak run that drives the whole
//! tier at once.
//!
//! The gates:
//! * **swap exactness** — a request that is preempted (KV serialized to
//!   the host parking buffer) and later resumed produces the exact
//!   token stream of an uncontended run, on the paged pool and the
//!   dense baseline, with and without speculative draft mirrors,
//! * **priority conservation** — over random submit/pop traces every
//!   request is accounted exactly once per class
//!   (popped + shed + displaced), and the queue drains empty,
//! * **chaos/soak** — a bursty (MMPP) trace with mixed priorities,
//!   mid-stream disconnects, adaptive degradation and a starved page
//!   pool: every request terminates, the pool reconciles to zero pages,
//!   and the per-class preempt/degrade/shed counters reconcile.

use fbquant::coordinator::backend::{Backend, NativeBackend};
use fbquant::coordinator::batcher::{Batcher, BatcherConfig, Submitted};
use fbquant::coordinator::overload::DegradeConfig;
use fbquant::coordinator::request::{GenEvent, GenRequest, Priority, N_CLASSES};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::coordinator::workload::{self, Arrival, LenDist, WorkloadConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::prop_assert_ok;
use fbquant::serve::harness;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::testing::{check, synth_checkpoint, SynthSpec};
use std::cell::Cell;
use std::time::{Duration, Instant};

fn spec() -> SynthSpec {
    SynthSpec { vocab: 64, max_seq: 64, ..SynthSpec::default() }
}

/// Heavier fixture: decode steps are slow enough that a request
/// submitted mid-stream reliably lands while the first is still
/// decoding.
fn heavy_spec() -> SynthSpec {
    SynthSpec { d: 128, n_layers: 4, d_ff: 256, vocab: 64, max_seq: 64, ..SynthSpec::default() }
}

/// Paged swap-out round trip is bit-identical: random prompt/budget
/// mixes decode on a pool sized to admit everyone but starve decode
/// (slots park mid-decode, swap to host, resume), and every stream must
/// match the same trace on an ample pool. Runs with and without the
/// speculative draft mirror (the parked state then carries the mirror
/// and its pending tokens too).
#[test]
fn prop_paged_swap_roundtrip_is_bit_identical() {
    let preempted = Cell::new(0usize);
    let res = check("paged_swap_roundtrip", 8, |g| {
        let spec_on = g.bool();
        let page_size = *g.pick(&[4usize, 8]);
        let n_req = g.usize_range(2, 3);
        let max_new = g.usize_range(4, 10);
        let prompts: Vec<Vec<u32>> = (0..n_req)
            .map(|i| {
                let len = g.usize_range(6, 18);
                (0..len).map(|p| ((p * 7 + i * 13 + 5) % 64) as u32).collect()
            })
            .collect();
        let reqs = || -> Vec<GenRequest> {
            prompts
                .iter()
                .enumerate()
                .map(|(i, p)| GenRequest::new(i as u64 + 1, p.clone(), max_new))
                .collect()
        };
        // every prompt admits, but decode has a single spare page to
        // fight over — slots must park to make progress
        let pages_admit: usize =
            prompts.iter().map(|p| (p.len() + page_size - 1) / page_size).sum();
        let run = |pages: usize| {
            let store = synth_checkpoint("overload_swap_prop", spec());
            let engine = NativeEngine::from_store(&store, SubMode::Fused)
                .map_err(|e| e.to_string())?;
            let mut be = NativeBackend::new(engine, "swap-prop")
                .with_max_slots(n_req)
                .with_kv_pool(page_size, pages);
            if spec_on {
                be = be.with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub));
            }
            Coordinator::run_closed_loop(&mut be, reqs(), &CoordinatorConfig::default())
                .map_err(|e| format!("{e:#}"))
        };
        let (tight, tm) = run(pages_admit + 1)?;
        let (roomy, rm) = run(pages_admit + 8 * n_req)?;
        if tight.len() != n_req || roomy.len() != n_req {
            return Err(format!(
                "requests lost: {}/{} tight, {}/{} roomy (shed {} / {})",
                tight.len(),
                n_req,
                roomy.len(),
                n_req,
                tm.requests_shed,
                rm.requests_shed
            ));
        }
        let parks: usize = tm.classes.iter().map(|c| c.preemptions).sum();
        let resumes: usize = tm.classes.iter().map(|c| c.resumes).sum();
        if parks != resumes || tm.parked != 0 {
            return Err(format!(
                "parking did not reconcile: {parks} parks, {resumes} resumes, {} left",
                tm.parked
            ));
        }
        preempted.set(preempted.get() + parks);
        for (a, b) in tight.iter().zip(&roomy) {
            if a.id != b.id || a.tokens != b.tokens {
                return Err(format!(
                    "request {} diverged after swap (spec={spec_on}, page={page_size}):\
                     \n tight: {:?}\n roomy: {:?}",
                    a.id, a.tokens, b.tokens
                ));
            }
        }
        Ok(())
    });
    prop_assert_ok!(res);
    assert!(preempted.get() > 0, "no case ever preempted — the tight pool was not tight");
}

/// Dense-baseline priority preemption is exact: a batch-class request
/// mid-decode is swapped out for an interactive arrival (one slot, so
/// preemption is the only way in), then resumes and finishes with the
/// token stream of an uncontended solo run.
#[test]
fn dense_priority_preemption_swaps_and_resumes_exactly() {
    let tag = "overload_dense_preempt";
    let p1: Vec<u32> = (0..8).map(|i| (i * 5 % 64) as u32).collect();
    let p2: Vec<u32> = (0..8).map(|i| ((i * 3 + 1) % 64) as u32).collect();
    let solo = |prompt: &[u32], budget: usize| -> Vec<u32> {
        let store = synth_checkpoint(tag, heavy_spec());
        let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
        let mut be = NativeBackend::new(engine, "solo").with_dense().with_max_slots(1);
        let req = GenRequest::new(1, prompt.to_vec(), budget);
        let (mut r, _) =
            Coordinator::run_closed_loop(&mut be, vec![req], &CoordinatorConfig::default())
                .unwrap();
        r.remove(0).tokens
    };
    let ref1 = solo(&p1, 40);
    let ref2 = solo(&p2, 8);

    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let store = synth_checkpoint(tag, heavy_spec());
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            Ok(Box::new(NativeBackend::new(engine, "preempt").with_dense().with_max_slots(1)))
        },
        CoordinatorConfig::default(),
    );
    let mut batch_req = GenRequest::new(0, p1.clone(), 40);
    batch_req.class = Priority::Batch;
    let rx = handle.submit(batch_req);
    // once the first token streams, the batch request owns the only slot
    match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
        GenEvent::Token { .. } => {}
        other => panic!("expected a token first, got {other:?}"),
    }
    let mut inter = GenRequest::new(0, p2.clone(), 8);
    inter.class = Priority::Interactive;
    let r2 = handle.client().submit_wait(inter).unwrap();
    assert_eq!(r2.tokens, ref2, "the preempting interactive stream diverged");

    let mut done = None;
    while let Ok(ev) = rx.recv_timeout(Duration::from_secs(60)) {
        match ev {
            GenEvent::Token { .. } => {}
            GenEvent::Done(r) => {
                done = Some(r);
                break;
            }
            GenEvent::Error { message, .. } => panic!("batch request died: {message}"),
        }
    }
    let r1 = done.expect("batch stream ended without Done");
    assert_eq!(r1.tokens, ref1, "suspend/resume changed the batch request's output");

    let metrics = handle.shutdown().unwrap();
    let batch = metrics.classes[Priority::Batch.index()];
    assert!(batch.preemptions >= 1, "interactive arrival did not preempt the batch slot");
    assert_eq!(batch.preemptions, batch.resumes, "every park must resume");
    assert!(metrics.swapped_bytes > 0, "dense swap traffic not metered");
    assert_eq!(metrics.parked, 0);
    let inter_stats = metrics.classes[Priority::Interactive.index()];
    assert_eq!(inter_stats.preemptions, 0, "the interactive request must never be the victim");
}

/// A speculating slot on the shared draft/target page pool, preempted by
/// a higher-priority arrival while mid-speculation, resumes
/// bit-identically: the draft mirror's aliased pages are dropped at park
/// time (shared pages serialize once, with the target) and re-derived by
/// re-aliasing the restored target pages on resume.
#[test]
fn speculating_slot_preempted_mid_window_resumes_bit_identically() {
    let tag = "overload_spec_preempt";
    let p1: Vec<u32> = (0..8).map(|i| (i * 5 % 64) as u32).collect();
    let p2: Vec<u32> = (0..8).map(|i| ((i * 3 + 1) % 64) as u32).collect();
    // page_size 16 with 8-token prompts: nothing is published to the
    // prefix cache, so the pool must reconcile to zero pages at the end
    let solo = |prompt: &[u32], budget: usize| -> Vec<u32> {
        let store = synth_checkpoint(tag, heavy_spec());
        let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
        let mut be = NativeBackend::new(engine, "solo")
            .with_max_slots(1)
            .with_kv_pool(16, 16)
            .with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub));
        let req = GenRequest::new(1, prompt.to_vec(), budget);
        let (mut r, _) =
            Coordinator::run_closed_loop(&mut be, vec![req], &CoordinatorConfig::default())
                .unwrap();
        r.remove(0).tokens
    };
    let ref1 = solo(&p1, 40);
    let ref2 = solo(&p2, 8);

    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let store = synth_checkpoint(tag, heavy_spec());
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            Ok(Box::new(
                NativeBackend::new(engine, "spec-preempt")
                    .with_max_slots(1)
                    .with_kv_pool(16, 16)
                    .with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub)),
            ))
        },
        CoordinatorConfig::default(),
    );
    let mut batch_req = GenRequest::new(0, p1.clone(), 40);
    batch_req.class = Priority::Batch;
    let rx = handle.submit(batch_req);
    // once the first token streams, the batch request is speculating on
    // the only slot; the interactive arrival can only enter by preempting
    match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
        GenEvent::Token { .. } => {}
        other => panic!("expected a token first, got {other:?}"),
    }
    let mut inter = GenRequest::new(0, p2.clone(), 8);
    inter.class = Priority::Interactive;
    let r2 = handle.client().submit_wait(inter).unwrap();
    assert_eq!(r2.tokens, ref2, "the preempting interactive stream diverged");

    let mut done = None;
    while let Ok(ev) = rx.recv_timeout(Duration::from_secs(60)) {
        match ev {
            GenEvent::Token { .. } => {}
            GenEvent::Done(r) => {
                done = Some(r);
                break;
            }
            GenEvent::Error { message, .. } => panic!("batch request died: {message}"),
        }
    }
    let r1 = done.expect("batch stream ended without Done");
    assert_eq!(r1.tokens, ref1, "park/resume changed the speculating slot's output");

    let metrics = handle.shutdown().unwrap();
    let batch = metrics.classes[Priority::Batch.index()];
    assert!(batch.preemptions >= 1, "interactive arrival did not preempt the speculating slot");
    assert_eq!(batch.preemptions, batch.resumes, "every park must resume");
    assert_eq!(metrics.parked, 0, "the parking buffer must drain");
    assert!(metrics.swapped_bytes > 0, "paged swap traffic not metered");
    let pool = metrics.kv_pool.expect("paged backend must report pool stats");
    assert_eq!(pool.pages_in_use, 0, "KV pages leaked: {} in use", pool.pages_in_use);
    assert!(pool.pages_aliased > 0, "speculation never aliased target pages into the mirror");
}

/// Conservation over random submit/pop traces: per class, everything
/// submitted is popped, shed at the door, or displaced by a
/// higher-priority arrival — nothing is lost, and the queue drains.
#[test]
fn prop_batcher_per_class_conservation_over_random_traces() {
    let res = check("batcher_conservation", 60, |g| {
        let cfg = BatcherConfig {
            max_queue: g.usize_range(1, 6),
            // aging off: class accounting must hold without it
            age_after: Duration::from_secs(3600),
            ..BatcherConfig::default()
        };
        let mut batcher = Batcher::new(cfg);
        let now = Instant::now();
        let (mut submitted, mut popped) = ([0usize; N_CLASSES], [0usize; N_CLASSES]);
        let (mut shed, mut displaced) = ([0usize; N_CLASSES], [0usize; N_CLASSES]);
        let mut next_id = 1u64;
        for _ in 0..g.usize_range(10, 60) {
            if g.bool() {
                let mut req = GenRequest::new(next_id, vec![1, 2, 3], 4);
                next_id += 1;
                req.class = Priority::from_index(g.usize_range(0, N_CLASSES - 1));
                submitted[req.class.index()] += 1;
                match batcher.submit(req) {
                    Submitted::Queued { displaced: Some(d) } => displaced[d.class.index()] += 1,
                    Submitted::Queued { displaced: None } => {}
                    Submitted::Shed(r) => shed[r.class.index()] += 1,
                }
            } else if let Some(r) = batcher.pop_ready(now) {
                popped[r.class.index()] += 1;
            }
            let by_class = batcher.queued_by_class();
            if by_class.iter().sum::<usize>() != batcher.len() {
                return Err("queued_by_class disagrees with len".into());
            }
        }
        let mut last_class = 0usize;
        while let Some(r) = batcher.pop_ready(now) {
            // with no interleaved submits the drain is class-ordered
            if r.class.index() < last_class {
                return Err(format!("drain popped class {} after {last_class}", r.class.index()));
            }
            last_class = r.class.index();
            popped[r.class.index()] += 1;
        }
        if !batcher.is_empty() {
            return Err("drain left the queue non-empty".into());
        }
        for c in 0..N_CLASSES {
            if submitted[c] != popped[c] + shed[c] + displaced[c] {
                return Err(format!(
                    "class {c} leaked: {} submitted vs {} popped + {} shed + {} displaced",
                    submitted[c], popped[c], shed[c], displaced[c]
                ));
            }
        }
        Ok(())
    });
    prop_assert_ok!(res);
}

/// The chaos/soak gate: a seeded bursty (on/off modulated Poisson)
/// trace with mixed priority classes and planned mid-stream disconnects
/// replays against a coordinator with a starved page pool, speculative
/// decoding and load-adaptive degradation all enabled. Everything the
/// tier can do — park, resume, displace, shed, degrade, cancel — is in
/// play at once; afterwards every request must be accounted for, the
/// page pool must be empty, and the per-class counters must reconcile.
#[test]
fn chaos_soak_every_request_terminates_and_the_pool_reconciles() {
    const N: usize = 48;
    let wl_cfg = WorkloadConfig {
        n_requests: N,
        arrival: Arrival::Bursty {
            rate_on: 400.0,
            rate_off: 20.0,
            mean_on_s: 0.03,
            mean_off_s: 0.03,
        },
        // prompts stay under one 16-position page so nothing is ever
        // published to the prefix cache — the pool must reconcile to
        // exactly zero pages after the drain
        prompt_len: LenDist::new(2.0, 0.3, 4, 12),
        output_len: LenDist::new(2.0, 0.4, 3, 12),
        template_frac: 0.0,
        vocab: 64,
        class_mix: [0.3, 0.4, 0.3],
        drop_frac: 0.25,
        seed: 41,
        ..WorkloadConfig::default()
    };
    let mut wl = workload::generate(&wl_cfg, None);
    wl.clamp_to(64);
    let planned_drops = wl.meta.iter().filter(|m| m.drop_after.is_some()).count();
    assert!(planned_drops >= 1, "seed 41 planned no disconnects at all");
    let classes_present: usize =
        (0..N_CLASSES).filter(|&c| wl.meta.iter().any(|m| m.class.index() == c)).count();
    assert_eq!(classes_present, N_CLASSES, "trace must mix all priority classes");

    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_queue: 8, ..BatcherConfig::default() },
        degrade: DegradeConfig { enabled: true, ..DegradeConfig::default() },
        ..CoordinatorConfig::default()
    };
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let store = synth_checkpoint("overload_chaos", spec());
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            // 3 slots over 5 pages: sustained decode cannot fit, so the
            // coordinator must park/resume its way through the trace
            Ok(Box::new(
                NativeBackend::new(engine, "chaos")
                    .with_max_slots(3)
                    .with_kv_pool(16, 5)
                    .with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub).with_adaptive()),
            ))
        },
        cfg,
    );
    let res = harness::run_in_process(&handle.client(), &wl);
    let metrics = handle.shutdown().unwrap();

    // every request got a terminal record and the trace fully replayed
    assert_eq!(res.records.len(), N, "requests vanished without a terminal event");
    assert_eq!(metrics.requests_in, N);
    assert!(res.dropped() >= 1, "no planned disconnect actually fired");

    // per-class ledgers reconcile against the global counters
    let sum = |f: fn(&fbquant::coordinator::ClassStats) -> usize| -> usize {
        metrics.classes.iter().map(f).sum()
    };
    assert_eq!(sum(|c| c.submitted), N, "per-class submissions disagree with requests_in");
    assert_eq!(sum(|c| c.done), metrics.requests_done);
    assert_eq!(sum(|c| c.shed), metrics.requests_shed);
    for c in &metrics.classes {
        assert!(c.done + c.shed <= c.submitted, "class terminal events exceed submissions");
        assert!(c.resumes <= c.preemptions, "resumed more than was ever parked");
    }
    // cancelled-while-parked requests never resume, so preemptions can
    // exceed resumes — but the parking buffer itself must drain
    assert_eq!(metrics.parked, 0, "requests left in the parking buffer");
    assert_eq!(
        metrics.requests_done + metrics.requests_shed + metrics.cancellations,
        N,
        "terminal outcomes do not cover the trace"
    );

    // the chaos actually bit: overload transitions happened and were
    // attributed to classes
    let pressure_events = sum(|c| c.preemptions) + sum(|c| c.degrades) + metrics.requests_shed;
    assert!(pressure_events > 0, "nothing parked, degraded or shed — the pool was not starved");
    if sum(|c| c.preemptions) > 0 {
        assert!(metrics.swapped_bytes > 0, "parks happened but no swap traffic was metered");
    }

    // the starved pool reconciles to zero pages in use (sub-page
    // prompts: nothing is retained by the prefix cache)
    let pool = metrics.kv_pool.expect("paged backend must report pool stats");
    assert_eq!(pool.pages_in_use, 0, "KV pages leaked: {} in use", pool.pages_in_use);
}
