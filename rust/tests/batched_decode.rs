//! Batched-decode equivalence and traffic tests: the weight-stationary
//! `step_batch` path must produce **bit-identical** logits to the
//! per-slot sequential decode over random interleavings of admissions,
//! decode steps and releases — across dense and paged KV states, slot
//! counts m ∈ {1, 3, 8}, and layers with and without sub-branches /
//! col_scale — while its weight+metadata read traffic per step stays
//! independent of the occupied-slot count.
//!
//! The tests synthesize tiny quantized checkpoints in a temp dir (no
//! build artifacts required).

use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::WeightStore;
use fbquant::prop_assert_ok;
use fbquant::quant::formats::{f32_bytes, u32_bytes, Archive, Dtype};
use fbquant::quant::groupwise;
use fbquant::quant::pack::pack_codes;
use fbquant::testing::check;
use fbquant::util::json::Json;
use fbquant::util::Pcg64;

/// Write a tiny quantized llamoid checkpoint (4-bit groupwise, optional
/// sub-branch + col_scale) and load it back as a `WeightStore`.
#[allow(clippy::too_many_arguments)]
fn synth_store(
    tag: &str,
    d: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    vocab: usize,
    max_seq: usize,
    group: usize,
    rank: usize,
    col_scale: bool,
) -> WeightStore {
    let dir = std::env::temp_dir().join("fbq_batched_decode");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.fbqw"));
    let mut rng = Pcg64::seeded(0xbd0 ^ (d as u64) ^ ((rank as u64) << 8));
    let mut tensors: Vec<(String, Dtype, Vec<usize>, Vec<u8>)> = Vec::new();

    let randn = |rng: &mut Pcg64, n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    };
    let tok_emb = randn(&mut rng, vocab * d, 0.5);
    let lm_head = randn(&mut rng, vocab * d, 0.2);
    tensors.push(("tok_emb".to_string(), Dtype::F32, vec![vocab, d], f32_bytes(&tok_emb)));
    tensors.push(("lm_head".to_string(), Dtype::F32, vec![vocab, d], f32_bytes(&lm_head)));
    let fnw: Vec<f32> = (0..d).map(|i| 1.0 + 0.01 * (i % 7) as f32).collect();
    tensors.push(("final_norm.w".to_string(), Dtype::F32, vec![d], f32_bytes(&fnw)));

    for l in 0..n_layers {
        for nm in ["attn_norm", "mlp_norm"] {
            let w: Vec<f32> = (0..d).map(|i| 1.0 + 0.02 * ((i + l) % 5) as f32).collect();
            tensors.push((format!("l{l}.{nm}.w"), Dtype::F32, vec![d], f32_bytes(&w)));
        }
        for name in ["q", "k", "v", "o", "gate", "up", "down"] {
            let (out, cin) = match name {
                "q" | "k" | "v" | "o" => (d, d),
                "gate" | "up" => (d_ff, d),
                _ => (d, d_ff),
            };
            let prefix = format!("l{l}.{name}");
            let w = randn(&mut rng, out * cin, 0.2);
            let p = groupwise::quant_params(&w, out, cin, 4, group);
            let codes = groupwise::quantize(&w, out, cin, &p);
            let packed = pack_codes(&codes, out, cin);
            tensors.push((
                format!("{prefix}/codes_packed"),
                Dtype::U32,
                vec![out, cin / 8],
                u32_bytes(&packed),
            ));
            tensors.push((
                format!("{prefix}/scales"),
                Dtype::F32,
                vec![out, cin / group],
                f32_bytes(&p.scales),
            ));
            tensors.push((
                format!("{prefix}/zeros"),
                Dtype::F32,
                vec![out, cin / group],
                f32_bytes(&p.zeros),
            ));
            if rank > 0 {
                let a = randn(&mut rng, rank * cin, 0.05);
                let b = randn(&mut rng, out * rank, 0.05);
                tensors.push((format!("{prefix}/a"), Dtype::F32, vec![rank, cin], f32_bytes(&a)));
                tensors.push((format!("{prefix}/b"), Dtype::F32, vec![out, rank], f32_bytes(&b)));
            }
            if col_scale {
                let cs: Vec<f32> = (0..cin).map(|_| 0.5 + rng.next_f32()).collect();
                tensors.push((
                    format!("{prefix}/col_scale"),
                    Dtype::F32,
                    vec![cin],
                    f32_bytes(&cs),
                ));
            }
        }
    }

    let cfg = Json::obj(vec![
        ("name", Json::from(tag)),
        ("family", Json::from("llamoid")),
        ("d_model", Json::from(d)),
        ("n_layers", Json::from(n_layers)),
        ("n_heads", Json::from(n_heads)),
        ("d_ff", Json::from(d_ff)),
        ("vocab", Json::from(vocab)),
        ("max_seq", Json::from(max_seq)),
        ("rope_theta", Json::from(10000.0f64)),
    ]);
    let meta = Json::obj(vec![
        ("config", cfg),
        ("scheme", Json::from("quant")),
        ("method", Json::from("synthetic")),
        ("bits", Json::from(4usize)),
        ("group", Json::from(group)),
        ("rank", Json::from(rank)),
    ]);
    Archive::write(&path, &tensors, &meta).unwrap();
    WeightStore::load(&path).unwrap()
}

fn mk_backend(store: &WeightStore, paged: bool, sequential: bool) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "bd").with_max_slots(8);
    if !paged {
        b = b.with_dense();
    }
    if sequential {
        b = b.with_sequential_decode();
    }
    b
}

#[test]
fn batched_decode_matches_sequential_at_fixed_occupancies() {
    for &(rank, cs) in &[(0usize, false), (4usize, true)] {
        let store =
            synth_store(&format!("fix_r{rank}_cs{cs}"), 64, 2, 4, 96, 50, 64, 16, rank, cs);
        for paged in [false, true] {
            for m in [1usize, 3, 8] {
                let mut bb = mk_backend(&store, paged, false);
                let mut bs = mk_backend(&store, paged, true);
                let mut state_b = bb.open_batch(8).unwrap();
                let mut state_s = bs.open_batch(8).unwrap();
                let mut last = vec![0u32; m];
                for slot in 0..m {
                    // distinct lengths: slots sit at different positions
                    let prompt: Vec<u32> =
                        (0..5 + slot).map(|i| ((slot * 11 + i * 7) % 50) as u32).collect();
                    let lb = bb.prefill_slot(&mut state_b, slot, &prompt).unwrap();
                    let ls = bs.prefill_slot(&mut state_s, slot, &prompt).unwrap();
                    assert_eq!(lb, ls, "prefill diverged (m={m} slot={slot})");
                    last[slot] = fbquant::tensor::ops::argmax(&lb) as u32;
                }
                for step in 0..6 {
                    let toks: Vec<SlotToken> =
                        (0..m).map(|s| SlotToken { slot: s, token: last[s] }).collect();
                    let lb = bb.decode(&mut state_b, &toks).unwrap();
                    let ls = bs.decode(&mut state_s, &toks).unwrap();
                    assert_eq!(
                        lb, ls,
                        "decode diverged (paged={paged} m={m} step={step} rank={rank})"
                    );
                    for s in 0..m {
                        last[s] = fbquant::tensor::ops::argmax(&lb[s]) as u32;
                    }
                }
            }
        }
    }
}

#[test]
fn prop_batched_decode_bit_identical_over_random_interleavings() {
    let store_plain = synth_store("prop_plain", 64, 2, 4, 96, 50, 64, 16, 0, false);
    let store_sub = synth_store("prop_sub", 64, 2, 4, 96, 50, 64, 16, 4, true);
    for (store, tag) in [(&store_plain, "plain"), (&store_sub, "sub")] {
        for paged in [false, true] {
            prop_assert_ok!(check(&format!("batched_equiv_{tag}_{paged}"), 8, |g| {
                let cap = 4usize;
                let mut bb = mk_backend(store, paged, false);
                let mut bs = mk_backend(store, paged, true);
                let mut state_b = bb.open_batch(cap).map_err(|e| e.to_string())?;
                let mut state_s = bs.open_batch(cap).map_err(|e| e.to_string())?;
                let mut last: Vec<Option<u32>> = vec![None; cap];
                let n_ops = g.usize_range(8, 24);
                for _ in 0..n_ops {
                    match g.rng.below(4) {
                        0 | 1 => {
                            // admit into the first free slot, if any
                            if let Some(slot) = (0..cap).find(|&s| last[s].is_none()) {
                                let plen = g.usize_range(1, 8);
                                let prompt: Vec<u32> =
                                    (0..plen).map(|_| g.rng.below(50) as u32).collect();
                                let lb = bb
                                    .prefill_slot(&mut state_b, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                let ls = bs
                                    .prefill_slot(&mut state_s, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                if lb != ls {
                                    return Err(format!("prefill diverged at slot {slot}"));
                                }
                                last[slot] = Some(fbquant::tensor::ops::argmax(&lb) as u32);
                            }
                        }
                        2 => {
                            // release a random occupied slot
                            let occ: Vec<usize> =
                                (0..cap).filter(|&s| last[s].is_some()).collect();
                            if !occ.is_empty() {
                                let s = occ[g.rng.below(occ.len())];
                                bb.release_slot(&mut state_b, s).map_err(|e| e.to_string())?;
                                bs.release_slot(&mut state_s, s).map_err(|e| e.to_string())?;
                                last[s] = None;
                            }
                        }
                        _ => {
                            // one batched step over every occupied slot
                            let toks: Vec<SlotToken> = (0..cap)
                                .filter_map(|s| {
                                    last[s].map(|t| SlotToken { slot: s, token: t })
                                })
                                .collect();
                            if toks.is_empty() {
                                continue;
                            }
                            let lb =
                                bb.decode(&mut state_b, &toks).map_err(|e| e.to_string())?;
                            let ls =
                                bs.decode(&mut state_s, &toks).map_err(|e| e.to_string())?;
                            if lb != ls {
                                return Err(format!(
                                    "decode diverged over {} slots (paged={paged})",
                                    toks.len()
                                ));
                            }
                            for (st, l) in toks.iter().zip(&lb) {
                                last[st.slot] = Some(fbquant::tensor::ops::argmax(l) as u32);
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
    }
}

#[test]
fn batched_weight_traffic_is_slot_count_independent() {
    // sizes chosen so weight bytes dominate activation bytes
    let store = synth_store("traffic", 128, 2, 4, 256, 96, 64, 32, 8, false);
    let run = |m: usize, sequential: bool| -> (u64, u64) {
        let mut b = mk_backend(&store, true, sequential);
        let mut state = b.open_batch(8).unwrap();
        let mut last = vec![0u32; m];
        for slot in 0..m {
            let prompt: Vec<u32> = (0..6).map(|i| ((slot * 13 + i * 5) % 96) as u32).collect();
            let lg = b.prefill_slot(&mut state, slot, &prompt).unwrap();
            last[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
        }
        b.reset_traffic();
        let toks: Vec<SlotToken> =
            (0..m).map(|s| SlotToken { slot: s, token: last[s] }).collect();
        b.decode(&mut state, &toks).unwrap();
        let t = b.traffic();
        (t.weight_bytes, t.bytes_read)
    };
    let (w1, _) = run(1, false);
    let (w3, _) = run(3, false);
    let (w8, r8) = run(8, false);
    assert_eq!(w1, w3, "weight+metadata bytes per batched step must not scale with slots");
    assert_eq!(w1, w8, "weight+metadata bytes per batched step must not scale with slots");

    let (ws8, rs8) = run(8, true);
    assert_eq!(ws8, 8 * w8, "sequential decode re-streams the weights per slot");
    assert!(
        rs8 as f64 >= 4.0 * r8 as f64,
        "batched decode must cut per-step read traffic >=4x at m=8 \
         (sequential {rs8} vs batched {r8})"
    );
}
