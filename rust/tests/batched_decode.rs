//! Batched-decode equivalence and traffic tests: the weight-stationary
//! `step_batch` path must produce **bit-identical** logits to the
//! per-slot sequential decode over random interleavings of admissions,
//! decode steps and releases — across dense and paged KV states, slot
//! counts m ∈ {1, 3, 8}, and layers with and without sub-branches /
//! col_scale — while its weight+metadata read traffic per step stays
//! independent of the occupied-slot count.
//!
//! The tests synthesize tiny quantized checkpoints in a temp dir (no
//! build artifacts required) via `fbquant::testing::synth`.

use fbquant::coordinator::backend::{Backend, NativeBackend, SlotToken};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::WeightStore;
use fbquant::prop_assert_ok;
use fbquant::testing::{check, synth_checkpoint, SynthSpec};

fn mk_backend(store: &WeightStore, paged: bool, sequential: bool) -> NativeBackend {
    let engine = NativeEngine::from_store(store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "bd").with_max_slots(8);
    if !paged {
        b = b.with_dense();
    }
    if sequential {
        b = b.with_sequential_decode();
    }
    b
}

#[test]
fn batched_decode_matches_sequential_at_fixed_occupancies() {
    for &(rank, cs) in &[(0usize, false), (4usize, true)] {
        let store = synth_checkpoint(
            &format!("fix_r{rank}_cs{cs}"),
            SynthSpec { rank, col_scale: cs, ..SynthSpec::default() },
        );
        for paged in [false, true] {
            for m in [1usize, 3, 8] {
                let mut bb = mk_backend(&store, paged, false);
                let mut bs = mk_backend(&store, paged, true);
                let mut state_b = bb.open_batch(8).unwrap();
                let mut state_s = bs.open_batch(8).unwrap();
                let mut last = vec![0u32; m];
                for slot in 0..m {
                    // distinct lengths: slots sit at different positions
                    let prompt: Vec<u32> =
                        (0..5 + slot).map(|i| ((slot * 11 + i * 7) % 50) as u32).collect();
                    let lb = bb.prefill_slot(&mut state_b, slot, &prompt).unwrap();
                    let ls = bs.prefill_slot(&mut state_s, slot, &prompt).unwrap();
                    assert_eq!(lb, ls, "prefill diverged (m={m} slot={slot})");
                    last[slot] = fbquant::tensor::ops::argmax(&lb) as u32;
                }
                for step in 0..6 {
                    let toks: Vec<SlotToken> =
                        (0..m).map(|s| SlotToken { slot: s, token: last[s] }).collect();
                    let lb = bb.decode(&mut state_b, &toks).unwrap();
                    let ls = bs.decode(&mut state_s, &toks).unwrap();
                    assert_eq!(
                        lb, ls,
                        "decode diverged (paged={paged} m={m} step={step} rank={rank})"
                    );
                    for s in 0..m {
                        last[s] = fbquant::tensor::ops::argmax(&lb[s]) as u32;
                    }
                }
            }
        }
    }
}

#[test]
fn prop_batched_decode_bit_identical_over_random_interleavings() {
    let store_plain =
        synth_checkpoint("prop_plain", SynthSpec { rank: 0, ..SynthSpec::default() });
    let store_sub = synth_checkpoint(
        "prop_sub",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    for (store, tag) in [(&store_plain, "plain"), (&store_sub, "sub")] {
        for paged in [false, true] {
            prop_assert_ok!(check(&format!("batched_equiv_{tag}_{paged}"), 8, |g| {
                let cap = 4usize;
                let mut bb = mk_backend(store, paged, false);
                let mut bs = mk_backend(store, paged, true);
                let mut state_b = bb.open_batch(cap).map_err(|e| e.to_string())?;
                let mut state_s = bs.open_batch(cap).map_err(|e| e.to_string())?;
                let mut last: Vec<Option<u32>> = vec![None; cap];
                let n_ops = g.usize_range(8, 24);
                for _ in 0..n_ops {
                    match g.rng.below(4) {
                        0 | 1 => {
                            // admit into the first free slot, if any
                            if let Some(slot) = (0..cap).find(|&s| last[s].is_none()) {
                                let plen = g.usize_range(1, 8);
                                let prompt: Vec<u32> =
                                    (0..plen).map(|_| g.rng.below(50) as u32).collect();
                                let lb = bb
                                    .prefill_slot(&mut state_b, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                let ls = bs
                                    .prefill_slot(&mut state_s, slot, &prompt)
                                    .map_err(|e| e.to_string())?;
                                if lb != ls {
                                    return Err(format!("prefill diverged at slot {slot}"));
                                }
                                last[slot] = Some(fbquant::tensor::ops::argmax(&lb) as u32);
                            }
                        }
                        2 => {
                            // release a random occupied slot
                            let occ: Vec<usize> =
                                (0..cap).filter(|&s| last[s].is_some()).collect();
                            if !occ.is_empty() {
                                let s = occ[g.rng.below(occ.len())];
                                bb.release_slot(&mut state_b, s).map_err(|e| e.to_string())?;
                                bs.release_slot(&mut state_s, s).map_err(|e| e.to_string())?;
                                last[s] = None;
                            }
                        }
                        _ => {
                            // one batched step over every occupied slot
                            let toks: Vec<SlotToken> = (0..cap)
                                .filter_map(|s| {
                                    last[s].map(|t| SlotToken { slot: s, token: t })
                                })
                                .collect();
                            if toks.is_empty() {
                                continue;
                            }
                            let lb =
                                bb.decode(&mut state_b, &toks).map_err(|e| e.to_string())?;
                            let ls =
                                bs.decode(&mut state_s, &toks).map_err(|e| e.to_string())?;
                            if lb != ls {
                                return Err(format!(
                                    "decode diverged over {} slots (paged={paged})",
                                    toks.len()
                                ));
                            }
                            for (st, l) in toks.iter().zip(&lb) {
                                last[st.slot] = Some(fbquant::tensor::ops::argmax(l) as u32);
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
    }
}

#[test]
fn batched_group_prefill_matches_per_slot_prefill() {
    // NativeBackend::prefill_slots runs a whole admission group (mixed
    // prompt lengths) through ONE multi-position pass; logits must be
    // bit-identical to per-slot prefill, and the slots must be fully
    // decodable afterwards
    let store = synth_checkpoint(
        "group_prefill",
        SynthSpec { rank: 4, col_scale: true, ..SynthSpec::default() },
    );
    for paged in [false, true] {
        let mut ba = mk_backend(&store, paged, false);
        let mut bb = mk_backend(&store, paged, false);
        let mut sa = ba.open_batch(4).unwrap();
        let mut sb = bb.open_batch(4).unwrap();
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|s| (0..4 + 3 * s).map(|i| ((s * 7 + i * 5) % 50) as u32).collect())
            .collect();
        let admissions: Vec<(usize, &[u32])> =
            prompts.iter().enumerate().map(|(s, p)| (s, p.as_slice())).collect();
        let group = ba.prefill_slots(&mut sa, &admissions).unwrap();
        let mut per_slot = Vec::with_capacity(admissions.len());
        for &(s, p) in &admissions {
            per_slot.push(bb.prefill_slot(&mut sb, s, p).unwrap());
        }
        assert_eq!(group, per_slot, "group prefill must be bit-identical (paged={paged})");
        let toks: Vec<SlotToken> = group
            .iter()
            .enumerate()
            .map(|(s, lg)| SlotToken { slot: s, token: fbquant::tensor::ops::argmax(lg) as u32 })
            .collect();
        let la = ba.decode(&mut sa, &toks).unwrap();
        let lb = bb.decode(&mut sb, &toks).unwrap();
        assert_eq!(la, lb, "decode after group prefill diverged (paged={paged})");
    }
}

#[test]
fn group_prefill_exhaustion_unwinds_cleanly() {
    // a pool too small for the group: admission must fail as a unit,
    // release every page it mapped, and leave the surface usable
    let store = synth_checkpoint("group_shed", SynthSpec { rank: 0, ..SynthSpec::default() });
    let engine = NativeEngine::from_store(&store, SubMode::Fused).unwrap();
    let mut b = NativeBackend::new(engine, "shed").with_max_slots(4).with_kv_pool(4, 3);
    let mut st = b.open_batch(4).unwrap();
    let prompts: Vec<Vec<u32>> =
        (0..2).map(|s| (0..10).map(|i| ((s * 7 + i) % 50) as u32).collect()).collect();
    let admissions: Vec<(usize, &[u32])> =
        prompts.iter().enumerate().map(|(s, p)| (s, p.as_slice())).collect();
    // 2 x 10 tokens need 6 four-position pages; the pool has 3
    let err = b.prefill_slots(&mut st, &admissions).unwrap_err();
    assert!(err.to_string().contains("admitting"), "unexpected error: {err}");
    let stats = b.kv_stats(&st).expect("paged backend reports stats");
    assert_eq!(stats.pages_in_use, 0, "failed group admission must release all pages");
    // a single admission that fits still goes through afterwards
    let one: Vec<(usize, &[u32])> = vec![(0, prompts[0].as_slice())];
    b.prefill_slots(&mut st, &one).unwrap();
    let stats = b.kv_stats(&st).expect("paged backend reports stats");
    assert_eq!(stats.pages_in_use, 3);
}

#[test]
fn batched_weight_traffic_is_slot_count_independent() {
    // sizes chosen so weight bytes dominate activation bytes
    let store = synth_checkpoint(
        "traffic",
        SynthSpec { d: 128, d_ff: 256, vocab: 96, group: 32, rank: 8, ..SynthSpec::default() },
    );
    let run = |m: usize, sequential: bool| -> (u64, u64) {
        let mut b = mk_backend(&store, true, sequential);
        let mut state = b.open_batch(8).unwrap();
        let mut last = vec![0u32; m];
        for slot in 0..m {
            let prompt: Vec<u32> = (0..6).map(|i| ((slot * 13 + i * 5) % 96) as u32).collect();
            let lg = b.prefill_slot(&mut state, slot, &prompt).unwrap();
            last[slot] = fbquant::tensor::ops::argmax(&lg) as u32;
        }
        b.reset_traffic();
        let toks: Vec<SlotToken> =
            (0..m).map(|s| SlotToken { slot: s, token: last[s] }).collect();
        b.decode(&mut state, &toks).unwrap();
        let t = b.traffic();
        (t.weight_bytes, t.bytes_read)
    };
    let (w1, _) = run(1, false);
    let (w3, _) = run(3, false);
    let (w8, r8) = run(8, false);
    assert_eq!(w1, w3, "weight+metadata bytes per batched step must not scale with slots");
    assert_eq!(w1, w8, "weight+metadata bytes per batched step must not scale with slots");

    let (ws8, rs8) = run(8, true);
    assert_eq!(ws8, 8 * w8, "sequential decode re-streams the weights per slot");
    assert!(
        rs8 as f64 >= 4.0 * r8 as f64,
        "batched decode must cut per-step read traffic >=4x at m=8 \
         (sequential {rs8} vs batched {r8})"
    );
}

/// With `--features simd` on a capable host this binary's identity
/// suite runs with the vector lane kernels active by default — pin that
/// here so the e2e coverage above is real, not a silent scalar
/// fallback (`tensor::simd` keeps both paths bit-identical).
#[cfg(feature = "simd")]
#[test]
fn simd_feature_smoke() {
    use fbquant::tensor::simd;
    if simd::available() {
        assert_eq!(simd::active(), simd::Path::Simd);
    }
}
