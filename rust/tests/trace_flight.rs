//! Flight-recorder integration gates: the span ledger written by a real
//! coordinator run must reconstruct request timelines by request id.
//!
//! Two scenarios on synthesized checkpoints (no build artifacts needed):
//! * a deterministic single-slot priority preemption — the
//!   preempted-and-resumed request's ordered timeline must read
//!   queue → prefill → decode → swap_out → swap_in → decode → done,
//! * the seeded chaos trace from the overload suite — every request's
//!   events must reconcile: exactly one terminal marker, a queue span
//!   for everything that was placed, and swap-ins never exceeding
//!   swap-outs.
//!
//! The recorder and its level are process globals, so the tests
//! serialize on a mutex, clear the rings before each scenario, and
//! assert only on their own request ids.

use fbquant::coordinator::backend::{Backend, NativeBackend};
use fbquant::coordinator::batcher::BatcherConfig;
use fbquant::coordinator::overload::DegradeConfig;
use fbquant::coordinator::request::{GenEvent, GenRequest, Priority};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::coordinator::workload::{self, Arrival, LenDist, WorkloadConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::serve::harness;
use fbquant::spec::{DraftMode, SpeculativeConfig};
use fbquant::testing::{synth_checkpoint, SynthSpec};
use fbquant::trace::{self, Level, Phase, SpanEvent};
use std::sync::Mutex;
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

/// Arm request-level tracing with a roomy ring (the env knob is read
/// once, at the first recorded event in this process) and clear any
/// stale events from a previous scenario.
fn arm() {
    std::env::set_var("FBQ_TRACE_BUF", "65536");
    trace::set_level(Level::Request);
    let _ = trace::drain();
}

fn events_for(events: &[SpanEvent], req: u64) -> Vec<&SpanEvent> {
    events.iter().filter(|e| e.req == req).collect()
}

fn count(ev: &[&SpanEvent], phase: Phase) -> usize {
    ev.iter().filter(|e| e.phase == phase).count()
}

fn first_start(ev: &[&SpanEvent], phase: Phase) -> Option<u64> {
    ev.iter().filter(|e| e.phase == phase).map(|e| e.start_ns).min()
}

fn last_start(ev: &[&SpanEvent], phase: Phase) -> Option<u64> {
    ev.iter().filter(|e| e.phase == phase).map(|e| e.start_ns).max()
}

/// The dense single-slot preemption scenario (a batch request mid-decode
/// is swapped out for an interactive arrival, then resumes): the drained
/// ledger must carry the whole story for the preempted request, in order,
/// attributed to its stable id.
#[test]
fn preempted_request_timeline_reconstructs_by_request_id() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    arm();
    let tag = "trace_dense_preempt";
    let heavy = SynthSpec {
        d: 128,
        n_layers: 4,
        d_ff: 256,
        vocab: 64,
        max_seq: 64,
        ..SynthSpec::default()
    };
    let p1: Vec<u32> = (0..8).map(|i| (i * 5 % 64) as u32).collect();
    let p2: Vec<u32> = (0..8).map(|i| ((i * 3 + 1) % 64) as u32).collect();
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let store = synth_checkpoint(tag, heavy);
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            Ok(Box::new(NativeBackend::new(engine, "preempt").with_dense().with_max_slots(1)))
        },
        CoordinatorConfig::default(),
    );
    const BATCH_ID: u64 = 0x7A01;
    const INTER_ID: u64 = 0x7A02;
    let mut batch_req = GenRequest::new(BATCH_ID, p1, 40);
    batch_req.class = Priority::Batch;
    let rx = handle.submit(batch_req);
    // once the first token streams, the batch request owns the only slot
    match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
        GenEvent::Token { .. } => {}
        other => panic!("expected a token first, got {other:?}"),
    }
    let mut inter = GenRequest::new(INTER_ID, p2, 8);
    inter.class = Priority::Interactive;
    let r2 = handle.client().submit_wait(inter).unwrap();
    assert_eq!(r2.id, INTER_ID, "explicit ids must survive admission");
    loop {
        match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
            GenEvent::Token { .. } => {}
            GenEvent::Done(r) => {
                assert_eq!(r.id, BATCH_ID);
                assert!(r.queue_us >= 0.0 && r.prefill_us > 0.0, "response timing missing");
                break;
            }
            GenEvent::Error { message, .. } => panic!("batch request died: {message}"),
        }
    }
    let metrics = handle.shutdown().unwrap();
    assert!(
        metrics.classes[Priority::Batch.index()].preemptions >= 1,
        "the scenario did not actually preempt — the timeline gate is vacuous"
    );

    trace::set_level(Level::Off);
    let dump = trace::drain();
    assert_eq!(dump.lost, 0, "ring lapped despite FBQ_TRACE_BUF=65536");

    // the preempted request's full story, by id
    let ev = events_for(&dump.events, BATCH_ID);
    assert_eq!(count(&ev, Phase::Queue), 1, "queue span: {ev:?}");
    assert_eq!(count(&ev, Phase::Prefill), 1, "prefill span: {ev:?}");
    assert!(count(&ev, Phase::DecodeStep) >= 2, "decode steps: {ev:?}");
    let n_out = count(&ev, Phase::SwapOut);
    let n_in = count(&ev, Phase::SwapIn);
    assert!(n_out >= 1, "no swap-out span despite a metered preemption");
    assert_eq!(n_out, n_in, "every park must trace a matching resume");
    assert_eq!(count(&ev, Phase::Done), 1, "terminal marker: {ev:?}");
    for e in &ev {
        assert!(e.end_ns >= e.start_ns, "inverted span {e:?}");
        assert!(!e.phase.is_kernel(), "kernel event at request level: {e:?}");
    }

    // ...in order: queue -> prefill -> decode -> swap_out -> swap_in ->
    // decode again -> done
    let queue = first_start(&ev, Phase::Queue).unwrap();
    let prefill = first_start(&ev, Phase::Prefill).unwrap();
    let dec_first = first_start(&ev, Phase::DecodeStep).unwrap();
    let dec_last = last_start(&ev, Phase::DecodeStep).unwrap();
    let out_first = first_start(&ev, Phase::SwapOut).unwrap();
    let in_last = last_start(&ev, Phase::SwapIn).unwrap();
    let done = first_start(&ev, Phase::Done).unwrap();
    assert!(queue <= prefill, "queue span starts after prefill");
    assert!(prefill <= dec_first, "prefill starts after the first decode step");
    assert!(dec_first < out_first, "no decode step before the swap-out");
    assert!(out_first <= in_last, "swap-in precedes swap-out");
    assert!(in_last < dec_last, "no decode step after the resume");
    assert!(done >= dec_last, "terminal marker before the last decode step");

    // the interactive request was never the victim: same ledger shape,
    // zero swap events
    let ev2 = events_for(&dump.events, INTER_ID);
    assert_eq!(count(&ev2, Phase::Queue), 1);
    assert_eq!(count(&ev2, Phase::Prefill), 1);
    assert!(count(&ev2, Phase::DecodeStep) >= 1);
    assert_eq!(count(&ev2, Phase::SwapOut) + count(&ev2, Phase::SwapIn), 0);
    assert_eq!(count(&ev2, Phase::Done), 1);
}

/// The chaos trace (bursty arrivals, mixed priorities, planned
/// disconnects, starved page pool, degradation): after the run, the
/// drained ledger must reconcile request-by-request — one terminal
/// marker each, placement spans only for placed requests, swap-ins
/// bounded by swap-outs.
#[test]
fn chaos_span_ledger_reconciles_per_request() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    arm();
    const N: usize = 32;
    let wl_cfg = WorkloadConfig {
        n_requests: N,
        arrival: Arrival::Bursty {
            rate_on: 400.0,
            rate_off: 20.0,
            mean_on_s: 0.03,
            mean_off_s: 0.03,
        },
        prompt_len: LenDist::new(2.0, 0.3, 4, 12),
        output_len: LenDist::new(2.0, 0.4, 3, 12),
        template_frac: 0.0,
        vocab: 64,
        class_mix: [0.3, 0.4, 0.3],
        drop_frac: 0.25,
        seed: 41,
        ..WorkloadConfig::default()
    };
    let mut wl = workload::generate(&wl_cfg, None);
    wl.clamp_to(64);

    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_queue: 8, ..BatcherConfig::default() },
        degrade: DegradeConfig { enabled: true, ..DegradeConfig::default() },
        ..CoordinatorConfig::default()
    };
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            let spec = SynthSpec { vocab: 64, max_seq: 64, ..SynthSpec::default() };
            let store = synth_checkpoint("trace_chaos", spec);
            let engine = NativeEngine::from_store(&store, SubMode::Fused)?;
            Ok(Box::new(
                NativeBackend::new(engine, "chaos")
                    .with_max_slots(3)
                    .with_kv_pool(16, 5)
                    .with_speculative(SpeculativeConfig::new(2, DraftMode::NoSub).with_adaptive()),
            ))
        },
        cfg,
    );
    let res = harness::run_in_process(&handle.client(), &wl);
    let metrics = handle.shutdown().unwrap();
    assert_eq!(res.records.len(), N, "requests vanished without a terminal event");

    trace::set_level(Level::Off);
    let dump = trace::drain();
    assert_eq!(dump.lost, 0, "ring lapped despite FBQ_TRACE_BUF=65536");

    let terminal_of = |ev: &[&SpanEvent]| -> Vec<Phase> {
        ev.iter().filter(|e| e.phase.is_terminal()).map(|e| e.phase).collect()
    };
    let mut swap_outs = 0usize;
    for rec in &res.records {
        let ev = events_for(&dump.events, rec.id);
        assert!(!ev.is_empty(), "request {} left no trace events at all", rec.id);
        let terms = terminal_of(&ev);
        assert_eq!(
            terms.len(),
            1,
            "request {} must have exactly one terminal marker, got {terms:?}",
            rec.id
        );
        let n_queue = count(&ev, Phase::Queue);
        let n_prefill = count(&ev, Phase::Prefill);
        assert!(n_queue <= 1 && n_prefill <= 1, "request {} placed twice", rec.id);
        assert_eq!(n_queue, n_prefill, "request {} queue/prefill spans disagree", rec.id);
        if terms[0] == Phase::Done {
            assert_eq!(n_queue, 1, "request {} finished without a queue span", rec.id);
        }
        let (n_out, n_in) = (count(&ev, Phase::SwapOut), count(&ev, Phase::SwapIn));
        assert!(n_in <= n_out, "request {} resumed more than it parked", rec.id);
        swap_outs += n_out;
        for e in &ev {
            assert!(e.end_ns >= e.start_ns, "inverted span {e:?}");
        }
    }
    // the chaos actually bit somewhere the recorder can see it
    let degrades = dump.events.iter().filter(|e| e.phase == Phase::Degrade).count();
    let sheds = dump.events.iter().filter(|e| e.phase == Phase::Shed).count();
    assert!(
        swap_outs + degrades + sheds > 0,
        "no swap/degrade/shed events — the pool was not starved"
    );
    // the span ledger covers the metrics ledger: every metered preemption
    // traced a swap-out span (a failed park also traces one but meters a
    // shed, so the trace side can only be >=)
    let parks: usize = metrics.classes.iter().map(|c| c.preemptions).sum();
    assert!(swap_outs >= parks, "trace swap-outs ({swap_outs}) below metered parks ({parks})");
}
