//! End-to-end serving tests on real checkpoints (native backend; the PJRT
//! generation path is covered too when artifacts are present).

use fbquant::coordinator::backend::{Backend, NativeBackend, PjrtBackend};
use fbquant::coordinator::request::{GenEvent, GenRequest};
use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
use fbquant::engine::{NativeEngine, SubMode};
use fbquant::model::{ByteTokenizer, WeightStore};
use fbquant::runtime::ExecRegistry;

fn artifacts() -> Option<std::path::PathBuf> {
    let root = fbquant::artifacts_dir();
    root.join("manifest.json").exists().then_some(root)
}

fn native_backend(root: &std::path::Path, method: &str, bits: u8) -> NativeBackend {
    let store =
        WeightStore::load(&WeightStore::path_for(root, "llamoid-tiny", method, bits)).unwrap();
    NativeBackend::new(NativeEngine::from_store(&store, SubMode::Fused).unwrap(), "e2e")
}

#[test]
fn greedy_generation_is_deterministic_and_onpolicy() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tok = ByteTokenizer::default();
    let mut backend = native_backend(&root, "fbquant", 4);
    let prompt = tok.encode("= sea =\nthe salty crab ");
    let run = |backend: &mut NativeBackend| {
        let req = GenRequest::new(1, prompt.clone(), 24);
        let (mut r, _) =
            Coordinator::run_closed_loop(backend, vec![req], &CoordinatorConfig::default())
                .unwrap();
        r.remove(0).tokens
    };
    let a = run(&mut backend);
    let b = run(&mut backend);
    assert_eq!(a, b, "greedy generation must be deterministic");
    assert_eq!(a.len(), 24);
    // trained on the corpus grammar: output is printable ASCII
    let text = tok.decode(&a);
    assert!(
        text.bytes().all(|c| c == b'\n' || (0x20..0x7f).contains(&c)),
        "degenerate output: {text:?}"
    );
}

#[test]
fn batched_generation_matches_single_request() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tok = ByteTokenizer::default();
    let mut backend = native_backend(&root, "rtn", 4);
    let prompts = [
        tok.encode("the green fox rests "),
        tok.encode("the busy tram turns "),
        tok.encode("the soft drum calls "),
    ];
    // singles
    let mut singles = Vec::new();
    for p in &prompts {
        let req = GenRequest::new(1, p.clone(), 12);
        let (mut r, _) =
            Coordinator::run_closed_loop(&mut backend, vec![req], &CoordinatorConfig::default())
                .unwrap();
        singles.push(r.remove(0).tokens);
    }
    // all three at once: the continuous pool decodes them side by side
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest::new(i as u64 + 1, p.clone(), 12))
        .collect();
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut backend, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(metrics.admissions, 3);
    assert_eq!(metrics.pools_opened, 1, "one persistent pool serves all three");
    assert!(metrics.peak_occupied >= 3, "requests did not decode concurrently");
    for (r, single) in responses.iter().zip(&singles) {
        assert_eq!(&r.tokens, single, "concurrent decoding changed greedy output");
    }
}

#[test]
fn pjrt_generation_agrees_with_native() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tok = ByteTokenizer::default();
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fbquant", 4)).unwrap();
    // prompt length 32 = one t32 prefill chunk
    let prompt = tok.encode("the salty crab drifts in the sea");
    assert_eq!(prompt.len(), 32);

    let mut native = native_backend(&root, "fbquant", 4);
    let req = GenRequest::new(1, prompt.clone(), 16);
    let (mut rn, _) =
        Coordinator::run_closed_loop(&mut native, vec![req], &CoordinatorConfig::default())
            .unwrap();
    let native_tokens = rn.remove(0).tokens;

    let mut reg = ExecRegistry::open(&root).unwrap();
    let mut pjrt = PjrtBackend::new(&mut reg, &store, &[1, 4], "e2e").unwrap();
    let req = GenRequest::new(1, prompt.clone(), 16);
    let (mut rp, _) =
        Coordinator::run_closed_loop(&mut pjrt, vec![req], &CoordinatorConfig::default()).unwrap();
    let pjrt_tokens = rp.remove(0).tokens;

    // greedy decoding over near-identical logits: allow a small prefix
    // divergence budget (float-order differences can flip near-ties)
    let agree = native_tokens
        .iter()
        .zip(&pjrt_tokens)
        .take_while(|(a, b)| a == b)
        .count();
    assert!(
        agree >= 12,
        "pjrt vs native diverged early: {agree}/16\n native: {:?}\n pjrt: {:?}",
        tok.decode(&native_tokens),
        tok.decode(&pjrt_tokens)
    );

    // batched lock-step pjrt decode (aligned group, capacity 4, 2 occupied,
    // empty lanes masked) also works
    let reqs: Vec<GenRequest> = (0..2)
        .map(|i| GenRequest::new(i as u64 + 1, prompt.clone(), 8))
        .collect();
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut pjrt, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(responses.len(), 2);
    assert_eq!(
        responses[0].tokens, responses[1].tokens,
        "identical prompts, identical greedy output"
    );
    assert_eq!(metrics.batches_formed, 1, "lock-step pjrt forms aligned groups");
}

#[test]
fn pjrt_per_lane_continuous_agrees_with_native() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tok = ByteTokenizer::default();
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fbquant", 4)).unwrap();
    let prompt = tok.encode("the salty crab drifts in the sea");

    let mut native = native_backend(&root, "fbquant", 4);
    let req = GenRequest::new(1, prompt.clone(), 12);
    let (mut rn, _) =
        Coordinator::run_closed_loop(&mut native, vec![req], &CoordinatorConfig::default())
            .unwrap();
    let native_tokens = rn.remove(0).tokens;

    // per-lane mode: every slot is an independent batch-1 surface, so the
    // continuous scheduler can admit prompts of unequal lengths together
    let mut reg = ExecRegistry::open(&root).unwrap();
    let mut pjrt =
        PjrtBackend::new(&mut reg, &store, &[1, 4], "e2e").unwrap().with_per_lane(true);
    assert!(pjrt.continuous());
    let reqs = vec![
        GenRequest::new(1, prompt.clone(), 12),
        GenRequest::new(2, tok.encode("the quiet owl waits "), 8),
    ];
    let (responses, metrics) =
        Coordinator::run_closed_loop(&mut pjrt, reqs, &CoordinatorConfig::default()).unwrap();
    assert_eq!(responses.len(), 2);
    assert_eq!(metrics.admissions, 2);
    assert_eq!(metrics.batches_formed, 0, "per-lane pjrt admits continuously");
    let agree = native_tokens
        .iter()
        .zip(&responses[0].tokens)
        .take_while(|(a, b)| a == b)
        .count();
    assert!(
        agree >= 9,
        "per-lane pjrt diverged early from native: {agree}/12\n native: {:?}\n pjrt: {:?}",
        tok.decode(&native_tokens),
        tok.decode(&responses[0].tokens)
    );
}

#[test]
fn spawned_coordinator_roundtrip() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let store =
        WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "rtn", 4)).unwrap();
    let handle = Coordinator::spawn(
        move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(NativeBackend::new(
                NativeEngine::from_store(&store, SubMode::None)?,
                "spawned",
            )))
        },
        CoordinatorConfig::default(),
    );
    let tok = ByteTokenizer::default();
    let rxs: Vec<_> = (0..5)
        .map(|i| {
            let mut req = GenRequest::new(0, tok.encode("the quiet owl waits "), 8);
            req.params.temperature = 0.5;
            req.params.seed = i;
            handle.submit(req)
        })
        .collect();
    for rx in rxs {
        let mut streamed = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(std::time::Duration::from_secs(60)) {
            match ev {
                GenEvent::Token { token, .. } => streamed.push(token),
                GenEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
                GenEvent::Error { message, .. } => panic!("request failed: {message}"),
            }
        }
        let r = done.expect("stream ended without Done");
        assert_eq!(r.tokens.len(), 8);
        assert_eq!(r.tokens, streamed, "streamed tokens disagree with final response");
    }
    let metrics = handle.shutdown().unwrap();
    assert_eq!(metrics.requests_done, 5);
}
