//! Cross-language format tests: the rust `.fbqw` reader against archives
//! written by python (and the rust writer against the rust reader).

use fbquant::model::WeightStore;
use fbquant::quant::formats::{f32_bytes, Archive, Dtype};
use fbquant::quant::pack::{pack_codes, unpack_codes};
use fbquant::util::json::Json;
use fbquant::util::Pcg64;

fn artifacts() -> Option<std::path::PathBuf> {
    let root = fbquant::artifacts_dir();
    root.join("data/vocab.json").exists().then_some(root)
}

#[test]
fn reads_python_written_corpus_archive() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let arc = Archive::load(&root.join("data/corpus_val.fbqw")).unwrap();
    assert_eq!(arc.meta_str("kind"), Some("tokens"));
    let toks = arc.get("tokens").unwrap();
    assert_eq!(toks.dtype, Dtype::U8);
    assert!(toks.numel() > 10_000);
    // byte corpus is printable-ish ASCII + newlines
    let sample = toks.as_u8().unwrap();
    assert!(sample[..1000].iter().all(|&b| b == b'\n' || (0x20..0x7f).contains(&b)));
}

#[test]
fn loads_fp_and_quant_checkpoints_consistently() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let fp = WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fp", 4)).unwrap();
    assert!(!fp.is_quantized());
    assert_eq!(fp.cfg.d_model, 128);

    let q = WeightStore::load(&WeightStore::path_for(&root, "llamoid-tiny", "fbquant", 4)).unwrap();
    assert!(q.is_quantized());
    assert_eq!(q.bits, 4);
    assert_eq!(q.group, 128);

    // quantized effective weights approximate the fp weights
    for prefix in ["l0.q", "l1.down"] {
        let wf = match fp.linear(prefix).unwrap() {
            fbquant::model::LinearWeights::Dense { w, .. } => w.clone(),
            _ => panic!("fp layer should be dense"),
        };
        let wq = q.linear(prefix).unwrap().effective_dense();
        assert_eq!(wf.len(), wq.len());
        let rel: f64 = {
            let num: f64 = wf.iter().zip(&wq).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let den: f64 = wf.iter().map(|&a| (a as f64).powi(2)).sum();
            (num / den).sqrt()
        };
        assert!(rel < 0.2, "{prefix}: relative error {rel}");
    }

    // quantized checkpoints are materially smaller
    assert!(q.resident_bytes() < fp.resident_bytes());
}

#[test]
fn python_packed_codes_unpack_in_rust() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let arc = Archive::load(&WeightStore::path_for(&root, "llamoid-tiny", "rtn", 3)).unwrap();
    let packed_t = arc.get("l0.q/codes_packed").unwrap();
    let (out, words) = (packed_t.shape[0], packed_t.shape[1]);
    let packed = packed_t.as_u32().unwrap();
    let codes = unpack_codes(&packed, out, words * 8);
    // 3-bit codes stay in [0, 7]
    assert!(codes.iter().all(|&c| (0..=7).contains(&c)));
    // repack round-trips
    assert_eq!(pack_codes(&codes, out, words * 8), packed);
}

#[test]
fn rust_writer_reader_roundtrip_with_meta() {
    let dir = std::env::temp_dir().join("fbq_cross_format");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rt.fbqw");
    let mut rng = Pcg64::seeded(77);
    let data: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
    let meta = Json::obj(vec![
        ("kind", Json::from("weights")),
        ("bits", Json::from(3usize)),
        ("nested", Json::obj(vec![("x", Json::from(true))])),
    ]);
    Archive::write(
        &path,
        &[("w".to_string(), Dtype::F32, vec![10, 100], f32_bytes(&data))],
        &meta,
    )
    .unwrap();
    let arc = Archive::load(&path).unwrap();
    assert_eq!(arc.get("w").unwrap().as_f32().unwrap(), data);
    assert_eq!(arc.meta_usize("bits"), Some(3));
    assert_eq!(arc.meta.get("nested").unwrap().get("x").unwrap().as_bool(), Some(true));
}
