//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Covers exactly the API surface fbquant uses — [`Result`], [`Error`],
//! [`Error::msg`], the [`anyhow!`] / [`bail!`] macros and the [`Context`]
//! extension trait — so the workspace builds with no network access.
//! The implementation collapses context chains into a single message
//! string (`"context: cause"`), which is all the crate's error reporting
//! relies on. The real crates.io `anyhow` is call-compatible: point the
//! workspace manifest at it to switch back.

use std::fmt::{self, Debug, Display};

/// Drop-in alias for `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value.
///
/// Like the real `anyhow::Error`, this deliberately does NOT implement
/// `std::error::Error`: the blanket `From<E: std::error::Error>` below
/// (which powers `?` conversions) would otherwise conflict with the
/// reflexive `From<T> for T` impl in core.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

mod ext {
    /// Sealed unification of `std::error::Error` types and [`crate::Error`]
    /// so [`crate::Context`] applies to both result flavours.
    pub trait IntoMsg {
        fn into_msg(self) -> String;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoMsg for E {
        fn into_msg(self) -> String {
            self.to_string()
        }
    }

    impl IntoMsg for crate::Error {
        fn into_msg(self) -> String {
            self.to_string()
        }
    }
}

/// Attach context to errors: `.context("...")` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoMsg> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {}", e.into_msg()) })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {}", f(), e.into_msg()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: boom");
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e2.to_string(), "step 3: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_returns_formatted() {
        fn inner(n: usize) -> Result<usize> {
            if n == 0 {
                bail!("n was {n}");
            }
            Ok(n)
        }
        assert_eq!(inner(0).unwrap_err().to_string(), "n was 0");
        assert_eq!(inner(2).unwrap(), 2);
    }
}
