//! Offline stub of the `xla` crate (xla-rs) API surface used by
//! `fbquant::runtime`.
//!
//! [`Literal`] is a real host-side container (shape + typed data), so all
//! marshalling code paths type-check and unit-test without a PJRT runtime.
//! The client / compiler / executable entry points fail at runtime with a
//! clear message: replace this path dependency in the workspace manifest
//! with the real `xla` crate (github.com/LaurentMazare/xla-rs) to execute
//! the AOT HLO artifacts.

use std::borrow::Borrow;
use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: fbquant was built against the vendored xla stub \
         (rust/vendor/xla); point the workspace manifest at the real \
         xla-rs crate to enable the PJRT backend"
    ))
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: typed buffer plus dimensions (row-major).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    fn vec1(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal { data: Data::F32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(unavailable("Literal::to_vec::<f32> on non-f32 literal")),
        }
    }
}

impl NativeType for i32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal { data: Data::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(unavailable("Literal::to_vec::<i32> on non-i32 literal")),
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1(data)
    }

    fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(parts) => parts.iter().map(|p| p.numel()).sum(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.numel() as i64 {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elements) from {} elements",
                self.numel()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => Err(unavailable("Literal::to_tuple on non-tuple literal")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl From<i32> for Literal {
    fn from(v: i32) -> Literal {
        Literal { data: Data::I32(vec![v]), dims: Vec::new() }
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1f32, 2.0, 3.0, 4.0]);
        let square = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(square.dims(), &[2, 2]);
        assert_eq!(square.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_i32_literal() {
        let lit = Literal::from(7i32);
        assert!(lit.dims().is_empty());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn runtime_entry_points_report_stub() {
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("stub"), "{e}");
    }
}
