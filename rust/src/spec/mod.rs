//! Self-speculative decoding: draft on the bare quantized branch,
//! verify through the batched multi-position step.
//!
//! FBQuant's architecture is a free draft/verify pair. The packed main
//! branch alone is a cheap approximation of the model — exactly what a
//! speculative *draft* needs — and the sub-branch feedback correction
//! recovers the accuracy the *verifier* demands. No second model, no
//! distillation: the draft is the target with the sub-branch skipped
//! ([`DraftMode::NoSub`], zero extra resident bytes) or a lower-bit
//! shadow re-pack of the same codes ([`DraftMode::Shadow`], produced by
//! `quant::groupwise::requantize`).
//!
//! One speculative step per slot:
//!
//! ```text
//!   target KV at L, input token t (sampled, uncommitted)
//!     draft:   K steps on the degraded branch → d_1 .. d_K
//!              greedy slots: argmax chain; sampled slots: d_j ~ q_j,
//!              the draft's post-params distribution (recorded for the
//!              accept ratio). Batched across slots; draft KV mirrors
//!              advance to L+K.
//!     verify:  ONE multi-position pass over the target
//!              (NativeEngine::step_batch_multi_sel, rows = m·(K+1)):
//!              feed [t, d_1 .. d_K] — greedy slots fetch only the
//!              argmax id per row (no rows×vocab materialization),
//!              sampled slots fetch the full logits rows they need
//!     accept:  greedy — d_j commits while d_j == argmax_{j-1}
//!              ([`greedy_accept_ids`]); sampled — d_j commits with
//!              probability min(1, p_j(d_j)/q_j(d_j)) and the first
//!              rejection resamples from the normalized residual
//!              max(0, p_j − q_j) ([`accept::stochastic_accept`])
//!     commit:  a accepted drafts + 1 correction/bonus = 1..=K+1 tokens
//!     rollback: truncate the target to L+1+a (KvSlot::truncate /
//!              KvPagePool::truncate_kv — rejected positions and page
//!              over-reservations return to the pool); the shared-pool
//!              draft mirror rolls back against the target's table
//!              (KvPagePool::retain_shared_prefix — only the CoW'd
//!              boundary and window pages release; still-shared aliases
//!              keep their reference). On the dense baseline, full
//!              acceptance queues the mirror's missing last token in a
//!              lazy catch-up list that rides the next step's first
//!              draft pass (no extra draft weight stream)
//! ```
//!
//! On the (default) paged store the draft mirror holds **no private
//! copy of the history**: before drafting, its page table syncs to the
//! target's committed pages in the ONE shared [`crate::engine::kv::KvPagePool`]
//! ([`KvPagePool::alias_kv`] — refcount bumps, no copy), the draft pass
//! privatizes only the boundary page it appends into (copy-on-write)
//! plus the fresh window pages, and the end-of-step rollback returns
//! exactly those to the pool. Speculation's KV tax is ~1 transient page
//! per in-flight window instead of a second KV budget.
//!
//! Greedy acceptance compares against the verifier's own argmax, and the
//! multi-position step is bit-identical per row to sequential decode, so
//! the greedy committed stream is **token-identical to non-speculative
//! greedy decode**. Stochastic acceptance is the classic rejection rule
//! (see [`accept`]): the committed stream is **distributed exactly as
//! plain sampled decode** — `rust/tests/spec_sampled.rs` pins that with
//! a seeded conformance harness. Either way, speculation only changes
//! how many weight streams each token costs, never what is emitted (in
//! value or in law). The verifier streams its weights once per step
//! regardless of K, so weight bytes per committed token fall whenever at
//! least one draft survives per step on average.
//!
//! With [`SpeculativeConfig::adaptive`], each slot's draft window tracks
//! its own acceptance-rate EWMA ([`adaptive::KController`]): `k` scales
//! with the measured rate within `[0, k_max]`, degrading to plain decode
//! (with periodic probes) on draft-hostile text.
//!
//! Wiring lives in `coordinator::backend`
//! (`NativeBackend::with_speculative`, `Backend::decode_speculative`)
//! and `coordinator::server` (slots emit `1..=K+1` tokens per scheduling
//! step); this module owns the draft state ([`DraftKv`]), the drafting
//! loop ([`draft_tokens`]) and the acceptance rules ([`greedy_accept_ids`],
//! [`accept::stochastic_accept`]).

pub mod accept;
pub mod adaptive;
pub mod draft;

pub use accept::{
    accept_prob, analytic_accept_rate, residual, stochastic_accept, stochastic_accept_with,
};
pub use adaptive::KController;
pub use draft::DraftKv;

use crate::coordinator::request::SamplingParams;
use crate::coordinator::sampler::{distribution, draw_from};
use crate::engine::kv::KvPagePool;
use crate::engine::native::{EngineWs, NativeEngine};
use crate::tensor::ops;
use crate::util::Pcg64;

/// Which degraded configuration drafts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftMode {
    /// Draft on the target's own weights with the sub-branch skipped
    /// (`SubMode::None`): zero extra resident bytes — the draft *is*
    /// FBQuant's bare packed branch.
    NoSub,
    /// Draft on a lower-bit shadow re-pack of the main branch (see
    /// `QuantLinear::shadow`): a cheaper weight stream per draft step,
    /// at some acceptance-rate cost.
    Shadow { bits: u8 },
}

/// Speculative-decoding configuration carried by a backend.
#[derive(Debug, Clone, Copy)]
pub struct SpeculativeConfig {
    /// Draft depth ceiling: up to `k` proposals per slot per step (each
    /// step commits `1..=k+1` tokens). With `adaptive`, the per-slot
    /// window moves within `[0, k]`.
    pub k: usize,
    pub draft: DraftMode,
    /// Drive each slot's draft window from its acceptance-rate EWMA
    /// ([`adaptive::KController`]) instead of always drafting `k`.
    pub adaptive: bool,
}

impl SpeculativeConfig {
    pub fn new(k: usize, draft: DraftMode) -> SpeculativeConfig {
        SpeculativeConfig { k, draft, adaptive: false }
    }

    pub fn with_adaptive(mut self) -> SpeculativeConfig {
        self.adaptive = true;
        self
    }
}

/// Outcome of one speculative step for one slot.
#[derive(Debug, Clone)]
pub struct SpecStep {
    /// Draft tokens accepted this step, in order — all committed.
    pub accepted: Vec<u32>,
    /// The correction/bonus token: sampled but not yet committed (the
    /// slot's next feed token, exactly like plain decode's sample).
    pub next: u32,
    /// Draft tokens proposed (acceptance-rate denominator; can be less
    /// than the configured `k` near `max_seq`, under pool pressure, or
    /// under an adaptive controller).
    pub proposed: usize,
}

/// Per-backend speculative state: the config, the optional shadow
/// engine, the draft-side workspace (draft traffic is metered apart
/// from the target's), the draft KV mirrors, the per-slot **lazy
/// catch-up queues** — tokens the target committed that the mirror has
/// not fed yet (they ride the NEXT step's first draft pass as extra
/// positions, so full acceptance never costs an extra draft weight
/// stream) — plus the stochastic-acceptance RNG and the per-slot
/// adaptive-K controllers.
pub struct SpecDecoder {
    pub cfg: SpeculativeConfig,
    pub(crate) shadow: Option<NativeEngine>,
    pub(crate) ws: EngineWs,
    pub(crate) kv: DraftKv,
    /// Per target-slot committed-but-unmirrored tokens, **dense mirrors
    /// only** (invariant there:
    /// `draft_len(slot) + pending[slot].len() == target_len(slot)`).
    /// Shared-pool mirrors keep these empty — the page-table sync
    /// catches them up against the target for free.
    pub(crate) pending: Vec<Vec<u32>>,
    /// Draws for draft sampling, accept/reject and residual resampling
    /// (one seeded stream per backend: serving runs stay reproducible).
    pub(crate) rng: Pcg64,
    /// Per-slot adaptive draft-depth state (used when `cfg.adaptive`).
    pub(crate) ctrl: Vec<KController>,
}

impl SpecDecoder {
    pub fn new(cfg: SpeculativeConfig, target: &NativeEngine) -> SpecDecoder {
        assert!(cfg.k >= 1, "speculative draft depth must be >= 1");
        let shadow = match cfg.draft {
            DraftMode::NoSub => None,
            DraftMode::Shadow { bits } => Some(target.shadow(bits)),
        };
        SpecDecoder {
            cfg,
            shadow,
            ws: EngineWs::default(),
            kv: DraftKv::Unopened,
            pending: Vec::new(),
            rng: Pcg64::seeded(0x5bec_acce),
            ctrl: Vec::new(),
        }
    }

    /// Extra weight bytes the draft engine pins (0 for
    /// [`DraftMode::NoSub`] — it reuses the target's tensors).
    pub fn resident_bytes(&self) -> usize {
        self.shadow.as_ref().map_or(0, |e| e.resident_bytes())
    }
}

/// Greedy acceptance for one slot over precomputed verifier argmax ids:
/// `ids[j]` is the target's argmax after feeding the j-th token of
/// `[t, drafts...]` (`ids.len() == drafts.len() + 1` — the shape
/// `NativeEngine::step_batch_multi_sel` returns for `RowsWant::Argmax`,
/// with no `rows × vocab` logits materialized). Returns `(a, next)`: the
/// count of leading drafts matching the verifier's argmax chain, and the
/// token the slot feeds next (the correction at the first mismatch, or
/// the bonus token after full acceptance). The committed stream
/// `drafts[..a] ++ [next]` equals sequential greedy decode exactly.
pub fn greedy_accept_ids(drafts: &[u32], ids: &[u32]) -> (usize, u32) {
    debug_assert_eq!(ids.len(), drafts.len() + 1, "one argmax per fed token");
    for (j, &d) in drafts.iter().enumerate() {
        if ids[j] != d {
            return (j, ids[j]);
        }
    }
    (drafts.len(), ids[drafts.len()])
}

/// [`greedy_accept_ids`] over full logits rows (reduces each row to its
/// argmax first). Kept for the full-logits verify path and for the
/// regression test pinning the argmax-only return bit-identical to it.
pub fn greedy_accept(drafts: &[u32], verify: &[Vec<f32>]) -> (usize, u32) {
    debug_assert_eq!(verify.len(), drafts.len() + 1, "one logits row per fed token");
    let ids: Vec<u32> = verify.iter().map(|row| ops::argmax(row) as u32).collect();
    greedy_accept_ids(drafts, &ids)
}

/// The drafting loop, batched across slots: draft step `j` feeds every
/// slot still within its budget (`ks[i] > j`) through one
/// weight-stationary pass on the draft engine, and extends that slot's
/// proposal chain — greedily for `samplings[i] == None`, else by
/// sampling from the draft's post-params distribution `q_j` (recorded
/// per position so verification can form the accept ratio and residual).
/// `cur0[i]` is slot `i`'s input token; `pending` holds each slot's
/// committed-but-unmirrored catch-up tokens on the dense store (drained
/// here for every slot that drafts — they ride the FIRST draft pass as
/// extra positions, costing no extra weight stream; shared-pool mirrors
/// keep `pending` empty, the page-table sync already caught them up).
/// `pool` is the shared target pool the [`DraftKv::Shared`] mirrors
/// read and write through (None on the dense baseline). The draft KV
/// mirrors advance by `pending + ks[i]` positions. Returns the proposal
/// lists (len `ks[i]` each) and, per slot, the draft distributions
/// `q_1..q_{ks[i]}` (empty for greedy slots).
#[allow(clippy::too_many_arguments)]
pub fn draft_tokens(
    draft: &NativeEngine,
    kv: &mut DraftKv,
    ws: &mut EngineWs,
    slots: &[usize],
    pending: &mut [Vec<u32>],
    cur0: &[u32],
    ks: &[usize],
    samplings: &[Option<&SamplingParams>],
    rng: &mut Pcg64,
    mut pool: Option<&mut KvPagePool>,
) -> (Vec<Vec<u32>>, Vec<Vec<Vec<f64>>>) {
    let n = slots.len();
    debug_assert_eq!(n, cur0.len());
    debug_assert_eq!(n, ks.len());
    debug_assert_eq!(n, samplings.len());
    let k_max = ks.iter().copied().max().unwrap_or(0);
    let mut drafts: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut qs: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n];
    if k_max == 0 {
        return (drafts, qs);
    }
    let mut cur = cur0.to_vec();
    // extend slot i's chain from its latest draft logits
    let propose = |i: usize,
                   logits: &[f32],
                   drafts: &mut Vec<Vec<u32>>,
                   qs: &mut Vec<Vec<Vec<f64>>>,
                   cur: &mut Vec<u32>,
                   rng: &mut Pcg64| {
        let t = match samplings[i] {
            None => ops::argmax(logits) as u32,
            Some(p) => {
                let q = distribution(logits, p);
                let t = draw_from(rng, &q);
                qs[i].push(q);
                t
            }
        };
        drafts[i].push(t);
        cur[i] = t;
    };
    // first draft pass: catch-up tokens + the input token per slot, as
    // one multi-position group each
    {
        let mut sel: Vec<usize> = Vec::new();
        let mut groups_store: Vec<Vec<u32>> = Vec::new();
        for i in 0..n {
            if ks[i] > 0 {
                let mut g = std::mem::take(&mut pending[slots[i]]);
                g.push(cur[i]);
                sel.push(slots[i]);
                groups_store.push(g);
            }
        }
        let groups: Vec<&[u32]> = groups_store.iter().map(|g| g.as_slice()).collect();
        let logits = kv.step_multi(draft, &sel, &groups, ws, pool.as_deref_mut());
        let mut li = 0usize;
        for i in 0..n {
            if ks[i] > 0 {
                propose(i, &logits[li], &mut drafts, &mut qs, &mut cur, rng);
                li += 1;
            }
        }
    }
    // remaining draft steps: single position per still-drafting slot
    for j in 1..k_max {
        let mut sel: Vec<usize> = Vec::new();
        let mut toks: Vec<u32> = Vec::new();
        for i in 0..n {
            if ks[i] > j {
                sel.push(slots[i]);
                toks.push(cur[i]);
            }
        }
        if sel.is_empty() {
            break;
        }
        let logits = kv.step(draft, &sel, &toks, ws, pool.as_deref_mut());
        let mut li = 0usize;
        for i in 0..n {
            if ks[i] > j {
                propose(i, &logits[li], &mut drafts, &mut qs, &mut cur, rng);
                li += 1;
            }
        }
    }
    (drafts, qs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_for(argmax: usize, vocab: usize) -> Vec<f32> {
        let mut l = vec![0f32; vocab];
        l[argmax] = 5.0;
        l
    }

    #[test]
    fn greedy_accept_full_partial_and_none() {
        // verifier chain: argmax after t is 7, after 7 is 3, after 3 is 9
        let verify = vec![logits_for(7, 16), logits_for(3, 16), logits_for(9, 16)];
        // full acceptance: drafts match the chain, bonus token follows
        assert_eq!(greedy_accept(&[7, 3], &verify), (2, 9));
        // first mismatch: correction replaces the draft
        assert_eq!(greedy_accept(&[7, 4], &verify), (1, 3));
        assert_eq!(greedy_accept(&[6, 3], &verify), (0, 7));
        // k = 0 degenerates to a plain greedy step
        assert_eq!(greedy_accept(&[], &verify[..1]), (0, 7));
    }

    #[test]
    fn greedy_accept_ids_matches_logits_variant() {
        let verify = vec![logits_for(7, 16), logits_for(3, 16), logits_for(9, 16)];
        let ids = vec![7u32, 3, 9];
        for drafts in [vec![7u32, 3], vec![7, 4], vec![6, 3], vec![]] {
            assert_eq!(
                greedy_accept_ids(&drafts, &ids[..drafts.len() + 1]),
                greedy_accept(&drafts, &verify[..drafts.len() + 1]),
                "drafts={drafts:?}"
            );
        }
    }
}
