//! Self-speculative decoding: draft on the bare quantized branch,
//! verify through the batched multi-position step.
//!
//! FBQuant's architecture is a free draft/verify pair. The packed main
//! branch alone is a cheap approximation of the model — exactly what a
//! speculative *draft* needs — and the sub-branch feedback correction
//! recovers the accuracy the *verifier* demands. No second model, no
//! distillation: the draft is the target with the sub-branch skipped
//! ([`DraftMode::NoSub`], zero extra resident bytes) or a lower-bit
//! shadow re-pack of the same codes ([`DraftMode::Shadow`], produced by
//! `quant::groupwise::requantize`).
//!
//! One speculative step per slot:
//!
//! ```text
//!   target KV at L, input token t (sampled, uncommitted)
//!     draft:   K greedy steps on the degraded branch  → d_1 .. d_K
//!              (batched across slots; draft KV mirrors advance to L+K)
//!     verify:  ONE multi-position pass over the target
//!              (NativeEngine::step_batch_multi, rows = m·(K+1)):
//!              feed [t, d_1 .. d_K]  → logits at every position
//!     accept:  greedy — d_j commits while d_j == argmax(logits_{j-1});
//!              first mismatch yields the correction token instead
//!     commit:  a accepted drafts + 1 correction/bonus = 1..=K+1 tokens
//!     rollback: truncate BOTH caches to L+1+a (KvSlot::truncate /
//!              KvPagePool::truncate_kv — rejected positions and page
//!              over-reservations return to the pool); on FULL
//!              acceptance the mirror's missing last token queues in a
//!              lazy catch-up list and rides the next step's first
//!              draft pass (no extra draft weight stream)
//! ```
//!
//! Because acceptance compares against the verifier's own greedy argmax
//! and the multi-position step is bit-identical per row to sequential
//! decode, the committed stream is **token-identical to non-speculative
//! greedy decode** — speculation only changes how many weight streams
//! each token costs, never which token is emitted. The verifier streams
//! its weights once per step regardless of K, so weight bytes per
//! committed token fall whenever at least one draft survives per step
//! on average.
//!
//! Wiring lives in `coordinator::backend`
//! (`NativeBackend::with_speculative`, `Backend::decode_speculative`)
//! and `coordinator::server` (slots emit `1..=K+1` tokens per scheduling
//! step); this module owns the draft state ([`DraftKv`]), the drafting
//! loop ([`draft_tokens`]) and the acceptance rule ([`greedy_accept`]).

pub mod draft;

pub use draft::DraftKv;

use crate::engine::native::{EngineWs, NativeEngine};
use crate::tensor::ops;

/// Which degraded configuration drafts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftMode {
    /// Draft on the target's own weights with the sub-branch skipped
    /// (`SubMode::None`): zero extra resident bytes — the draft *is*
    /// FBQuant's bare packed branch.
    NoSub,
    /// Draft on a lower-bit shadow re-pack of the main branch (see
    /// `QuantLinear::shadow`): a cheaper weight stream per draft step,
    /// at some acceptance-rate cost.
    Shadow { bits: u8 },
}

/// Speculative-decoding configuration carried by a backend.
#[derive(Debug, Clone, Copy)]
pub struct SpeculativeConfig {
    /// Draft depth: up to `k` proposals per slot per step (each step
    /// commits `1..=k+1` tokens).
    pub k: usize,
    pub draft: DraftMode,
}

/// Outcome of one speculative step for one slot.
#[derive(Debug, Clone)]
pub struct SpecStep {
    /// Draft tokens accepted this step, in order — all committed.
    pub accepted: Vec<u32>,
    /// The correction/bonus token: sampled but not yet committed (the
    /// slot's next feed token, exactly like plain decode's sample).
    pub next: u32,
    /// Draft tokens proposed (acceptance-rate denominator; can be less
    /// than the configured `k` near `max_seq` or under pool pressure).
    pub proposed: usize,
}

/// Per-backend speculative state: the config, the optional shadow
/// engine, the draft-side workspace (draft traffic is metered apart
/// from the target's), the draft KV mirrors and the per-slot **lazy
/// catch-up queues** — tokens the target committed that the mirror has
/// not fed yet. They ride the NEXT step's first draft pass as extra
/// positions, so full acceptance never costs an extra draft weight
/// stream.
pub struct SpecDecoder {
    pub cfg: SpeculativeConfig,
    pub(crate) shadow: Option<NativeEngine>,
    pub(crate) ws: EngineWs,
    pub(crate) kv: DraftKv,
    /// Per target-slot committed-but-unmirrored tokens (invariant:
    /// `draft_len(slot) + pending[slot].len() == target_len(slot)`).
    pub(crate) pending: Vec<Vec<u32>>,
}

impl SpecDecoder {
    pub fn new(cfg: SpeculativeConfig, target: &NativeEngine) -> SpecDecoder {
        assert!(cfg.k >= 1, "speculative draft depth must be >= 1");
        let shadow = match cfg.draft {
            DraftMode::NoSub => None,
            DraftMode::Shadow { bits } => Some(target.shadow(bits)),
        };
        SpecDecoder {
            cfg,
            shadow,
            ws: EngineWs::default(),
            kv: DraftKv::Unopened,
            pending: Vec::new(),
        }
    }

    /// Extra weight bytes the draft engine pins (0 for
    /// [`DraftMode::NoSub`] — it reuses the target's tensors).
    pub fn resident_bytes(&self) -> usize {
        self.shadow.as_ref().map_or(0, |e| e.resident_bytes())
    }
}

/// Greedy acceptance for one slot: `verify[j]` are the target logits
/// after feeding the j-th token of `[t, drafts...]`
/// (`verify.len() == drafts.len() + 1`). Returns `(a, next)`: the count
/// of leading drafts that match the verifier's argmax chain, and the
/// token the slot feeds next (the correction at the first mismatch, or
/// the bonus token after full acceptance). The committed stream
/// `drafts[..a] ++ [next]` equals sequential greedy decode exactly.
pub fn greedy_accept(drafts: &[u32], verify: &[Vec<f32>]) -> (usize, u32) {
    debug_assert_eq!(verify.len(), drafts.len() + 1, "one logits row per fed token");
    for (j, &d) in drafts.iter().enumerate() {
        let g = ops::argmax(&verify[j]) as u32;
        if g != d {
            return (j, g);
        }
    }
    (drafts.len(), ops::argmax(&verify[drafts.len()]) as u32)
}

/// The drafting loop, batched across slots: draft step `j` feeds every
/// slot still within its budget (`ks[i] > j`) through one
/// weight-stationary pass on the draft engine, and extends that slot's
/// proposal chain greedily. `cur0[i]` is slot `i`'s input token;
/// `pending` holds each slot's committed-but-unmirrored catch-up tokens
/// (drained here for every slot that drafts — they ride the FIRST draft
/// pass as extra positions, costing no extra weight stream). The draft
/// KV mirrors advance by `pending + ks[i]` positions. Returns the
/// proposal lists (len `ks[i]` each).
pub fn draft_tokens(
    draft: &NativeEngine,
    kv: &mut DraftKv,
    ws: &mut EngineWs,
    slots: &[usize],
    pending: &mut [Vec<u32>],
    cur0: &[u32],
    ks: &[usize],
) -> Vec<Vec<u32>> {
    let n = slots.len();
    debug_assert_eq!(n, cur0.len());
    debug_assert_eq!(n, ks.len());
    let k_max = ks.iter().copied().max().unwrap_or(0);
    let mut drafts: Vec<Vec<u32>> = vec![Vec::new(); n];
    if k_max == 0 {
        return drafts;
    }
    let mut cur = cur0.to_vec();
    // first draft pass: catch-up tokens + the input token per slot, as
    // one multi-position group each
    {
        let mut sel: Vec<usize> = Vec::new();
        let mut groups_store: Vec<Vec<u32>> = Vec::new();
        for i in 0..n {
            if ks[i] > 0 {
                let mut g = std::mem::take(&mut pending[slots[i]]);
                g.push(cur[i]);
                sel.push(slots[i]);
                groups_store.push(g);
            }
        }
        let groups: Vec<&[u32]> = groups_store.iter().map(|g| g.as_slice()).collect();
        let logits = kv.step_multi(draft, &sel, &groups, ws);
        let mut li = 0usize;
        for i in 0..n {
            if ks[i] > 0 {
                let t = ops::argmax(&logits[li]) as u32;
                drafts[i].push(t);
                cur[i] = t;
                li += 1;
            }
        }
    }
    // remaining draft steps: single position per still-drafting slot
    for j in 1..k_max {
        let mut sel: Vec<usize> = Vec::new();
        let mut toks: Vec<u32> = Vec::new();
        for i in 0..n {
            if ks[i] > j {
                sel.push(slots[i]);
                toks.push(cur[i]);
            }
        }
        if sel.is_empty() {
            break;
        }
        let logits = kv.step(draft, &sel, &toks, ws);
        let mut li = 0usize;
        for i in 0..n {
            if ks[i] > j {
                let t = ops::argmax(&logits[li]) as u32;
                drafts[i].push(t);
                cur[i] = t;
                li += 1;
            }
        }
    }
    drafts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_for(argmax: usize, vocab: usize) -> Vec<f32> {
        let mut l = vec![0f32; vocab];
        l[argmax] = 5.0;
        l
    }

    #[test]
    fn greedy_accept_full_partial_and_none() {
        // verifier chain: argmax after t is 7, after 7 is 3, after 3 is 9
        let verify = vec![logits_for(7, 16), logits_for(3, 16), logits_for(9, 16)];
        // full acceptance: drafts match the chain, bonus token follows
        assert_eq!(greedy_accept(&[7, 3], &verify), (2, 9));
        // first mismatch: correction replaces the draft
        assert_eq!(greedy_accept(&[7, 4], &verify), (1, 3));
        assert_eq!(greedy_accept(&[6, 3], &verify), (0, 7));
        // k = 0 degenerates to a plain greedy step
        assert_eq!(greedy_accept(&[], &verify[..1]), (0, 7));
    }
}
