//! Rejection-sampling acceptance for stochastic speculative decoding.
//!
//! The classic speculative-sampling rule (Leviathan et al. / Chen et
//! al.): a draft token `d ~ q(·)` is accepted with probability
//! `min(1, p(d)/q(d))`; on rejection the slot resamples from the
//! normalized residual `r(x) ∝ max(0, p(x) − q(x))`. The marginal of the
//! emitted token is then *exactly* `p` — acceptance contributes
//! `q(x)·min(1, p(x)/q(x)) = min(p(x), q(x))` and the rejection branch
//! contributes `(1 − Σ min(p, q)) · r(x) = max(0, p(x) − q(x))`, which
//! sum to `p(x)` pointwise. Speculation therefore changes how many
//! weight streams a sampled token costs, never its distribution — the
//! invariant `rust/tests/spec_sampled.rs` pins statistically.
//!
//! `p` and `q` here are *post-sampling-params* distributions (temperature
//! / top-k / top-p applied, see `crate::coordinator::sampler::
//! distribution`), so the guarantee is equality with the plain sampled
//! decode path, not with the raw softmax.

use crate::coordinator::sampler::draw_from;
use crate::util::Pcg64;

/// Probability of accepting draft token `d` given target mass `p_d` and
/// draft mass `q_d` at that token: `min(1, p_d/q_d)`. A draft token the
/// target assigns zero mass is always rejected; `q_d` is positive for
/// any token actually drawn from `q`.
pub fn accept_prob(p_d: f64, q_d: f64) -> f64 {
    if p_d <= 0.0 {
        0.0
    } else if q_d <= 0.0 || p_d >= q_d {
        1.0
    } else {
        p_d / q_d
    }
}

/// The normalized residual distribution `max(0, p − q) / Σ max(0, p − q)`
/// a rejected position resamples from. When the residual carries no mass
/// (`p == q` up to float noise — a rejection is then itself a
/// measure-zero float artifact), falls back to `p` so the draw stays
/// well-defined and still distributed as the target.
pub fn residual(p: &[f64], q: &[f64]) -> Vec<f64> {
    debug_assert_eq!(p.len(), q.len(), "residual over mismatched supports");
    let mut r: Vec<f64> = p.iter().zip(q).map(|(&pv, &qv)| (pv - qv).max(0.0)).collect();
    let mass: f64 = r.iter().sum();
    if mass <= f64::EPSILON {
        return p.to_vec();
    }
    for v in r.iter_mut() {
        *v /= mass;
    }
    r
}

/// Analytic per-position acceptance rate `Σ_x min(p(x), q(x))` — the
/// probability a draft drawn from `q` survives verification against `p`.
pub fn analytic_accept_rate(p: &[f64], q: &[f64]) -> f64 {
    p.iter().zip(q).map(|(&pv, &qv)| pv.min(qv)).sum()
}

/// Stochastic acceptance for one slot's speculative step, with the
/// target distributions supplied **lazily**: `target(j)` builds the
/// target's post-params distribution after feeding the j-th token of
/// `[t, drafts...]` (`j` ranges over `0..=drafts.len()`). The accept
/// loop consumes each row at most once and stops at the first
/// rejection, so rows past it — at real vocab sizes each a sort plus a
/// vocab-sized allocation — are never built. `drafts` were drawn
/// sequentially from the draft distributions `qs` (`qs[j]` is the draft
/// model's post-params distribution at position `j`). Returns
/// `(a, next)`: the number of leading drafts accepted, and the slot's
/// next feed token — a residual resample at the first rejection, or a
/// bonus draw from the target's last row after full acceptance. The
/// committed stream `drafts[..a] ++ [next]` is distributed exactly as
/// sequential sampling from the target.
pub fn stochastic_accept_with<F>(
    drafts: &[u32],
    qs: &[Vec<f64>],
    mut target: F,
    rng: &mut Pcg64,
) -> (usize, u32)
where
    F: FnMut(usize) -> Vec<f64>,
{
    debug_assert_eq!(qs.len(), drafts.len(), "one draft row per proposal");
    for (j, &d) in drafts.iter().enumerate() {
        let p = target(j);
        let acc = accept_prob(p[d as usize], qs[j][d as usize]);
        if rng.next_f64() >= acc {
            let r = residual(&p, &qs[j]);
            return (j, draw_from(rng, &r));
        }
    }
    (drafts.len(), draw_from(rng, &target(drafts.len())))
}

/// [`stochastic_accept_with`] over precomputed target rows
/// (`ps.len() == drafts.len() + 1`) — the shape the property tests and
/// hand-built p/q cases use.
pub fn stochastic_accept(
    drafts: &[u32],
    qs: &[Vec<f64>],
    ps: &[Vec<f64>],
    rng: &mut Pcg64,
) -> (usize, u32) {
    debug_assert_eq!(ps.len(), drafts.len() + 1, "one target row per fed token");
    stochastic_accept_with(drafts, qs, |j| ps[j].clone(), rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};
    use crate::prop_assert_ok;

    fn random_dist(g: &mut Gen, n: usize) -> Vec<f64> {
        let mut d: Vec<f64> = (0..n).map(|_| g.rng.next_f64() + 1e-3).collect();
        // sparsify some entries to exercise disjoint supports
        for v in d.iter_mut() {
            if g.rng.below(4) == 0 {
                *v = 0.0;
            }
        }
        if d.iter().sum::<f64>() <= 0.0 {
            d[0] = 1.0;
        }
        let total: f64 = d.iter().sum();
        d.into_iter().map(|v| v / total).collect()
    }

    #[test]
    fn prop_residual_is_a_valid_distribution() {
        prop_assert_ok!(check("residual_valid", 200, |g| {
            let n = g.usize_range(2, 24);
            let p = random_dist(g, n);
            let q = random_dist(g, n);
            let r = residual(&p, &q);
            if r.iter().any(|&v| v < 0.0) {
                return Err("negative residual mass".into());
            }
            let total: f64 = r.iter().sum();
            if (total - 1.0).abs() > 1e-9 {
                return Err(format!("residual sums to {total}"));
            }
            // residual support lies inside p's support
            for (i, (&rv, &pv)) in r.iter().zip(&p).enumerate() {
                if rv > 0.0 && pv <= 0.0 {
                    return Err(format!("residual puts mass at {i} where p has none"));
                }
            }
            Ok(())
        }));
    }

    #[test]
    fn residual_of_identical_distributions_falls_back_to_target() {
        let p = vec![0.25, 0.5, 0.25];
        assert_eq!(residual(&p, &p), p);
    }

    #[test]
    fn accept_prob_clamps() {
        assert_eq!(accept_prob(0.0, 0.5), 0.0);
        assert_eq!(accept_prob(0.5, 0.25), 1.0);
        assert!((accept_prob(0.2, 0.4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn acceptance_matches_analytic_rate_on_handbuilt_pairs() {
        // q proposes the wrong head token half the time: p = (0.8, 0.2),
        // q = (0.4, 0.6) → analytic rate = min(.8,.4) + min(.2,.6) = 0.6
        let p = vec![0.8, 0.2];
        let q = vec![0.4, 0.6];
        let rate = analytic_accept_rate(&p, &q);
        assert!((rate - 0.6).abs() < 1e-12);
        let mut rng = Pcg64::seeded(0xacce);
        let n = 40_000usize;
        let mut accepted = 0usize;
        let mut emitted = vec![0usize; 2];
        for _ in 0..n {
            let d = draw_from(&mut rng, &q);
            let (a, next) =
                stochastic_accept(&[d], &[q.clone()], &[p.clone(), p.clone()], &mut rng);
            accepted += a;
            // the first emitted token: the accepted draft or the residual
            // resample — must be ~ p either way
            emitted[if a == 1 { d as usize } else { next as usize }] += 1;
        }
        let emp_rate = accepted as f64 / n as f64;
        assert!((emp_rate - rate).abs() < 0.01, "empirical {emp_rate} vs analytic {rate}");
        let emp_p0 = emitted[0] as f64 / n as f64;
        assert!((emp_p0 - p[0]).abs() < 0.01, "emitted marginal {emp_p0} vs target {}", p[0]);
    }

    #[test]
    fn prop_first_emitted_token_is_target_distributed() {
        prop_assert_ok!(check("stochastic_marginal", 6, |g| {
            let n = g.usize_range(2, 8);
            let p = random_dist(g, n);
            let q = {
                // q must cover nothing beyond proposals it can draw; any
                // q works for correctness — use an independent random one
                let mut q = random_dist(g, n);
                if q.iter().sum::<f64>() <= 0.0 {
                    q = p.clone();
                }
                q
            };
            let trials = 30_000usize;
            let mut counts = vec![0usize; n];
            for _ in 0..trials {
                let d = draw_from(g.rng, &q);
                let (a, next) =
                    stochastic_accept(&[d], &[q.clone()], &[p.clone(), p.clone()], g.rng);
                counts[if a == 1 { d as usize } else { next as usize }] += 1;
            }
            let tv: f64 = counts
                .iter()
                .zip(&p)
                .map(|(&c, &pv)| (c as f64 / trials as f64 - pv).abs())
                .sum::<f64>()
                / 2.0;
            if tv > 0.02 {
                return Err(format!("total variation {tv:.4} from target"));
            }
            Ok(())
        }));
    }
}
