//! Per-slot adaptive draft depth from a running acceptance-rate EWMA.
//!
//! Drafting is only free while proposals survive verification: every
//! rejected draft cost a draft weight stream (and draft-KV pages) for
//! nothing. [`KController`] tracks each slot's acceptance rate with an
//! exponentially-weighted moving average and scales the next step's
//! draft window proportionally — `k = round(rate · k_max)`, clamped to
//! `[1, k_max]` while the rate sits above the degrade threshold (a k=0
//! step observes nothing, so it must only happen on the probed degrade
//! path below). Below [`DEGRADE_RATE`] the slot degrades to
//! plain decode (`k = 0`) but keeps probing with a single draft every
//! [`PROBE_EVERY`] steps so a slot whose text becomes draft-friendly
//! again (e.g. leaves a hard span) can climb back out.
//!
//! The controller starts optimistic (`rate = 1.0` → `k_max`): the first
//! steps measure the actual rate and the EWMA converges within a few
//! observations at `alpha = `[`EWMA_ALPHA`].

/// EWMA weight of the newest observation.
pub const EWMA_ALPHA: f64 = 0.25;

/// Acceptance rate below which a slot stops drafting (plain decode).
pub const DEGRADE_RATE: f64 = 0.125;

/// While degraded, probe with one draft every this many steps.
pub const PROBE_EVERY: usize = 16;

/// One slot's adaptive draft-depth state.
#[derive(Debug, Clone)]
pub struct KController {
    k_max: usize,
    rate: f64,
    steps_since_probe: usize,
}

impl KController {
    pub fn new(k_max: usize) -> KController {
        KController { k_max, rate: 1.0, steps_since_probe: 0 }
    }

    /// Current acceptance-rate estimate in `[0, 1]`.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Draft window for the next step, in `[0, k_max]`. Advances the
    /// probe counter, so call once per speculative step.
    pub fn next_k(&mut self) -> usize {
        if self.rate < DEGRADE_RATE {
            self.steps_since_probe += 1;
            if self.steps_since_probe >= PROBE_EVERY {
                self.steps_since_probe = 0;
                return 1.min(self.k_max);
            }
            return 0;
        }
        self.steps_since_probe = 0;
        // floor at 1 above the degrade threshold: at small k_max,
        // rounding alone could yield 0 in the band
        // [DEGRADE_RATE, 0.5/k_max) — and a k=0 step observes nothing,
        // which would freeze the estimate (and the slot) there forever.
        // (The 1.min guards a directly-constructed k_max = 0 controller
        // — the backend rejects that at config time — since
        // usize::clamp panics when min > max.)
        ((self.rate * self.k_max as f64).round() as usize).clamp(1.min(self.k_max), self.k_max)
    }

    /// Fold one step's outcome into the estimate. Steps that proposed
    /// nothing (window clamped to zero by max_seq or pool pressure)
    /// carry no acceptance signal and leave the estimate unchanged.
    /// Inputs are clamped so an adversarial `accepted > proposed` report
    /// cannot push the estimate outside `[0, 1]`.
    pub fn observe(&mut self, proposed: usize, accepted: usize) {
        if proposed == 0 {
            return;
        }
        let r = (accepted as f64 / proposed as f64).clamp(0.0, 1.0);
        self.rate = (1.0 - EWMA_ALPHA) * self.rate + EWMA_ALPHA * r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_ok;
    use crate::testing::check;

    #[test]
    fn full_acceptance_holds_k_max() {
        let mut c = KController::new(4);
        for _ in 0..50 {
            let k = c.next_k();
            assert_eq!(k, 4);
            c.observe(k, k);
        }
        assert!((c.rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_acceptance_degrades_to_plain_decode_with_probes() {
        let mut c = KController::new(4);
        let mut ks = Vec::new();
        for _ in 0..200 {
            let k = c.next_k();
            ks.push(k);
            c.observe(k, 0);
        }
        // converges to 0 with periodic single-draft probes
        let tail = &ks[ks.len() - 3 * PROBE_EVERY..];
        assert!(tail.iter().all(|&k| k <= 1), "{tail:?}");
        assert!(tail.contains(&0), "never degraded: {tail:?}");
        assert!(tail.contains(&1), "never probed: {tail:?}");
        assert_eq!(
            tail.iter().filter(|&&k| k == 1).count(),
            3,
            "one probe per {PROBE_EVERY} steps: {tail:?}"
        );
    }

    #[test]
    fn recovers_after_a_hard_span() {
        let mut c = KController::new(4);
        for _ in 0..100 {
            let k = c.next_k();
            c.observe(k, 0);
        }
        assert_eq!(c.next_k(), 0, "degraded after sustained rejection");
        // acceptance returns: probes pull the estimate back up
        for _ in 0..200 {
            let k = c.next_k();
            c.observe(k, k);
        }
        assert_eq!(c.next_k(), 4, "failed to climb back to k_max");
    }

    #[test]
    fn small_k_max_never_freezes_between_degrade_and_probe() {
        // regression: with k_max = 1, a rate in [DEGRADE_RATE, 0.5)
        // would round to 0 without entering the probe branch — the slot
        // must keep drafting (k = 1) so the estimate stays live
        for k_max in 1..=3usize {
            let mut c = KController::new(k_max);
            for step in 0..300 {
                let k = c.next_k();
                if c.rate() >= DEGRADE_RATE {
                    assert!(k >= 1, "k_max={k_max} step={step}: live slot stopped drafting");
                }
                // alternate rejection/acceptance so the rate hovers
                c.observe(k, if step % 2 == 0 { 0 } else { k });
            }
            // and it can still climb back to full depth
            for _ in 0..100 {
                let k = c.next_k();
                c.observe(k, k);
            }
            assert_eq!(c.next_k(), k_max, "k_max={k_max} failed to recover");
        }
    }

    #[test]
    fn prop_k_never_leaves_bounds_under_adversarial_streams() {
        prop_assert_ok!(check("adaptive_k_bounds", 100, |g| {
            let k_max = g.usize_range(1, 8);
            let mut c = KController::new(k_max);
            for _ in 0..300 {
                let k = c.next_k();
                if k > k_max {
                    return Err(format!("k={k} above k_max={k_max}"));
                }
                // adversarial: proposed/accepted unrelated to k, accepted
                // may even exceed proposed
                let proposed = g.usize_range(0, 8);
                let accepted = g.usize_range(0, 12);
                c.observe(proposed, accepted);
                if !(0.0..=1.0).contains(&c.rate()) {
                    return Err(format!("rate {} outside [0, 1]", c.rate()));
                }
            }
            Ok(())
        }));
    }
}
