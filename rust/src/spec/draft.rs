//! Draft-side KV state for self-speculative decoding.
//!
//! The draft engine attends over its *own* K/V history (its
//! representations differ from the target's), so every speculative slot
//! carries a second, rollback-able KV mirror: the same committed token
//! sequence, draft-engine values. [`DraftKv`] manages those mirrors with
//! the same paging discipline as the target backend — one dense cache
//! per slot, or a private page pool. The paged pool runs with the prefix
//! cache disabled: draft pages are transient scratch that is truncated
//! every step, never shared across admissions.

use crate::engine::kv::{
    KvCache, KvPagePool, KvPoolConfig, PagedKv, PagedSlotBatch, ParkedKv, SlotBatch,
};
use crate::engine::native::{EngineWs, NativeEngine};
use crate::model::Config;
use anyhow::{bail, Context, Result};

/// The draft KV mirrors of one open batch, addressed by target slot id.
pub enum DraftKv {
    /// No batch open yet.
    Unopened,
    /// One dense full-capacity cache per occupied slot.
    Dense { slots: Vec<Option<KvCache>> },
    /// Pool-backed mirrors (the backend's paged mode).
    Paged { pool: KvPagePool, slots: Vec<Option<PagedKv>> },
}

impl DraftKv {
    pub fn open_dense(&mut self, capacity: usize) {
        *self = DraftKv::Dense { slots: (0..capacity).map(|_| None).collect() };
    }

    pub fn open_paged(&mut self, cfg: KvPoolConfig, capacity: usize) {
        *self = DraftKv::Paged {
            pool: KvPagePool::new(cfg),
            slots: (0..capacity).map(|_| None).collect(),
        };
    }

    /// Committed draft length of `slot` (None when unoccupied).
    pub fn len(&self, slot: usize) -> Option<usize> {
        match self {
            DraftKv::Unopened => None,
            DraftKv::Dense { slots } => slots.get(slot).and_then(|s| s.as_ref()).map(|kv| kv.len),
            DraftKv::Paged { slots, .. } => {
                slots.get(slot).and_then(|s| s.as_ref()).map(|kv| kv.len())
            }
        }
    }

    /// Drop `slot`'s mirror (pages return to the pool). Unoccupied slots
    /// are ignored so release stays idempotent with the target's.
    pub fn release(&mut self, slot: usize) {
        match self {
            DraftKv::Unopened => {}
            DraftKv::Dense { slots } => {
                if let Some(s) = slots.get_mut(slot) {
                    *s = None;
                }
            }
            DraftKv::Paged { pool, slots } => {
                if let Some(s) = slots.get_mut(slot) {
                    if let Some(mut kv) = s.take() {
                        pool.release_kv(&mut kv);
                    }
                }
            }
        }
    }

    /// Create an **empty** mirror for a newly admitted `slot`. No engine
    /// work happens here (and on the paged store, no page allocation):
    /// the prompt queues in the slot's lazy catch-up list and is
    /// mirrored by the first draft pass of the slot's first speculative
    /// step — so slots that never speculate (sampled requests) pay no
    /// draft compute and, on the paged store, no draft-KV pages at all.
    pub fn occupy(&mut self, cfg: &Config, slot: usize) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                if slot >= slots.len() {
                    bail!("draft kv: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("draft kv: slot {slot} is already occupied");
                }
                slots[slot] =
                    Some(KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim()));
            }
            DraftKv::Paged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("draft kv: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("draft kv: slot {slot} is already occupied");
                }
                slots[slot] = Some(pool.new_kv(cfg.max_seq));
            }
        }
        Ok(())
    }

    /// Make the next `n` positions of `slot` writable (page mapping plus
    /// copy-on-write on the paged store; a capacity check on dense).
    pub fn ensure(&mut self, slot: usize, n: usize) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                let kv = slots
                    .get(slot)
                    .and_then(|s| s.as_ref())
                    .with_context(|| format!("draft kv: slot {slot} is not occupied"))?;
                if kv.remaining() < n {
                    bail!(
                        "draft kv: slot {slot} has {} positions left, needs {n}",
                        kv.remaining()
                    );
                }
                Ok(())
            }
            DraftKv::Paged { pool, slots } => {
                let kv = slots
                    .get_mut(slot)
                    .and_then(|s| s.as_mut())
                    .with_context(|| format!("draft kv: slot {slot} is not occupied"))?;
                let len = kv.len();
                pool.ensure_range(kv, len, len + n)
            }
        }
    }

    /// Roll `slot` back to `len` committed positions (speculative
    /// rollback; whole pages past the boundary — including over-reserved
    /// ones — return to the pool).
    pub fn truncate(&mut self, slot: usize, len: usize) {
        match self {
            DraftKv::Unopened => {}
            DraftKv::Dense { slots } => {
                if let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) {
                    kv.truncate(len);
                }
            }
            DraftKv::Paged { pool, slots } => {
                if let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) {
                    pool.truncate_kv(kv, len);
                }
            }
        }
    }

    /// Swap `slot`'s mirror out into a host buffer and vacate the slot
    /// (paged mirrors release their pages). `None` when the slot has no
    /// mirror — a slot that never speculated has nothing to park.
    pub fn park(&mut self, slot: usize) -> Option<ParkedKv> {
        match self {
            DraftKv::Unopened => None,
            DraftKv::Dense { slots } => {
                slots.get_mut(slot).and_then(|s| s.take()).map(|kv| kv.park())
            }
            DraftKv::Paged { pool, slots } => {
                slots.get_mut(slot).and_then(|s| s.take()).map(|mut kv| pool.park_kv(&mut kv))
            }
        }
    }

    /// Restore a parked mirror into the vacated `slot` bit-exactly. On
    /// failure (paged pool cannot supply the pages) the slot is left
    /// vacant and the parking buffer remains valid for a later retry.
    pub fn unpark(&mut self, cfg: &Config, slot: usize, parked: &ParkedKv) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { .. } => {
                self.occupy(cfg, slot)?;
                let DraftKv::Dense { slots } = self else { unreachable!() };
                slots[slot].as_mut().expect("just occupied").unpark(parked);
                Ok(())
            }
            DraftKv::Paged { pool, slots } => {
                if slot >= slots.len() {
                    bail!("draft kv: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("draft kv: slot {slot} is already occupied");
                }
                slots[slot] = Some(pool.unpark_kv(parked, cfg.max_seq)?);
                Ok(())
            }
        }
    }

    /// One batched draft step over the listed slots (`toks[i]` feeds
    /// `sel[i]`): the draft analogue of the backend's weight-stationary
    /// decode — draft weights stream once per draft step across all
    /// drafting slots. Returns next-token logits per listed slot.
    pub fn step(
        &mut self,
        engine: &NativeEngine,
        sel: &[usize],
        toks: &[u32],
        ws: &mut EngineWs,
    ) -> Vec<Vec<f32>> {
        let groups: Vec<&[u32]> = toks.iter().map(std::slice::from_ref).collect();
        self.step_multi(engine, sel, &groups, ws)
    }

    /// Multi-position batched draft step: slot `sel[i]` consumes the
    /// `groups[i]` tokens in one pass (the lazy catch-up path — tokens
    /// the target committed while the mirror lagged ride the first
    /// draft pass as extra rows, costing no extra weight stream).
    /// Returns each listed slot's **last-position** logits.
    pub fn step_multi(
        &mut self,
        engine: &NativeEngine,
        sel: &[usize],
        groups: &[&[u32]],
        ws: &mut EngineWs,
    ) -> Vec<Vec<f32>> {
        match self {
            DraftKv::Unopened => panic!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                let mut sb = SlotBatch::select(slots, sel);
                engine
                    .step_batch_multi(groups, &mut sb, ws, false)
                    .into_iter()
                    .map(|mut per| per.pop().expect("one logits row"))
                    .collect()
            }
            DraftKv::Paged { pool, slots } => {
                let mut sb = PagedSlotBatch::select(pool, slots, sel);
                engine
                    .step_batch_multi(groups, &mut sb, ws, false)
                    .into_iter()
                    .map(|mut per| per.pop().expect("one logits row"))
                    .collect()
            }
        }
    }
}
