//! Draft-side KV state for self-speculative decoding.
//!
//! The draft engine writes its *own* K/V representations for the tokens
//! it proposes, so every speculating slot carries a rollback-able draft
//! view. On the (default) paged store that view is **not** a second
//! copy of the history: draft and target agree on every committed
//! position, so the mirror *aliases* the target slot's pages out of the
//! ONE shared [`KvPagePool`] ([`DraftKv::Shared`]) — a refcount bump
//! per page, no copy — and only pays real pages for the positions the
//! draft pass appends: one copy-on-write of the shared boundary page
//! plus the fresh window pages, all returned to the pool at the end of
//! the step ([`KvPagePool::retain_shared_prefix`]). Draft KV cost per
//! speculating slot is therefore ~1 page of transient scratch, not a
//! second KV budget.
//!
//! Because the mirror's between-step state is a pure function of the
//! target's (aliases of its committed pages), there is nothing to
//! serialize on preemption ([`DraftKv::park`] just drops the aliases)
//! and nothing to re-prefill on admission — registration is an empty
//! view that syncs to the target's page table on the slot's first
//! speculative step ([`DraftKv::sync_to_target`]).
//!
//! The dense baseline ([`DraftKv::Dense`]) keeps one private
//! full-capacity cache per slot and the lazy catch-up discipline: the
//! prompt (and any plain-decoded tokens) queue per slot and ride the
//! first draft pass.

use crate::engine::kv::{KvCache, KvPagePool, PagedKv, PagedSlotBatch, ParkedKv, SlotBatch};
use crate::engine::native::{EngineWs, NativeEngine};
use crate::model::Config;
use anyhow::{bail, Context, Result};

/// The draft KV mirrors of one open batch, addressed by target slot id.
pub enum DraftKv {
    /// No batch open yet.
    Unopened,
    /// One dense full-capacity cache per occupied slot (the dense
    /// baseline: private storage, lazy catch-up queues).
    Dense { slots: Vec<Option<KvCache>> },
    /// Pool-backed mirrors that **alias the target's pages in the one
    /// shared pool** (the backend's paged mode). The pool itself lives
    /// in the batch state, so every operation that touches pages takes
    /// it as a parameter.
    Shared { slots: Vec<Option<PagedKv>> },
}

impl DraftKv {
    pub fn open_dense(&mut self, capacity: usize) {
        *self = DraftKv::Dense { slots: (0..capacity).map(|_| None).collect() };
    }

    /// Open shared-pool mirrors: empty per-slot views into the target's
    /// pool. No pages are held until a slot's first speculative step
    /// aliases the target's committed table.
    pub fn open_shared(&mut self, capacity: usize) {
        *self = DraftKv::Shared { slots: (0..capacity).map(|_| None).collect() };
    }

    /// Committed draft length of `slot` (None when unoccupied).
    pub fn len(&self, slot: usize) -> Option<usize> {
        match self {
            DraftKv::Unopened => None,
            DraftKv::Dense { slots } => slots.get(slot).and_then(|s| s.as_ref()).map(|kv| kv.len),
            DraftKv::Shared { slots } => {
                slots.get(slot).and_then(|s| s.as_ref()).map(|kv| kv.len())
            }
        }
    }

    /// Drop `slot`'s mirror (aliased pages drop their reference back to
    /// the shared pool). Unoccupied slots are ignored so release stays
    /// idempotent with the target's.
    pub fn release(&mut self, slot: usize, pool: Option<&mut KvPagePool>) {
        match self {
            DraftKv::Unopened => {}
            DraftKv::Dense { slots } => {
                if let Some(s) = slots.get_mut(slot) {
                    *s = None;
                }
            }
            DraftKv::Shared { slots } => {
                if let Some(s) = slots.get_mut(slot) {
                    if let Some(mut kv) = s.take() {
                        let pool = pool.expect("shared draft mirrors need the target pool");
                        pool.release_kv(&mut kv);
                    }
                }
            }
        }
    }

    /// Create an **empty** mirror for a newly admitted `slot`. No engine
    /// work and no page allocation happens here: a shared mirror aliases
    /// the target's committed pages on the slot's first speculative step
    /// (so slots that never speculate pay no draft compute and no draft
    /// pages), and a dense mirror fills from its lazy catch-up queue.
    pub fn occupy(&mut self, cfg: &Config, slot: usize) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                if slot >= slots.len() {
                    bail!("draft kv: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("draft kv: slot {slot} is already occupied");
                }
                slots[slot] =
                    Some(KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim()));
            }
            DraftKv::Shared { slots } => {
                if slot >= slots.len() {
                    bail!("draft kv: slot {slot} out of range ({} slots)", slots.len());
                }
                if slots[slot].is_some() {
                    bail!("draft kv: slot {slot} is already occupied");
                }
                slots[slot] = Some(PagedKv::empty(cfg.max_seq));
            }
        }
        Ok(())
    }

    /// Sync a shared mirror to the target's committed state: alias the
    /// target's pages covering `0..target.len()` (refcount bumps, no
    /// copy — already-shared pages are kept, diverged ones released) so
    /// the draft pass attends over the exact committed history. This is
    /// what replaced the private mirror's catch-up re-prefill: the
    /// mirror is *always* caught up, one page-table sync away.
    ///
    /// Panics when the mirror is not [`DraftKv::Shared`] — dense
    /// mirrors sync through their catch-up queues.
    pub fn sync_to_target(&mut self, pool: &mut KvPagePool, slot: usize, target: &PagedKv) {
        let DraftKv::Shared { slots } = self else {
            panic!("sync_to_target on a non-shared draft mirror");
        };
        let kv = slots
            .get_mut(slot)
            .and_then(|s| s.as_mut())
            .expect("sync_to_target: slot has no mirror");
        pool.alias_kv(kv, target, target.len());
    }

    /// End-of-step rollback for a shared mirror: release every page
    /// that diverged from the target's (post-truncate) table — the
    /// copy-on-write boundary page and the draft window pages — keeping
    /// only the still-shared alias prefix. Rejection and acceptance are
    /// the same operation here: the shared boundary simply advances as
    /// the target commits more full pages.
    pub fn retain_target_prefix(&mut self, pool: &mut KvPagePool, slot: usize, target: &PagedKv) {
        let DraftKv::Shared { slots } = self else {
            panic!("retain_target_prefix on a non-shared draft mirror");
        };
        if let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) {
            pool.retain_shared_prefix(kv, target);
        }
    }

    /// Make the next `n` positions of `slot` writable. On the shared
    /// store this privatizes the aliased boundary page (copy-on-write)
    /// and maps fresh window pages out of the one shared pool; on dense
    /// it is a capacity check.
    pub fn ensure(&mut self, slot: usize, n: usize, pool: Option<&mut KvPagePool>) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                let kv = slots
                    .get(slot)
                    .and_then(|s| s.as_ref())
                    .with_context(|| format!("draft kv: slot {slot} is not occupied"))?;
                if kv.remaining() < n {
                    bail!(
                        "draft kv: slot {slot} has {} positions left, needs {n}",
                        kv.remaining()
                    );
                }
                Ok(())
            }
            DraftKv::Shared { slots } => {
                let kv = slots
                    .get_mut(slot)
                    .and_then(|s| s.as_mut())
                    .with_context(|| format!("draft kv: slot {slot} is not occupied"))?;
                let pool = pool.expect("shared draft mirrors need the target pool");
                let len = kv.len();
                pool.ensure_range(kv, len, len + n)
            }
        }
    }

    /// Roll `slot` back to `len` committed positions (dense speculative
    /// rollback). Shared mirrors roll back against the target's table
    /// instead — see [`DraftKv::retain_target_prefix`].
    pub fn truncate(&mut self, slot: usize, len: usize) {
        match self {
            DraftKv::Unopened | DraftKv::Shared { .. } => {}
            DraftKv::Dense { slots } => {
                if let Some(kv) = slots.get_mut(slot).and_then(|s| s.as_mut()) {
                    kv.truncate(len);
                }
            }
        }
    }

    /// Swap `slot`'s mirror out and vacate the slot. A dense mirror is
    /// copied into a host buffer; a **shared mirror has nothing to
    /// serialize** — its state is derivable from the target's (aliases
    /// of committed pages), so parking just drops the page references
    /// and returns `None`. The target's pages are never written twice
    /// to the parking buffer, and restore re-aliases bit-identically on
    /// the next speculative step.
    pub fn park(&mut self, slot: usize, pool: Option<&mut KvPagePool>) -> Option<ParkedKv> {
        match self {
            DraftKv::Unopened => None,
            DraftKv::Dense { slots } => {
                slots.get_mut(slot).and_then(|s| s.take()).map(|kv| kv.park())
            }
            DraftKv::Shared { slots } => {
                if let Some(mut kv) = slots.get_mut(slot).and_then(|s| s.take()) {
                    let pool = pool.expect("shared draft mirrors need the target pool");
                    pool.release_kv(&mut kv);
                }
                None
            }
        }
    }

    /// Restore a parked dense mirror into the vacated `slot` bit-exactly
    /// (shared mirrors park as `None` and resume via
    /// [`DraftKv::occupy`] + first-step sync).
    pub fn unpark(&mut self, cfg: &Config, slot: usize, parked: &ParkedKv) -> Result<()> {
        match self {
            DraftKv::Unopened => bail!("draft kv: no open batch"),
            DraftKv::Dense { .. } => {
                self.occupy(cfg, slot)?;
                let DraftKv::Dense { slots } = self else { unreachable!() };
                slots[slot].as_mut().expect("just occupied").unpark(parked);
                Ok(())
            }
            DraftKv::Shared { .. } => {
                // nothing was serialized for a shared mirror; an empty
                // view re-aliases the restored target on the next step
                self.occupy(cfg, slot)
            }
        }
    }

    /// One batched draft step over the listed slots (`toks[i]` feeds
    /// `sel[i]`): the draft analogue of the backend's weight-stationary
    /// decode — draft weights stream once per draft step across all
    /// drafting slots. Returns next-token logits per listed slot.
    pub fn step(
        &mut self,
        engine: &NativeEngine,
        sel: &[usize],
        toks: &[u32],
        ws: &mut EngineWs,
        pool: Option<&mut KvPagePool>,
    ) -> Vec<Vec<f32>> {
        let groups: Vec<&[u32]> = toks.iter().map(std::slice::from_ref).collect();
        self.step_multi(engine, sel, &groups, ws, pool)
    }

    /// Multi-position batched draft step: slot `sel[i]` consumes the
    /// `groups[i]` tokens in one pass (on the dense store, catch-up
    /// tokens the target committed while the mirror lagged ride the
    /// first draft pass as extra rows; shared mirrors are always caught
    /// up by the page-table sync and feed single positions). Returns
    /// each listed slot's **last-position** logits.
    pub fn step_multi(
        &mut self,
        engine: &NativeEngine,
        sel: &[usize],
        groups: &[&[u32]],
        ws: &mut EngineWs,
        pool: Option<&mut KvPagePool>,
    ) -> Vec<Vec<f32>> {
        match self {
            DraftKv::Unopened => panic!("draft kv: no open batch"),
            DraftKv::Dense { slots } => {
                let mut sb = SlotBatch::select(slots, sel);
                engine
                    .step_batch_multi(groups, &mut sb, ws, false)
                    .into_iter()
                    .map(|mut per| per.pop().expect("one logits row"))
                    .collect()
            }
            DraftKv::Shared { slots } => {
                let pool = pool.expect("shared draft mirrors need the target pool");
                let mut sb = PagedSlotBatch::select(pool, slots, sel);
                engine
                    .step_batch_multi(groups, &mut sb, ws, false)
                    .into_iter()
                    .map(|mut per| per.pop().expect("one logits row"))
                    .collect()
            }
        }
    }
}
