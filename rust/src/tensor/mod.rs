//! Dense tensor substrate: owned f32 tensors plus the BLAS-free linear
//! algebra and NN ops the native engine is built on.

pub mod ops;
pub mod simd;
pub mod tensor;

pub use tensor::Tensor;
