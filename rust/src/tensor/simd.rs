//! Vectorized lane kernels for the decode hot loop, plus the runtime
//! path control that keeps them bit-identical to the scalar reference.
//!
//! Every quantized kernel in this crate accumulates in one **canonical
//! lane order**: for each packed 32-bit word, code `j` multiplies
//! activation lane `j` into an independent accumulator `lanes[j]`
//! (separate multiply and add — never an FMA), and a group's eight lane
//! accumulators reduce through the fixed tree
//! `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` ([`reduce8`]). The scalar
//! implementations below *are* that definition; the AVX2/NEON variants
//! perform the identical float operations per lane in the identical
//! order, so `simd` and `scalar` paths agree **element-exactly** — the
//! scalar path stays the bit-exactness oracle for every identity test.
//!
//! The vector paths compile only with the `simd` cargo feature and are
//! runtime-detected (AVX2 on x86_64, NEON on aarch64); without the
//! feature, on other arches, or when detection fails, every entry point
//! falls back to the scalar lane kernels. `FBQ_SIMD=0` disables the
//! vector path at runtime; [`force_path`] pins it programmatically
//! (bench quadrants, oracle tests).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which lane-kernel implementation a call should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// Portable scalar lane kernels — the bit-exactness oracle.
    Scalar,
    /// Runtime-detected AVX2/NEON kernels (falls back to scalar when
    /// the `simd` feature is off or the CPU lacks the extension).
    Simd,
}

/// 0 = default (env + detection), 1 = force scalar, 2 = force simd.
static FORCE: AtomicU8 = AtomicU8::new(0);

/// Pin the lane-kernel path for the whole process (`None` restores the
/// default of "vectorize when available"). Bench quadrants and the
/// scalar-vs-SIMD oracle tests use this; concurrent callers see the
/// flip immediately, and both settings are always *correct* — only the
/// instruction sequence changes, never the result.
pub fn force_path(p: Option<Path>) {
    let v = match p {
        None => 0,
        Some(Path::Scalar) => 1,
        Some(Path::Simd) => 2,
    };
    FORCE.store(v, Ordering::SeqCst);
}

/// True when a vector extension is compiled in *and* present at runtime.
pub fn available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        return is_x86_feature_detected!("avx2");
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        return std::arch::is_aarch64_feature_detected!("neon");
    }
    #[allow(unreachable_code)]
    false
}

fn default_is_simd() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if !available() {
            return false;
        }
        match std::env::var("FBQ_SIMD") {
            Ok(v) => v.trim() != "0",
            Err(_) => true,
        }
    })
}

/// The path the lane kernels will take right now.
#[inline]
pub fn active() -> Path {
    match FORCE.load(Ordering::Relaxed) {
        1 => Path::Scalar,
        2 => {
            if available() {
                Path::Simd
            } else {
                Path::Scalar
            }
        }
        _ => {
            if default_is_simd() {
                Path::Simd
            } else {
                Path::Scalar
            }
        }
    }
}

/// The canonical 8-lane reduction tree shared by the scalar and vector
/// paths: `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
#[inline(always)]
pub fn reduce8(l: &[f32]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Unpack the eight 4-bit codes of one packed word as floats (code `j`
/// occupies bits `[4j, 4j+4)`). Mirrors `quant::pack::word_codes`;
/// duplicated here so the lane kernels are self-contained.
#[inline(always)]
fn word_lanes(word: u32) -> [f32; 8] {
    [
        (word & 0xF) as f32,
        ((word >> 4) & 0xF) as f32,
        ((word >> 8) & 0xF) as f32,
        ((word >> 12) & 0xF) as f32,
        ((word >> 16) & 0xF) as f32,
        ((word >> 20) & 0xF) as f32,
        ((word >> 24) & 0xF) as f32,
        ((word >> 28) & 0xF) as f32,
    ]
}

// ---------------------------------------------------------------------------
// dot
// ---------------------------------------------------------------------------

/// Dense dot product in the canonical lane order, dispatched on
/// [`active`]. Scalar and vector paths return bit-identical results.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_path(a, b, active())
}

/// [`dot`] with an explicit path (oracle tests compare the two).
#[inline]
pub fn dot_path(a: &[f32], b: &[f32], path: Path) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match path {
        Path::Scalar => dot_scalar(a, b),
        Path::Simd => dot_simd(a, b),
    }
}

/// Scalar reference: 8 independent lane accumulators over the main
/// body, [`reduce8`], then a sequential tail for `len % 8` elements.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 8;
    let mut l = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        l[0] += a[i] * b[i];
        l[1] += a[i + 1] * b[i + 1];
        l[2] += a[i + 2] * b[i + 2];
        l[3] += a[i + 3] * b[i + 3];
        l[4] += a[i + 4] * b[i + 4];
        l[5] += a[i + 5] * b[i + 5];
        l[6] += a[i + 6] * b[i + 6];
        l[7] += a[i + 7] * b[i + 7];
    }
    let mut acc = reduce8(&l);
    for i in chunks * 8..n {
        acc += a[i] * b[i];
    }
    acc
}

#[inline]
fn dot_simd(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return unsafe { avx2::dot(a, b) };
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return unsafe { neon::dot(a, b) };
        }
    }
    dot_scalar(a, b)
}

// ---------------------------------------------------------------------------
// fused unpack + lane-accumulate over one quantization group
// ---------------------------------------------------------------------------

/// Unpack every word of one quantization group and lane-accumulate the
/// code/activation products for `m` activation rows:
///
/// `lanes[i*8 + j] += code_j(words[wi]) * xs[i*xstride + off + wi*8 + j]`
///
/// for all `wi` (ascending) and slots `i`. The caller owns zeroing
/// `lanes`, reducing each row's 8 lanes via [`reduce8`], and applying
/// the per-group scale/zero identity. Scalar and vector paths perform
/// identical per-lane float ops in identical order.
#[inline]
pub fn accum_group(
    words: &[u32],
    xs: &[f32],
    m: usize,
    xstride: usize,
    off: usize,
    lanes: &mut [f32],
    path: Path,
) {
    debug_assert!(lanes.len() >= 8 * m);
    debug_assert!(xs.len() >= (m - 1) * xstride + off + words.len() * 8);
    match path {
        Path::Scalar => accum_group_scalar(words, xs, m, xstride, off, lanes),
        Path::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                if is_x86_feature_detected!("avx2") {
                    return unsafe { avx2::accum_group(words, xs, m, xstride, off, lanes) };
                }
            }
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    return unsafe { neon::accum_group(words, xs, m, xstride, off, lanes) };
                }
            }
            accum_group_scalar(words, xs, m, xstride, off, lanes)
        }
    }
}

fn accum_group_scalar(
    words: &[u32],
    xs: &[f32],
    m: usize,
    xstride: usize,
    off: usize,
    lanes: &mut [f32],
) {
    for i in 0..m {
        let l = &mut lanes[i * 8..i * 8 + 8];
        let xrow = i * xstride + off;
        for (wi, &w) in words.iter().enumerate() {
            let codes = word_lanes(w);
            let xb = &xs[xrow + wi * 8..xrow + wi * 8 + 8];
            l[0] += codes[0] * xb[0];
            l[1] += codes[1] * xb[1];
            l[2] += codes[2] * xb[2];
            l[3] += codes[3] * xb[3];
            l[4] += codes[4] * xb[4];
            l[5] += codes[5] * xb[5];
            l[6] += codes[6] * xb[6];
            l[7] += codes[7] * xb[7];
        }
    }
}

// ---------------------------------------------------------------------------
// dequantize one group
// ---------------------------------------------------------------------------

/// Dequantize one group's packed words:
/// `out[wi*8 + j] = (code_j(words[wi]) - zero) * scale`.
/// Element-wise, so scalar and vector paths are trivially bit-identical.
#[inline]
pub fn dequant_group(words: &[u32], scale: f32, zero: f32, out: &mut [f32], path: Path) {
    debug_assert!(out.len() >= words.len() * 8);
    match path {
        Path::Scalar => dequant_group_scalar(words, scale, zero, out),
        Path::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                if is_x86_feature_detected!("avx2") {
                    return unsafe { avx2::dequant_group(words, scale, zero, out) };
                }
            }
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    return unsafe { neon::dequant_group(words, scale, zero, out) };
                }
            }
            dequant_group_scalar(words, scale, zero, out)
        }
    }
}

fn dequant_group_scalar(words: &[u32], scale: f32, zero: f32, out: &mut [f32]) {
    for (wi, &w) in words.iter().enumerate() {
        let codes = word_lanes(w);
        let ob = &mut out[wi * 8..wi * 8 + 8];
        for j in 0..8 {
            ob[j] = (codes[j] - zero) * scale;
        }
    }
}

// ---------------------------------------------------------------------------
// software prefetch
// ---------------------------------------------------------------------------

/// Prefetch the packed code words of an upcoming row into L1 so the
/// unpack loop streams from cache instead of stalling on DRAM. No-op
/// off x86_64 (aarch64 has no stable prefetch intrinsic) and without
/// the `simd` feature.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline(always)]
pub fn prefetch_words(words: &[u32]) {
    use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
    let base = words.as_ptr() as *const i8;
    let bytes = std::mem::size_of_val(words);
    let mut off = 0usize;
    while off < bytes {
        // SAFETY: `base + off` stays inside the `words` allocation.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(base.add(off)) };
        off += 64;
    }
}

/// Prefetch stub for targets without a stable prefetch intrinsic.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline(always)]
pub fn prefetch_words(_words: &[u32]) {}

// ---------------------------------------------------------------------------
// AVX2
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-lane accumulate + the shared scalar tail/reduction; lane `j`
    /// of `acc` sees exactly the ops of `dot_scalar`'s `l[j]`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(c * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(c * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), acc);
        let mut s = super::reduce8(&l);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// Word unpack via variable right-shift + mask, then lane-parallel
    /// mul/add (kept separate so no FMA contraction can occur).
    #[target_feature(enable = "avx2")]
    pub unsafe fn accum_group(
        words: &[u32],
        xs: &[f32],
        m: usize,
        xstride: usize,
        off: usize,
        lanes: &mut [f32],
    ) {
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        let mask = _mm256_set1_epi32(0xF);
        for i in 0..m {
            let lp = lanes.as_mut_ptr().add(i * 8);
            let mut acc = _mm256_loadu_ps(lp);
            let xbase = xs.as_ptr().add(i * xstride + off);
            for (wi, &w) in words.iter().enumerate() {
                let wv = _mm256_set1_epi32(w as i32);
                let codes =
                    _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(wv, shifts), mask));
                let xv = _mm256_loadu_ps(xbase.add(wi * 8));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(codes, xv));
            }
            _mm256_storeu_ps(lp, acc);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_group(words: &[u32], scale: f32, zero: f32, out: &mut [f32]) {
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        let mask = _mm256_set1_epi32(0xF);
        let vz = _mm256_set1_ps(zero);
        let vs = _mm256_set1_ps(scale);
        for (wi, &w) in words.iter().enumerate() {
            let wv = _mm256_set1_epi32(w as i32);
            let codes = _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(wv, shifts), mask));
            let v = _mm256_mul_ps(_mm256_sub_ps(codes, vz), vs);
            _mm256_storeu_ps(out.as_mut_ptr().add(wi * 8), v);
        }
    }
}

// ---------------------------------------------------------------------------
// NEON
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use std::arch::aarch64::*;

    /// Negative vector shifts = logical right shifts for the unpack.
    const SH_LO: [i32; 4] = [0, -4, -8, -12];
    const SH_HI: [i32; 4] = [-16, -20, -24, -28];

    /// Two 4-lane halves mirror `dot_scalar`'s `l[0..4]` / `l[4..8]`;
    /// `vmulq`+`vaddq` stay separate (never `vmlaq`, which fuses).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * 8);
            let pb = b.as_ptr().add(c * 8);
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let mut l = [0f32; 8];
        vst1q_f32(l.as_mut_ptr(), acc0);
        vst1q_f32(l.as_mut_ptr().add(4), acc1);
        let mut s = super::reduce8(&l);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn accum_group(
        words: &[u32],
        xs: &[f32],
        m: usize,
        xstride: usize,
        off: usize,
        lanes: &mut [f32],
    ) {
        let sh_lo = vld1q_s32(SH_LO.as_ptr());
        let sh_hi = vld1q_s32(SH_HI.as_ptr());
        let mask = vdupq_n_u32(0xF);
        for i in 0..m {
            let lp = lanes.as_mut_ptr().add(i * 8);
            let mut acc0 = vld1q_f32(lp);
            let mut acc1 = vld1q_f32(lp.add(4));
            let xbase = xs.as_ptr().add(i * xstride + off);
            for (wi, &w) in words.iter().enumerate() {
                let wv = vdupq_n_u32(w);
                let c0 = vcvtq_f32_u32(vandq_u32(vshlq_u32(wv, sh_lo), mask));
                let c1 = vcvtq_f32_u32(vandq_u32(vshlq_u32(wv, sh_hi), mask));
                let xp = xbase.add(wi * 8);
                acc0 = vaddq_f32(acc0, vmulq_f32(c0, vld1q_f32(xp)));
                acc1 = vaddq_f32(acc1, vmulq_f32(c1, vld1q_f32(xp.add(4))));
            }
            vst1q_f32(lp, acc0);
            vst1q_f32(lp.add(4), acc1);
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_group(words: &[u32], scale: f32, zero: f32, out: &mut [f32]) {
        let sh_lo = vld1q_s32(SH_LO.as_ptr());
        let sh_hi = vld1q_s32(SH_HI.as_ptr());
        let mask = vdupq_n_u32(0xF);
        let vz = vdupq_n_f32(zero);
        let vs = vdupq_n_f32(scale);
        for (wi, &w) in words.iter().enumerate() {
            let wv = vdupq_n_u32(w);
            let c0 = vcvtq_f32_u32(vandq_u32(vshlq_u32(wv, sh_lo), mask));
            let c1 = vcvtq_f32_u32(vandq_u32(vshlq_u32(wv, sh_hi), mask));
            let op = out.as_mut_ptr().add(wi * 8);
            vst1q_f32(op, vmulq_f32(vsubq_f32(c0, vz), vs));
            vst1q_f32(op.add(4), vmulq_f32(vsubq_f32(c1, vz), vs));
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn dot_scalar_matches_sequential_sum_within_eps() {
        let mut rng = Pcg64::seeded(7);
        for n in [0usize, 1, 7, 8, 9, 24, 31, 100] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = dot_scalar(&a, &b) as f64;
            assert!(
                (naive - got).abs() <= 1e-4 * (n.max(1) as f64),
                "n={n}: naive {naive} vs lane {got}"
            );
        }
    }

    #[test]
    fn simd_paths_are_bit_identical_to_scalar() {
        // When the feature/hardware is absent the Simd path falls back
        // to scalar, so this holds (trivially) on every build.
        let mut rng = Pcg64::seeded(11);
        for n in [1usize, 8, 16, 24, 31, 40, 104, 257] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            assert_eq!(
                dot_path(&a, &b, Path::Scalar).to_bits(),
                dot_path(&a, &b, Path::Simd).to_bits(),
                "dot diverged at n={n}"
            );
        }
        for (m, nwords) in [(1usize, 1usize), (3, 4), (8, 13), (17, 5)] {
            let words: Vec<u32> = (0..nwords).map(|_| rng.next_u32()).collect();
            let xstride = nwords * 8 + 3;
            let xs = rand_vec(&mut rng, m * xstride);
            let mut lanes_a = vec![0.125f32; 8 * m];
            let mut lanes_b = lanes_a.clone();
            accum_group(&words, &xs, m, xstride, 0, &mut lanes_a, Path::Scalar);
            accum_group(&words, &xs, m, xstride, 0, &mut lanes_b, Path::Simd);
            for (x, y) in lanes_a.iter().zip(&lanes_b) {
                assert_eq!(x.to_bits(), y.to_bits(), "accum_group m={m} nw={nwords}");
            }
            let mut out_a = vec![0f32; nwords * 8];
            let mut out_b = out_a.clone();
            dequant_group(&words, 0.37, 5.0, &mut out_a, Path::Scalar);
            dequant_group(&words, 0.37, 5.0, &mut out_b, Path::Simd);
            assert_eq!(out_a, out_b);
        }
    }

    #[test]
    fn word_lanes_match_pack_word_codes() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..64 {
            let w = rng.next_u32();
            assert_eq!(word_lanes(w), crate::quant::pack::word_codes(w));
        }
    }

    #[test]
    fn prefetch_is_safe_on_any_slice() {
        prefetch_words(&[]);
        let v: Vec<u32> = (0..33).collect();
        prefetch_words(&v);
    }
}
