//! Owned row-major f32 tensor with light shape algebra.
//!
//! The native engine's hot loops operate on raw slices; `Tensor` carries
//! shape metadata at module boundaries and for the eval harness.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape, data: vec![0.0; numel] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape, data: vec![v; numel] }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Max |a − b| against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert!(r.clone().reshape(vec![7]).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 2], vec![0.0; 3]).is_err());
    }
}
