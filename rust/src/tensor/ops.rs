//! BLAS-free linear algebra + NN ops, numerically mirroring the JAX layer-2
//! definitions (`python/compile/model.py`) so the native engine and the
//! PJRT path agree to float tolerance.
//!
//! Layout conventions: activations `[n, d]` row-major; weights `[out, in]`
//! so both operands of `matmul_t` stream contiguously.

/// y[n, out] = x[n, in] · w[out, in]ᵀ  (+= when `accumulate`)
pub fn matmul_t(x: &[f32], w: &[f32], y: &mut [f32], n: usize, cin: usize, out: usize) {
    assert_eq!(x.len(), n * cin);
    assert_eq!(w.len(), out * cin);
    assert_eq!(y.len(), n * out);
    for i in 0..n {
        let xi = &x[i * cin..(i + 1) * cin];
        let yi = &mut y[i * out..(i + 1) * out];
        for o in 0..out {
            yi[o] = dot(xi, &w[o * cin..(o + 1) * cin]);
        }
    }
}

/// Dot product in the crate-wide canonical 8-lane accumulation order
/// (`tensor::simd`): eight independent lane accumulators, a fixed
/// reduction tree, a sequential tail. Dispatches to the runtime-detected
/// AVX2/NEON kernel when the `simd` feature is on — bit-identical to
/// the scalar lane reference by construction, so the dense sub-branch,
/// lm-head and attention paths never depend on which path ran.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::tensor::simd::dot(a, b)
}

/// y += alpha * x (axpy)
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// In-place softmax over the last axis of `[rows, cols]`.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// RMSNorm: x * rsqrt(mean(x²) + eps) * w  (matches jax: eps inside sqrt)
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32], eps: f32) {
    let d = x.len();
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * w[i];
    }
}

/// LayerNorm with weight and bias (population variance, like jnp.var).
pub fn layernorm(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], eps: f32) {
    let d = x.len() as f32;
    let mu: f32 = x.iter().sum::<f32>() / d;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d;
    let r = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * r * w[i] + b[i];
    }
}

/// SiLU (swish): x * sigmoid(x)
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// GELU, tanh approximation (the jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// RoPE, half-split convention (mirror of `model.apply_rope`):
/// `q` is one head `[head_dim]`; rotate pairs (i, i+half).
pub fn rope_rotate(v: &mut [f32], pos: usize, theta: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let x1 = v[i];
        let x2 = v[i + half];
        v[i] = x1 * cos - x2 * sin;
        v[i + half] = x2 * cos + x1 * sin;
    }
}

/// argmax over a slice.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// log-softmax value of index `idx` over `x` (for likelihood scoring).
pub fn log_softmax_at(x: &[f32], idx: usize) -> f32 {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f32 = x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
    x[idx] - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Pcg64::seeded(31);
        for n in [0usize, 1, 7, 8, 9, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4 * (n.max(1) as f32));
        }
    }

    #[test]
    fn matmul_t_small() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]] -> y = x·wᵀ
        let x = [1., 2., 3., 4.];
        let w = [1., 0., 0., 1., 1., 1.];
        let mut y = [0f32; 6];
        matmul_t(&x, &w, &mut y, 2, 2, 3);
        assert_eq!(y, [1., 2., 3., 3., 4., 7.]);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, -3.0, 3.0, -3.0];
        let w = [1.0f32; 4];
        let mut out = [0f32; 4];
        rmsnorm(&x, &w, &mut out, 0.0);
        for v in out {
            assert!((v.abs() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32; 4];
        let b = [0.0f32; 4];
        let mut out = [0f32; 4];
        layernorm(&x, &w, &b, &mut out, 0.0);
        let mu: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_is_identity() {
        let mut rng = Pcg64::seeded(32);
        let orig: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let mut v = orig.clone();
        rope_rotate(&mut v, 0, 10_000.0);
        assert_eq!(v, orig);
        let mut v = orig.clone();
        rope_rotate(&mut v, 17, 10_000.0);
        let n0: f32 = orig.iter().map(|x| x * x).sum();
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-4);
        assert!(v != orig);
    }

    #[test]
    fn activations_reference_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(1.0) - 0.731_058_6).abs() < 1e-5);
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_191_9).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808_1).abs() < 1e-4);
    }

    #[test]
    fn log_softmax_at_normalizes() {
        let x = [0.5f32, 1.5, -0.5];
        let total: f32 = (0..3).map(|i| log_softmax_at(&x, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
