//! Deterministic random number generation (PCG-XSH-RR 64/32 and helpers).
//!
//! Offline substitute for the `rand` crate. Everything that needs
//! randomness in this crate (sampling, property tests, synthetic workloads)
//! goes through [`Pcg64`] so runs are reproducible from a single seed.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014), with a 64-bit output built
/// from two 32-bit draws.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Seed the generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument convenience seeding.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's method (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for the
    /// synthetic request workloads).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seeded(42);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg64::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
