//! Command-line parsing and the `fbquant` top-level command dispatch.
//!
//! Offline substitute for `clap`: `--key value` options, `--flag` booleans,
//! positional arguments, and per-command help derived from a declarative
//! option table.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("option --{name} expects a value"))?;
                    args.options.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }
}

const USAGE: &str = "\
fbquant — FeedBack Quantization serving stack (IJCAI'25 reproduction)

USAGE: fbquant <COMMAND> [OPTIONS]

COMMANDS:
  info                       Inventory of artifacts, models and executables
  generate                   Generate tokens from a model (native engine or PJRT)
  serve                      HTTP/SSE serving front end over the coordinator
                             (POST /v1/generate streams tokens; GET /metrics
                             [?format=prometheus], /healthz, /debug/trace;
                             loopback POST /admin/shutdown stops it; --synth
                             serves a synthesized checkpoint; FBQ_TRACE=request|
                             kernel arms the flight recorder)
  loadgen                    Trace-driven open-loop load harness: one seeded trace
                             in-process and over HTTP loopback -> BENCH_serve.json
                             (--class-mix i,s,b --drop-frac f --degrade --pages n
                             exercise the overload tier: priority preemption,
                             mid-stream disconnects, adaptive degradation;
                             --prom-out f / --trace-out f dump the prometheus
                             scrape and the chrome trace from the http run)
  eval-ppl                   Perplexity on the held-out validation set (Table 1 cell)
  eval-zeroshot              Zero-shot multiple-choice accuracy (Table 2 cell)
  judge                      Pairwise model comparison (Fig 6 cell)
  inspect-weights            Per-layer stats of a .fbqw archive

COMMON OPTIONS:
  --model <name>             e.g. llamoid-tiny (see `info`)
  --method <m>               fp | rtn | gptq | awq | omniquant | loftq |
                             svdquant | caldera | eora | fbquant
  --bits <b>                 3 | 4 (ignored for fp)
  --backend <b>              native | pjrt          [default: native]
  --artifacts <dir>          artifact root          [default: ./artifacts]

Run `fbquant <COMMAND> --help` for command-specific options.
";

/// Top-level entry point used by `rust/src/main.rs`.
pub fn run() -> Result<()> {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "-h" || raw[0] == "help" {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = raw.remove(0);
    let args = Args::parse(
        raw,
        &[
            "help", "detail", "fused", "verbose", "quiet", "no-sub", "sync", "synth", "bursty",
            "degrade",
        ],
    )?;
    if args.flag("verbose") {
        super::logging::set_level(super::logging::Level::Debug);
    }
    if args.flag("quiet") {
        super::logging::set_level(super::logging::Level::Error);
    }
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("FBQ_ARTIFACTS", dir);
    }
    dispatch(&cmd, &args)
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => crate::eval::commands::cmd_info(args),
        "generate" => crate::eval::commands::cmd_generate(args),
        "serve" => crate::eval::commands::cmd_serve(args),
        "loadgen" => crate::eval::commands::cmd_loadgen(args),
        "eval-ppl" => crate::eval::commands::cmd_eval_ppl(args),
        "eval-zeroshot" => crate::eval::commands::cmd_eval_zeroshot(args),
        "judge" => crate::eval::commands::cmd_judge(args),
        "inspect-weights" => crate::eval::commands::cmd_inspect_weights(args),
        other => bail!("unknown command '{other}' (try `fbquant help`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), &["detail"]).unwrap()
    }

    #[test]
    fn parses_options_and_positionals() {
        let a = parse(&["pos1", "--model", "llamoid-tiny", "--bits=3", "pos2", "--detail"]);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get("model"), Some("llamoid-tiny"));
        assert_eq!(a.get("bits"), Some("3"));
        assert!(a.flag("detail"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "12", "--rate", "2.5"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("rate", 0).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--model".to_string()], &[]);
        assert!(r.is_err());
    }
}
