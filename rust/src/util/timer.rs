//! Timing helpers and latency histograms for the metrics pipeline.

use std::time::{Duration, Instant};

/// Stopwatch with a readable report.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Online latency recorder: stores raw samples (bounded) plus running
/// aggregates, reports mean / p50 / p95 / p99 / max.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<f64>,
    count: usize,
    sum_us: f64,
    max_us: f64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
        // Keep raw samples bounded; reservoir-free cap is fine for the
        // benchmark scale used here.
        if self.samples_us.len() < 1_000_000 {
            self.samples_us.push(us);
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        super::percentile(&self.samples_us, p)
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count,
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.max_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_aggregate() {
        let mut s = LatencyStats::new();
        for us in [10.0, 20.0, 30.0, 40.0, 100.0] {
            s.record_us(us);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean_us() - 40.0).abs() < 1e-9);
        assert_eq!(s.max_us(), 100.0);
        assert_eq!(s.percentile_us(50.0), 30.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyStats::new();
        a.record_us(1.0);
        let mut b = LatencyStats::new();
        b.record_us(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_us(), 2.0);
    }
}
