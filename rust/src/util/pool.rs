//! Minimal scoped thread pool (offline substitute for rayon).
//!
//! Used for data-parallel work: batch evaluation, quantization sweeps
//! and benchmark fan-out. [`decode_threads`] (the `FBQ_THREADS` knob)
//! also sizes the row-parallel decode kernels in `engine::kernels`,
//! which spawn their own scoped workers over disjoint output-row slices;
//! those only fan out above a multi-million-MAC work floor (see
//! `engine::kernels::plan_threads`), so the spawn/join cost is amortized
//! against >=1ms of compute per call — a persistent worker pool would
//! shave that further (ROADMAP). The serving coordinator's own
//! scheduling uses dedicated long-lived threads instead (see
//! `coordinator::server`).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `f(i)` for `i in 0..n` on up to `threads` workers, returning results
/// in index order. Panics in workers are propagated.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        return;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.expect("worker dropped result")).collect()
    })
}

/// Default worker count: physical parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Worker count for the row-parallel decode kernels, from the
/// `FBQ_THREADS` environment knob (cached after first read).
///
/// `FBQ_THREADS=1` (or `0`) forces the serial path; unset or unparsable
/// falls back to [`default_threads`]. Thread count never changes results —
/// parallel kernels partition output rows, so every element is computed by
/// exactly one worker in the same operation order as the serial loop.
pub fn decode_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| match std::env::var("FBQ_THREADS") {
        // 0 means "no extra threads" by the usual convention: run serial
        Ok(v) => v.trim().parse::<usize>().map(|n| n.max(1)).unwrap_or_else(|_| default_threads()),
        Err(_) => default_threads(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }
}
