//! Thread-pool utilities: the persistent pinned worker pool behind the
//! row-parallel decode kernels, plus a scoped `parallel_map` (offline
//! substitute for rayon) for batch evaluation and benchmark fan-out.
//!
//! # Persistent pool
//!
//! The decode hot loop calls a row-parallel kernel thousands of times
//! per second; spawning a fresh `std::thread::scope` per call pays
//! clone/join syscalls each time. [`WorkerPool`] instead spawns
//! `decode_threads() - 1` long-lived workers **once** (lazily, on first
//! parallel kernel call), parks them on channel receives between
//! steps, and pins each to a core on Linux (`FBQ_PIN=0` opts out).
//! [`WorkerPool::run_scoped`] dispatches borrowed closures: the first
//! job runs on the calling thread (the "leader") while the rest
//! round-robin over the workers, and the call blocks on a completion
//! latch before returning — which is what makes lending non-`'static`
//! borrows to the long-lived workers sound. A panicking job poisons the
//! latch and re-panics on the submitter after every sibling finishes,
//! so a dying step surfaces an error instead of deadlocking and the
//! pool stays usable.
//!
//! `FBQ_THREADS` still bounds the worker count (`0`/`1` = serial, no
//! workers at all); [`force_dispatch`] lets benches and tests pin the
//! per-call scoped-spawn fallback for A/B comparison. Pool dispatch
//! overhead is measured once at startup ([`WorkerPool::dispatch_overhead_ns`])
//! and feeds the kernel-side fan-out floor (`engine::kernels::plan_threads`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// A borrowed unit of work, callable exactly once.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

// ---------------------------------------------------------------------------
// dispatch mode
// ---------------------------------------------------------------------------

/// How [`run_jobs`] fans work out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Spawn a fresh `std::thread::scope` per call (the pre-pool
    /// behavior, kept as the A/B baseline).
    Scoped,
    /// Reuse the lazily-spawned persistent [`WorkerPool`] (default).
    Pool,
}

/// 0 = default (pool), 1 = scoped, 2 = pool.
static FORCE_DISPATCH: AtomicU8 = AtomicU8::new(0);

/// Pin the fan-out mechanism for the whole process (`None` restores the
/// pool default). Both modes partition work identically, so results
/// never depend on this — only dispatch latency does.
pub fn force_dispatch(d: Option<Dispatch>) {
    let v = match d {
        None => 0,
        Some(Dispatch::Scoped) => 1,
        Some(Dispatch::Pool) => 2,
    };
    FORCE_DISPATCH.store(v, Ordering::SeqCst);
}

/// The fan-out mechanism [`run_jobs`] will use right now.
pub fn dispatch_mode() -> Dispatch {
    match FORCE_DISPATCH.load(Ordering::Relaxed) {
        1 => Dispatch::Scoped,
        _ => Dispatch::Pool,
    }
}

/// Run borrowed jobs to completion via the current [`dispatch_mode`].
/// Blocks until every job has finished; panics (after completion of the
/// siblings) if any job panicked.
pub fn run_jobs(jobs: Vec<Task<'_>>) {
    match dispatch_mode() {
        Dispatch::Pool => global().run_scoped(jobs),
        Dispatch::Scoped => {
            std::thread::scope(|s| {
                for job in jobs {
                    s.spawn(job);
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// completion latch
// ---------------------------------------------------------------------------

/// Counts outstanding dispatched jobs; the submitter blocks on it so
/// borrowed closures never outlive their frame. `poisoned` records a
/// worker-side panic to re-raise on the submitter.
struct Latch {
    left: Mutex<usize>,
    cv: Condvar,
    poisoned: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { left: Mutex::new(n), cv: Condvar::new(), poisoned: AtomicBool::new(false) }
    }

    fn done(&self) {
        let mut g = self.left.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.left.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

struct Job(Box<dyn FnOnce() + Send + 'static>);

/// Long-lived, core-pinned workers parked on channel receives between
/// kernel calls. See the module docs for the dispatch/soundness model.
pub struct WorkerPool {
    txs: Vec<mpsc::Sender<Job>>,
    overhead_ns: u64,
}

impl WorkerPool {
    /// Spawn `workers` parked worker threads (0 = a serial pool that
    /// runs everything inline on the submitter).
    fn spawn(workers: usize) -> WorkerPool {
        let pin = match std::env::var("FBQ_PIN") {
            Ok(v) => v.trim() != "0",
            Err(_) => true,
        };
        let mut txs = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = mpsc::channel::<Job>();
            std::thread::Builder::new()
                .name(format!("fbq-pool-{i}"))
                .spawn(move || {
                    if pin {
                        pin_current_thread(i + 1);
                    }
                    while let Ok(job) = rx.recv() {
                        (job.0)();
                    }
                })
                .expect("failed to spawn fbq pool worker");
            txs.push(tx);
        }
        let mut pool = WorkerPool { txs, overhead_ns: 0 };
        pool.overhead_ns = pool.calibrate();
        pool
    }

    /// Number of parked workers (the submitting thread adds one more
    /// lane of parallelism on top).
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Measured wall-clock cost of one empty full-width dispatch
    /// (round-trip: wake every worker, run nothing, settle the latch).
    /// The kernel fan-out floor is derived from this.
    pub fn dispatch_overhead_ns(&self) -> u64 {
        self.overhead_ns
    }

    fn calibrate(&self) -> u64 {
        if self.txs.is_empty() {
            return 0;
        }
        let nop_round = |pool: &WorkerPool| {
            let jobs: Vec<Task<'_>> =
                (0..pool.txs.len() + 1).map(|_| Box::new(|| {}) as Task<'_>).collect();
            pool.run_scoped(jobs);
        };
        // warm the workers out of their first park before timing
        for _ in 0..2 {
            nop_round(self);
        }
        const ROUNDS: u32 = 8;
        let t0 = std::time::Instant::now();
        for _ in 0..ROUNDS {
            nop_round(self);
        }
        (t0.elapsed().as_nanos() as u64 / u64::from(ROUNDS)).max(1)
    }

    /// Run borrowed jobs to completion. Job 0 executes on the calling
    /// thread while the rest round-robin over the parked workers; the
    /// call returns only after every job has finished (or panicked), at
    /// which point a worker-side panic is re-raised here.
    pub fn run_scoped(&self, mut jobs: Vec<Task<'_>>) {
        match jobs.len() {
            0 => return,
            1 => return (jobs.pop().expect("len checked"))(),
            _ => {}
        }
        if self.txs.is_empty() {
            for job in jobs {
                job();
            }
            return;
        }
        let leader_job = jobs.remove(0);
        let latch = Arc::new(Latch::new(jobs.len()));
        for (w, job) in jobs.into_iter().enumerate() {
            let latch = Arc::clone(&latch);
            // SAFETY: `run_scoped` blocks on the latch below until every
            // dispatched job has run (the wrapper settles the latch on
            // success *and* panic), so the borrows captured in `job`
            // strictly outlive its execution even though the worker
            // thread sees a 'static closure.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + '_>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let wrapped = Job(Box::new(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    latch.poisoned.store(true, Ordering::SeqCst);
                }
                latch.done();
            }));
            if let Err(err) = self.txs[w % self.txs.len()].send(wrapped) {
                // worker thread gone (only possible after an external
                // kill): run inline — the wrapper settles the latch
                let job = err.0;
                (job.0)();
            }
        }
        let leader = catch_unwind(AssertUnwindSafe(leader_job));
        // MUST settle before unwinding: workers may still hold borrows
        // into this frame.
        latch.wait();
        if let Err(p) = leader {
            resume_unwind(p);
        }
        if latch.poisoned.load(Ordering::SeqCst) {
            panic!("fbq worker pool: a dispatched job panicked");
        }
    }
}

/// The process-wide pool, spawned on first use and sized
/// `decode_threads() - 1` (so `FBQ_THREADS=0`/`1` never spawns workers).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::spawn(decode_threads().saturating_sub(1)))
}

/// Best-effort Linux core pinning via a hand-rolled `sched_setaffinity`
/// binding (std-only crate — no libc dependency). Failure, non-Linux
/// platforms, or `FBQ_PIN=0` leave the worker floating, which is always
/// safe.
#[cfg(target_os = "linux")]
fn pin_current_thread(core: usize) {
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16],
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16 * 64);
    if ncores <= 1 {
        return;
    }
    let core = core % ncores;
    let mut set = CpuSet { bits: [0; 16] };
    set.bits[core / 64] |= 1u64 << (core % 64);
    // SAFETY: pid 0 = current thread; the mask outlives the call and
    // its size is passed alongside. A nonzero return (cgroup cpuset
    // restrictions etc.) is deliberately ignored.
    let _ = unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) };
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_core: usize) {}

// ---------------------------------------------------------------------------
// scoped parallel_map (unchanged API)
// ---------------------------------------------------------------------------

/// Run `f(i)` for `i in 0..n` on up to `threads` workers, returning results
/// in index order. Panics in workers are propagated.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        return;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.expect("worker dropped result")).collect()
    })
}

/// Default worker count: physical parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Worker count for the row-parallel decode kernels, from the
/// `FBQ_THREADS` environment knob (cached after first read).
///
/// `FBQ_THREADS=1` (or `0`) forces the serial path; unset or unparsable
/// falls back to [`default_threads`]. Thread count never changes results —
/// parallel kernels partition output rows, so every element is computed by
/// exactly one worker in the same operation order as the serial loop.
pub fn decode_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| match std::env::var("FBQ_THREADS") {
        // 0 means "no extra threads" by the usual convention: run serial
        Ok(v) => v.trim().parse::<usize>().map(|n| n.max(1)).unwrap_or_else(|_| default_threads()),
        Err(_) => default_threads(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    /// Every job runs exactly once and writes exactly its own slice —
    /// work conservation + result placement, under random job counts
    /// against pools of random widths (including 0 = serial and widths
    /// far above the job count, i.e. oversubscribed the other way).
    #[test]
    fn pool_conserves_work_and_placement() {
        let mut rng = Pcg64::seeded(42);
        for trial in 0..12 {
            let workers = rng.below(5); // 0..=4, 0 exercises the serial path
            let pool = WorkerPool::spawn(workers);
            let njobs = 1 + rng.below(33);
            let per_job = 1 + rng.below(7);
            let mut out = vec![0usize; njobs * per_job];
            {
                let jobs: Vec<Task<'_>> = out
                    .chunks_mut(per_job)
                    .enumerate()
                    .map(|(j, chunk)| {
                        Box::new(move || {
                            for (k, slot) in chunk.iter_mut().enumerate() {
                                *slot += j * 1000 + k + 1;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                pool.run_scoped(jobs);
            }
            for j in 0..njobs {
                for k in 0..per_job {
                    assert_eq!(
                        out[j * per_job + k],
                        j * 1000 + k + 1,
                        "trial {trial}: job {j} lane {k} ran zero or multiple times"
                    );
                }
            }
        }
    }

    /// Many jobs over few workers: the round-robin queues drain fully.
    #[test]
    fn pool_oversubscribed_counts_every_job() {
        let pool = WorkerPool::spawn(2);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Task<'_>> = (0..97)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as Task<'_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 97);
    }

    /// A panicking job must surface an error on the submitter (not
    /// deadlock), the sibling jobs must still run, and the pool must
    /// stay usable afterwards.
    #[test]
    fn pool_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::spawn(3);
        for round in 0..3 {
            let survivors = AtomicUsize::new(0);
            let result = catch_unwind(AssertUnwindSafe(|| {
                let jobs: Vec<Task<'_>> = (0..8)
                    .map(|j| {
                        let survivors = &survivors;
                        Box::new(move || {
                            if j == 5 {
                                panic!("boom {j}");
                            }
                            survivors.fetch_add(1, Ordering::SeqCst);
                        }) as Task<'_>
                    })
                    .collect();
                pool.run_scoped(jobs);
            }));
            assert!(result.is_err(), "round {round}: panic was swallowed");
            assert_eq!(survivors.load(Ordering::SeqCst), 7, "round {round}");
        }
        // and a clean dispatch still works on the same workers
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Task<'_>> = (0..6)
            .map(|_| {
                Box::new(|| {
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Task<'_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(ok.load(Ordering::SeqCst), 6);
    }

    /// A panic on the *leader* job (runs on the submitting thread) also
    /// propagates, after the workers settle.
    #[test]
    fn pool_leader_panic_waits_for_workers() {
        let pool = WorkerPool::spawn(2);
        let done = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut jobs: Vec<Task<'_>> = vec![Box::new(|| panic!("leader down"))];
            for _ in 0..4 {
                jobs.push(Box::new(|| {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    done.fetch_add(1, Ordering::SeqCst);
                }));
            }
            pool.run_scoped(jobs);
        }));
        assert!(result.is_err());
        assert_eq!(done.load(Ordering::SeqCst), 4, "leader unwound before workers settled");
    }

    #[test]
    fn pool_zero_and_single_job_shortcuts() {
        let pool = WorkerPool::spawn(2);
        pool.run_scoped(Vec::new());
        let mut x = 0u32;
        pool.run_scoped(vec![Box::new(|| x += 7) as Task<'_>]);
        assert_eq!(x, 7);
    }

    #[test]
    fn calibration_measures_positive_overhead() {
        let pool = WorkerPool::spawn(2);
        assert!(pool.dispatch_overhead_ns() > 0);
        let serial = WorkerPool::spawn(0);
        assert_eq!(serial.dispatch_overhead_ns(), 0);
    }

    #[test]
    fn run_jobs_works_in_both_dispatch_modes() {
        for mode in [Dispatch::Scoped, Dispatch::Pool] {
            force_dispatch(Some(mode));
            let mut out = vec![0usize; 40];
            {
                let jobs: Vec<Task<'_>> = out
                    .chunks_mut(10)
                    .enumerate()
                    .map(|(j, chunk)| {
                        Box::new(move || {
                            for slot in chunk.iter_mut() {
                                *slot = j + 1;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                run_jobs(jobs);
            }
            force_dispatch(None);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i / 10 + 1, "mode {mode:?}");
            }
        }
    }
}
