//! Minimal JSON value type with parser and serializer.
//!
//! Offline substitute for `serde_json`, covering everything the artifact
//! manifests, model configs and metrics emitters need: objects, arrays,
//! strings (with escapes), f64 numbers, booleans, null. Object key order is
//! preserved so emitted manifests diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: pairs kept in insertion order, with an index for lookup.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object constructor helper.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with context instead of returning `Option`.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::new(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of usizes (shapes in manifests).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Convert an object into a key→value map (for repeated lookups).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<usize>> for Json {
    fn from(v: Vec<usize>) -> Self {
        Json::Arr(v.into_iter().map(Json::from).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    msg: String,
}

impl JsonError {
    fn new(msg: String) -> Self {
        JsonError { msg }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::new(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    if let Ok(chunk) = std::str::from_utf8(&self.bytes[start..end]) {
                        s.push_str(chunk);
                        self.pos = end;
                    } else {
                        s.push('\u{fffd}');
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit()
                || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse(r#""héllo \"w\"""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo \"w\""));
        let v = Json::parse("\"caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = Json::obj(vec![
            ("shape", Json::from(vec![2usize, 3, 4])),
            ("name", Json::from("w_q")),
            ("ok", Json::from(true)),
        ]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }
}
