//! Std-only termination-signal latch: SIGTERM / SIGINT set a process-wide
//! flag the serving loop polls to enter the same graceful drain path as
//! stdin EOF and `POST /admin/shutdown`.
//!
//! No libc crate, no signal-handling dependency: on Unix the `signal`
//! symbol the standard library already links is declared directly, and
//! the handler body is a single atomic store — the only async-signal-safe
//! action it needs. On other platforms installation is a no-op and the
//! flag simply never trips.

use std::sync::atomic::{AtomicBool, Ordering};

/// Latched by the signal handler; read by [`termination_requested`].
static TERM: AtomicBool = AtomicBool::new(false);

/// True once SIGTERM or SIGINT has been delivered (after
/// [`hook_termination`] installed the handlers). Latches for the rest of
/// the process: termination is never un-requested.
pub fn termination_requested() -> bool {
    TERM.load(Ordering::SeqCst)
}

/// Install SIGTERM + SIGINT handlers that latch [`termination_requested`].
/// Idempotent; a no-op off Unix.
pub fn hook_termination() {
    #[cfg(unix)]
    unix::install();
}

#[cfg(unix)]
mod unix {
    use super::{Ordering, TERM};

    /// Same numeric values on every Unix Rust targets (Linux, macOS, BSDs).
    pub(crate) const SIGINT: i32 = 2;
    pub(crate) const SIGTERM: i32 = 15;

    extern "C" fn on_term(_sig: i32) {
        // A store to a static atomic is async-signal-safe; everything
        // else (logging, draining, joining) happens on the polling side.
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        /// `signal(2)` from the C runtime std already links. glibc/musl
        /// give it BSD semantics: the handler persists across deliveries
        /// and interrupted syscalls restart.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub(super) fn install() {
        unsafe {
            let _ = signal(SIGTERM, on_term);
            let _ = signal(SIGINT, on_term);
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    extern "C" {
        fn raise(sig: i32) -> i32;
    }

    #[test]
    fn sigterm_latches_instead_of_killing() {
        hook_termination();
        // With the handler installed, raising SIGTERM at ourselves must
        // latch the flag — were the default disposition still active the
        // whole test process would die here.
        unsafe {
            raise(super::unix::SIGTERM);
        }
        assert!(termination_requested());
    }
}
