//! Constant-memory log-bucketed latency histogram.
//!
//! Replaces the sample-vector [`super::timer::LatencyStats`] on the serving
//! path: a fixed array of geometrically-spaced buckets (growth factor ~1.2,
//! so any percentile is resolved to within ~±10% relative error) plus exact
//! running aggregates (count / sum / min / max). Recording is O(1) with no
//! allocation, memory is constant regardless of sample count, and two
//! histograms merge by adding bucket counts — which is what lets per-worker
//! stats fold into one exposition without shipping raw samples.
//!
//! Bucket `i` spans `(ub(i-1), ub(i)]` with `ub(i) = LO_US * GROWTH^i`;
//! bucket 0 is the underflow bucket `[0, LO_US]` and the last bucket is the
//! overflow bucket with an infinite upper bound. The same bucket bounds feed
//! the Prometheus `_bucket{le=...}` exposition and the `buckets` arrays in
//! `BENCH_serve.json`.

use std::time::Duration;

use crate::util::Json;

/// Total bucket count, including the underflow (0) and overflow (last)
/// buckets. 128 buckets at growth 1.2 cover 0.1µs .. ~9.5e8µs (~16 min),
/// far wider than any latency this stack records, in 1KiB per histogram.
pub const N_BUCKETS: usize = 128;

/// Upper bound of the underflow bucket, in microseconds.
const LO_US: f64 = 0.1;

/// Geometric growth factor between consecutive bucket upper bounds.
const GROWTH: f64 = 1.2;

/// Upper bound (µs) of bucket `i`; `+Inf` for the overflow bucket.
pub fn bucket_upper_us(i: usize) -> f64 {
    if i + 1 >= N_BUCKETS {
        f64::INFINITY
    } else {
        LO_US * GROWTH.powi(i as i32)
    }
}

/// Bucket index for a value in microseconds.
fn bucket_index(us: f64) -> usize {
    if !(us > LO_US) {
        return 0; // also catches NaN and negatives
    }
    let idx = ((us / LO_US).ln() / GROWTH.ln()).floor() as usize + 1;
    idx.min(N_BUCKETS - 1)
}

/// Log-bucketed latency histogram with exact count/sum/min/max.
#[derive(Debug, Clone)]
pub struct Hist {
    counts: Box<[u64]>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            counts: vec![0u64; N_BUCKETS].into_boxed_slice(),
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.record_us(ns as f64 / 1e3);
    }

    pub fn record_us(&mut self, us: f64) {
        let us = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        self.counts[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        if us < self.min_us {
            self.min_us = us;
        }
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn min_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Per-bucket counts (index `i` pairs with [`bucket_upper_us`]`(i)`).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// p-th percentile (0..=100), resolved by linear interpolation inside
    /// the containing bucket — accurate to the bucket's ~1.2x width.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &n) in self.counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if cum + n >= target {
                let lo = if i == 0 { 0.0 } else { bucket_upper_us(i - 1) };
                let hi = bucket_upper_us(i);
                let est = if hi.is_finite() {
                    let frac = (target - cum) as f64 / n as f64;
                    lo + (hi - lo) * frac
                } else {
                    // Overflow bucket: the exact max is the best bound.
                    self.max_us
                };
                return est.clamp(self.min_us(), self.max_us);
            }
            cum += n;
        }
        self.max_us
    }

    /// Fold another histogram into this one (bucket-wise addition).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count,
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.max_us
        )
    }

    /// Non-empty buckets as `[upper_bound_us, count]` pairs; the overflow
    /// bucket's bound is emitted as the string `"+Inf"`.
    pub fn buckets_json(&self) -> Json {
        let mut out = Vec::new();
        for (i, &n) in self.counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let le = bucket_upper_us(i);
            let le_json =
                if le.is_finite() { Json::Num(le) } else { Json::Str("+Inf".to_string()) };
            out.push(Json::Arr(vec![le_json, Json::Num(n as f64)]));
        }
        Json::Arr(out)
    }

    /// Summary object with the same keys the JSON metrics always exposed
    /// (`n`, `mean_us`, `p50_us`, `p95_us`, `p99_us`, `max_us`) plus the
    /// sparse `buckets` array capturing distribution shape.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.count as f64)),
            ("mean_us", Json::Num(self.mean_us())),
            ("p50_us", Json::Num(self.percentile_us(50.0))),
            ("p95_us", Json::Num(self.percentile_us(95.0))),
            ("p99_us", Json::Num(self.percentile_us(99.0))),
            ("max_us", Json::Num(self.max_us())),
            ("buckets", self.buckets_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_consistent() {
        // Every recorded value must land in a bucket whose bounds contain it.
        let mut v = 0.013f64;
        while v < 5e8 {
            let i = bucket_index(v);
            let hi = bucket_upper_us(i);
            let lo = if i == 0 { 0.0 } else { bucket_upper_us(i - 1) };
            assert!(v <= hi * (1.0 + 1e-12), "v={v} above bucket {i} hi={hi}");
            assert!(v >= lo * (1.0 - 1e-9), "v={v} below bucket {i} lo={lo}");
            v *= 1.37;
        }
    }

    #[test]
    fn exact_aggregates() {
        let mut h = Hist::new();
        for us in [10.0, 20.0, 30.0, 40.0, 100.0] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 40.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 100.0);
        assert_eq!(h.min_us(), 10.0);
        // Bucketed median: within one 1.2x bucket of the true 30.0.
        let p50 = h.percentile_us(50.0);
        assert!(p50 >= 30.0 / GROWTH && p50 <= 30.0 * GROWTH, "p50={p50}");
    }

    #[test]
    fn percentile_relative_error_bounded() {
        let mut h = Hist::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        for (p, truth) in [(50.0, 500.0), (95.0, 950.0), (99.0, 990.0)] {
            let est = h.percentile_us(p);
            assert!(
                est >= truth / GROWTH && est <= truth * GROWTH,
                "p{p}: est={est} truth={truth}"
            );
        }
        assert_eq!(h.percentile_us(100.0), 1000.0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut both = Hist::new();
        for i in 0..200 {
            let v = 1.5f64.powi(i % 23) + i as f64;
            if i % 2 == 0 { &mut a } else { &mut b }.record_us(v);
            both.record_us(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.bucket_counts(), both.bucket_counts());
        assert!((a.sum_us() - both.sum_us()).abs() < 1e-6);
        assert_eq!(a.max_us(), both.max_us());
        assert_eq!(a.min_us(), both.min_us());
    }

    #[test]
    fn overflow_and_underflow_buckets() {
        let mut h = Hist::new();
        h.record_us(0.0); // underflow
        h.record_us(1e12); // overflow (past the widest finite bound)
        assert_eq!(h.bucket_counts()[0], 1);
        assert_eq!(h.bucket_counts()[N_BUCKETS - 1], 1);
        assert_eq!(h.percentile_us(100.0), 1e12);
    }

    #[test]
    fn json_shape() {
        let mut h = Hist::new();
        h.record_us(42.0);
        let j = h.to_json();
        for key in ["n", "mean_us", "p50_us", "p95_us", "p99_us", "max_us", "buckets"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("n").unwrap().as_usize(), Some(1));
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].as_arr().unwrap()[1].as_usize(), Some(1));
    }

    #[test]
    fn empty_hist_reports_zeros() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0.0);
        assert_eq!(h.min_us(), 0.0);
    }
}
