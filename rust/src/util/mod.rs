//! Shared infrastructure: mini-JSON, deterministic RNG, CLI parsing,
//! logging, a scoped thread pool and timing utilities.
//!
//! The build is fully offline against a small vendored crate set, so the
//! usual ecosystem crates (serde_json, clap, rayon, rand) are replaced by
//! these purpose-built modules. They are small but real: everything here is
//! unit-tested and used on the request path.

pub mod cli;
pub mod hist;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod signal;
pub mod timer;

pub use hist::Hist;
pub use json::Json;
pub use rng::Pcg64;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable byte count (`1.5 MiB` style).
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) using nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
