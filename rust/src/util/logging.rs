//! Tiny leveled logger writing to stderr.
//!
//! Level is taken from `FBQ_LOG` (`error|warn|info|debug|trace`), default
//! `info`. Offline substitute for `env_logger`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn start_time() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Current log level (lazy-initialized from `FBQ_LOG`).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lv = match std::env::var("FBQ_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    start_time();
    lv
}

/// Force a level (tests, CLI flags).
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Emit a record if `lv` is enabled.
pub fn log(lv: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if lv > level() {
        return;
    }
    let tag = match lv {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let t = start_time().elapsed();
    eprintln!("[{:>9.3}s {tag} {module}] {args}", t.as_secs_f64());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_overrides() {
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
