//! Render a drained [`TraceDump`](super::TraceDump) as Chrome trace-event
//! JSON — the `{"traceEvents": [...]}` format `chrome://tracing` and
//! Perfetto load directly.
//!
//! Track layout: slot-bound request phases land on one lane per scheduler
//! slot (`tid = slot`), while slotless events (admission-edge markers,
//! batch-wide phases, kernel spans) land on one lane per recording thread
//! (`tid = WORKER_TID_BASE + track`). Lane names are emitted as thread-name
//! metadata events so the viewer labels them.

use super::{SpanEvent, TraceDump, SLOT_NONE};
use crate::util::Json;

/// Offset separating per-worker lanes from per-slot lanes.
const WORKER_TID_BASE: f64 = 1000.0;

fn tid_of(e: &SpanEvent) -> f64 {
    if e.slot != SLOT_NONE {
        e.slot as f64
    } else {
        WORKER_TID_BASE + e.track as f64
    }
}

fn args_of(e: &SpanEvent) -> Json {
    let mut pairs = vec![("req", Json::Num(e.req as f64))];
    if e.slot != SLOT_NONE {
        pairs.push(("slot", Json::Num(e.slot as f64)));
    }
    pairs.push(("payload", Json::Num(e.payload as f64)));
    Json::obj(pairs)
}

/// Render the dump. `ts`/`dur` are microseconds (floats), per the format.
pub fn to_chrome_json(dump: &TraceDump) -> Json {
    let mut events = Vec::with_capacity(dump.events.len() + 16);
    let mut lanes: Vec<(f64, String)> = Vec::new();
    for e in &dump.events {
        let tid = tid_of(e);
        if !lanes.iter().any(|(t, _)| *t == tid) {
            let name = if e.slot != SLOT_NONE {
                format!("slot {}", e.slot)
            } else {
                format!("worker {}", e.track)
            };
            lanes.push((tid, name));
        }
        let mut pairs = vec![
            ("name", Json::Str(e.phase.name().to_string())),
            (
                "cat",
                Json::Str(if e.phase.is_kernel() { "kernel" } else { "request" }.to_string()),
            ),
            ("ph", Json::Str(if e.phase.is_marker() { "i" } else { "X" }.to_string())),
            ("ts", Json::Num(e.start_ns as f64 / 1e3)),
        ];
        if e.phase.is_marker() {
            pairs.push(("s", Json::Str("t".to_string())));
        } else {
            pairs.push(("dur", Json::Num(e.dur_ns() as f64 / 1e3)));
        }
        pairs.push(("pid", Json::Num(1.0)));
        pairs.push(("tid", Json::Num(tid)));
        pairs.push(("args", args_of(e)));
        events.push(Json::obj(pairs));
    }
    // Thread-name metadata events label the lanes in the viewer.
    for (tid, name) in &lanes {
        events.push(Json::obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(*tid)),
            ("args", Json::obj(vec![("name", Json::Str(name.clone()))])),
        ]));
    }
    let mut other = vec![
        ("lost_events", Json::Num(dump.lost as f64)),
        ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
    ];
    if let Some((from_ns, until_ns)) = dump.winner_window {
        // A concurrent scraper won the drain race: this document covers
        // only events recorded after the winner's window.
        other.push(("partial", Json::Bool(true)));
        other.push(("winner_drain_from_us", Json::Num(from_ns as f64 / 1e3)));
        other.push(("winner_drain_until_us", Json::Num(until_ns as f64 / 1e3)));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("otherData", Json::obj(other)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Phase;

    fn ev(phase: Phase, req: u64, slot: u16, start: u64, end: u64) -> SpanEvent {
        SpanEvent { req, start_ns: start, end_ns: end, payload: 3, phase, slot, track: 2 }
    }

    #[test]
    fn renders_spans_markers_and_lanes() {
        let dump = TraceDump {
            events: vec![
                ev(Phase::Prefill, 7, 1, 1000, 5000),
                ev(Phase::Gemv, 0, SLOT_NONE, 1200, 1800),
                ev(Phase::Done, 7, 1, 5000, 5000),
            ],
            lost: 4,
            winner_window: None,
        };
        let j = to_chrome_json(&dump);
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 events + 2 lane metadata entries (slot 1, worker 2).
        assert_eq!(evs.len(), 5);

        let prefill = &evs[0];
        assert_eq!(prefill.get("name").unwrap().as_str(), Some("prefill"));
        assert_eq!(prefill.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(prefill.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(prefill.get("dur").unwrap().as_f64(), Some(4.0));
        assert_eq!(prefill.get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(prefill.get("args").unwrap().get("req").unwrap().as_usize(), Some(7));

        let gemv = &evs[1];
        assert_eq!(gemv.get("cat").unwrap().as_str(), Some("kernel"));
        assert_eq!(gemv.get("tid").unwrap().as_f64(), Some(WORKER_TID_BASE + 2.0));

        let done = &evs[2];
        assert_eq!(done.get("ph").unwrap().as_str(), Some("i"));
        assert!(done.get("dur").is_none());

        let meta = &evs[3];
        assert_eq!(meta.get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(meta.get("args").unwrap().get("name").unwrap().as_str(), Some("slot 1"));

        let other = j.get("otherData").unwrap();
        assert_eq!(other.get("lost_events").unwrap().as_usize(), Some(4));
        assert!(other.get("partial").is_none(), "uncontended dump must not claim partiality");
        // The whole document must reparse (valid JSON for Perfetto).
        let text = j.to_string_compact();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn contended_dump_reports_partial_and_the_winners_window() {
        let dump = TraceDump {
            events: vec![ev(Phase::Prefill, 1, 0, 10_000, 20_000)],
            lost: 0,
            winner_window: Some((2_000, 7_000)),
        };
        let j = to_chrome_json(&dump);
        let other = j.get("otherData").unwrap();
        assert_eq!(other.get("partial"), Some(&Json::Bool(true)));
        assert_eq!(other.get("winner_drain_from_us").unwrap().as_f64(), Some(2.0));
        assert_eq!(other.get("winner_drain_until_us").unwrap().as_f64(), Some(7.0));
        let text = j.to_string_compact();
        assert!(Json::parse(&text).is_ok());
    }
}
