//! Lock-free per-thread span rings behind the flight recorder.
//!
//! Each recording thread owns one [`Ring`]: a fixed-capacity circular
//! buffer of 5-word binary events written with relaxed stores and published
//! with one release store of the `written` counter — no locks, no
//! allocation, no CAS on the hot path. A central drainer walks every
//! registered ring off-path with the classic seqlock recipe: read the
//! words, fence, re-read `written`, and discard any event the writer may
//! have lapped during the read. Lapping therefore never blocks the writer
//! (flight-recorder semantics: newest events win) and never yields torn
//! events — it only increments a `lost` count the dump reports honestly.
//!
//! Rings are pooled: when a recording thread exits, its ring (events
//! included) goes on a free list and the next new thread reuses it, so
//! short-lived connection threads don't grow the registry without bound.

use std::cell::RefCell;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{Phase, SpanEvent};

/// Words per encoded event: req, start_ns, end_ns, payload, meta.
const WORDS: usize = 5;

/// Default per-thread ring capacity in events (`FBQ_TRACE_BUF` overrides).
const DEFAULT_CAP: usize = 8192;

/// One single-writer, single-drainer event ring.
pub(crate) struct Ring {
    slots: Box<[AtomicU64]>,
    cap: u64,
    /// Events ever written (monotonic; publishes the slot words).
    written: AtomicU64,
    /// Events ever consumed or skipped by the drainer (drainer-only).
    drained: AtomicU64,
    /// Writer track id, for per-thread timeline lanes in the dump.
    track: u32,
}

impl Ring {
    pub(crate) fn new(cap: usize, track: u32) -> Ring {
        let cap = cap.max(16);
        let mut slots = Vec::with_capacity(cap * WORDS);
        slots.resize_with(cap * WORDS, || AtomicU64::new(0));
        Ring {
            slots: slots.into_boxed_slice(),
            cap: cap as u64,
            written: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            track,
        }
    }

    /// Hot path: store the event words, then publish with one release
    /// store. Single writer per ring, so plain (non-RMW) stores suffice.
    #[inline]
    pub(crate) fn push(&self, req: u64, start_ns: u64, end_ns: u64, payload: u64, meta: u64) {
        let w = self.written.load(Ordering::Relaxed);
        let base = (w % self.cap) as usize * WORDS;
        self.slots[base].store(req, Ordering::Relaxed);
        self.slots[base + 1].store(start_ns, Ordering::Relaxed);
        self.slots[base + 2].store(end_ns, Ordering::Relaxed);
        self.slots[base + 3].store(payload, Ordering::Relaxed);
        self.slots[base + 4].store(meta, Ordering::Relaxed);
        self.written.store(w + 1, Ordering::Release);
    }

    /// Drain all publishable events into `out`; returns how many events
    /// were lost to writer lapping (overwritten before we could read them).
    pub(crate) fn drain_into(&self, out: &mut Vec<SpanEvent>) -> u64 {
        let mut d = self.drained.load(Ordering::Relaxed);
        let w = self.written.load(Ordering::Acquire);
        let mut lost = 0u64;
        if w - d > self.cap {
            // Writer lapped us before this drain even started.
            lost += w - self.cap - d;
            d = w - self.cap;
        }
        let first = out.len();
        for e in d..w {
            let base = (e % self.cap) as usize * WORDS;
            let req = self.slots[base].load(Ordering::Relaxed);
            let start_ns = self.slots[base + 1].load(Ordering::Relaxed);
            let end_ns = self.slots[base + 2].load(Ordering::Relaxed);
            let payload = self.slots[base + 3].load(Ordering::Relaxed);
            let meta = self.slots[base + 4].load(Ordering::Relaxed);
            match decode_meta(meta) {
                Some((phase, slot)) => out.push(SpanEvent {
                    req,
                    start_ns,
                    end_ns,
                    payload,
                    phase,
                    slot,
                    track: (meta >> 32) as u32,
                }),
                // Unknown phase byte: torn beyond recognition; count it.
                None => lost += 1,
            }
        }
        // Seqlock re-check: any event the writer may have been overwriting
        // while we read (index < written_now + 1 - cap) is suspect — drop
        // it from what we keep and count it as lost instead.
        fence(Ordering::Acquire);
        let w2 = self.written.load(Ordering::Relaxed);
        let safe_min = (w2 + 1).saturating_sub(self.cap);
        if safe_min > d {
            let torn = (safe_min - d).min(w - d) as usize;
            let torn = torn.min(out.len() - first);
            out.drain(first..first + torn);
            lost += torn as u64;
        }
        self.drained.store(w, Ordering::Relaxed);
        lost
    }

    #[cfg(test)]
    fn written(&self) -> u64 {
        self.written.load(Ordering::Acquire)
    }
}

#[inline]
fn encode_meta(phase: Phase, slot: u16, track: u32) -> u64 {
    (phase as u8 as u64) | ((slot as u64) << 16) | ((track as u64) << 32)
}

#[inline]
fn decode_meta(meta: u64) -> Option<(Phase, u16)> {
    let phase = Phase::from_u8(meta as u8)?;
    Some((phase, (meta >> 16) as u16))
}

/// Every ring ever created (drain walks this), and exited threads' rings
/// awaiting reuse. A freed ring still holds its undrained events, so
/// nothing a dying thread recorded is lost.
static REGISTRY: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static FREE: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
/// Serializes drains (each ring is single-drainer by contract) and
/// remembers the last completed drain's window so a drainer that lost the
/// race can tell its caller which window the winner walked off with.
static DRAIN: Mutex<DrainState> = Mutex::new(DrainState { last_from_ns: 0, last_until_ns: 0 });

/// Trace-epoch window `[last_from_ns, last_until_ns]` consumed by the most
/// recent drain. Guarded by [`DRAIN`].
struct DrainState {
    last_from_ns: u64,
    last_until_ns: u64,
}

fn ring_capacity() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("FBQ_TRACE_BUF")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAP)
    })
}

/// Returns the ring to the free pool when its thread exits.
struct LocalRing(Arc<Ring>);

impl Drop for LocalRing {
    fn drop(&mut self) {
        if let Ok(mut free) = FREE.lock() {
            free.push(self.0.clone());
        }
    }
}

thread_local! {
    static LOCAL: RefCell<Option<LocalRing>> = const { RefCell::new(None) };
}

fn acquire_ring() -> LocalRing {
    if let Some(r) = FREE.lock().ok().and_then(|mut f| f.pop()) {
        return LocalRing(r);
    }
    static NEXT_TRACK: AtomicU64 = AtomicU64::new(0);
    let track = NEXT_TRACK.fetch_add(1, Ordering::Relaxed) as u32;
    let ring = Arc::new(Ring::new(ring_capacity(), track));
    if let Ok(mut reg) = REGISTRY.lock() {
        reg.push(ring.clone());
    }
    LocalRing(ring)
}

/// Record one event into the calling thread's ring (creating or reusing a
/// ring on first use). Safe to call from any thread; silently drops the
/// event if thread-local storage is already torn down.
#[inline]
pub(crate) fn record(req: u64, start_ns: u64, end_ns: u64, payload: u64, phase: Phase, slot: u16) {
    let _ = LOCAL.try_with(|l| {
        let mut l = l.borrow_mut();
        let ring = l.get_or_insert_with(acquire_ring);
        let track = ring.0.track;
        ring.0.push(req, start_ns, end_ns, payload, encode_meta(phase, slot, track));
    });
}

/// Drain every registered ring. Events are sorted by start time; `lost`
/// counts writer-lapped events across all rings since the last drain.
///
/// Drains serialize on [`DRAIN`]. A caller that had to wait for a
/// concurrent drain to finish gets `Some((from_ns, until_ns))` — the
/// trace-epoch window the winner consumed — so it can report its own
/// result as partial instead of silently returning half the stream.
pub(crate) fn drain_all() -> (Vec<SpanEvent>, u64, Option<(u64, u64)>) {
    use std::sync::TryLockError;
    let (mut st, contended) = match DRAIN.try_lock() {
        Ok(g) => (g, false),
        Err(TryLockError::WouldBlock) => (DRAIN.lock().unwrap_or_else(|e| e.into_inner()), true),
        Err(TryLockError::Poisoned(e)) => (e.into_inner(), false),
    };
    let winner = if contended { Some((st.last_from_ns, st.last_until_ns)) } else { None };
    let from_ns = st.last_until_ns;
    let rings: Vec<Arc<Ring>> = match REGISTRY.lock() {
        Ok(reg) => reg.clone(),
        Err(_) => Vec::new(),
    };
    let mut events = Vec::new();
    let mut lost = 0u64;
    for ring in &rings {
        lost += ring.drain_into(&mut events);
    }
    events.sort_by_key(|e| (e.start_ns, e.end_ns, e.req));
    st.last_from_ns = from_ns;
    st.last_until_ns = super::now_ns();
    (events, lost, winner)
}

/// Record an already-timed span (used when the caller captured the
/// interval itself, e.g. queue wait measured from the admission stamp).
pub(crate) fn record_closed(
    phase: Phase,
    req: u64,
    slot: u16,
    start_ns: u64,
    end_ns: u64,
    payload: u64,
) {
    record(req, start_ns, end_ns.max(start_ns), payload, phase, slot);
}

/// Record an instantaneous marker event.
pub(crate) fn record_instant(phase: Phase, req: u64, slot: u16, now_ns: u64, payload: u64) {
    record(req, now_ns, now_ns, payload, phase, slot);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SLOT_NONE;
    use std::sync::atomic::AtomicBool;

    fn ev(ring: &Ring, i: u64) {
        // Payload carries a checksum of req so torn events are detectable.
        ring.push(i, i * 10, i * 10 + 5, i.wrapping_mul(0x9e37), encode_meta(Phase::Draft, 3, 7));
    }

    #[test]
    fn drain_returns_events_in_order() {
        let r = Ring::new(64, 0);
        for i in 0..10 {
            ev(&r, i);
        }
        let mut out = Vec::new();
        let lost = r.drain_into(&mut out);
        assert_eq!(lost, 0);
        assert_eq!(out.len(), 10);
        for (i, e) in out.iter().enumerate() {
            assert_eq!(e.req, i as u64);
            assert_eq!(e.start_ns, i as u64 * 10);
            assert_eq!(e.end_ns, i as u64 * 10 + 5);
            assert_eq!(e.phase, Phase::Draft);
            assert_eq!(e.slot, 3);
            assert_eq!(e.track, 7);
        }
    }

    #[test]
    fn wraparound_keeps_newest_and_counts_lost() {
        let cap = 16;
        let r = Ring::new(cap, 0);
        let total = 3 * cap as u64 + 5;
        for i in 0..total {
            ev(&r, i);
        }
        let mut out = Vec::new();
        let lost = r.drain_into(&mut out);
        assert_eq!(out.len(), cap);
        assert_eq!(lost, total - cap as u64);
        // The survivors are exactly the newest `cap` events.
        assert_eq!(out.first().unwrap().req, total - cap as u64);
        assert_eq!(out.last().unwrap().req, total - 1);
    }

    #[test]
    fn repeated_drains_conserve_every_event() {
        let r = Ring::new(32, 0);
        let mut seen = 0u64;
        for round in 0..10u64 {
            for i in 0..20u64 {
                ev(&r, round * 20 + i);
            }
            let mut out = Vec::new();
            let lost = r.drain_into(&mut out);
            assert_eq!(lost, 0, "no overflow expected at this rate");
            seen += out.len() as u64;
        }
        assert_eq!(seen, 200);
    }

    #[test]
    fn concurrent_writers_conserve_counts() {
        // N writer threads, each with its own ring (single-writer
        // invariant), one drainer looping concurrently. Every written
        // event must end up either drained (with intact checksum) or
        // counted lost — never silently vanish, never torn.
        const WRITERS: usize = 4;
        const PER: u64 = 20_000;
        let rings: Vec<Arc<Ring>> = (0..WRITERS).map(|t| Arc::new(Ring::new(128, t as u32))).collect();
        let stop = Arc::new(AtomicBool::new(false));

        let handles: Vec<_> = rings
            .iter()
            .cloned()
            .map(|r| {
                std::thread::spawn(move || {
                    for i in 0..PER {
                        ev(&r, i);
                    }
                })
            })
            .collect();

        let drainer = {
            let rings = rings.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut drained = 0u64;
                let mut lost = 0u64;
                let mut out = Vec::new();
                loop {
                    let done = stop.load(Ordering::Acquire);
                    for r in &rings {
                        out.clear();
                        lost += r.drain_into(&mut out);
                        for e in &out {
                            assert_eq!(
                                e.payload,
                                e.req.wrapping_mul(0x9e37),
                                "torn event survived the seqlock check"
                            );
                            assert_eq!(e.phase, Phase::Draft);
                        }
                        drained += out.len() as u64;
                    }
                    if done {
                        return (drained, lost);
                    }
                }
            })
        };

        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Release);
        let (drained, lost) = drainer.join().unwrap();
        let written: u64 = rings.iter().map(|r| r.written()).sum();
        assert_eq!(written, WRITERS as u64 * PER);
        assert_eq!(drained + lost, written, "drain must conserve events");
        assert!(drained > 0, "drainer never kept anything");
    }

    #[test]
    fn contended_drain_reports_the_winners_window() {
        // Hold the drain lock to stand in for an in-flight winner, then
        // start a second drain on another thread: it must block, and once
        // the winner finishes it must report a winner window instead of
        // pretending its half-empty result is the whole stream.
        let mut st = DRAIN.lock().unwrap_or_else(|e| e.into_inner());
        st.last_from_ns = 100;
        st.last_until_ns = 900;
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let loser = std::thread::spawn(move || {
            started_tx.send(()).unwrap();
            drain_all()
        });
        started_rx.recv().unwrap();
        // Give the loser time to reach the lock before the winner releases.
        std::thread::sleep(std::time::Duration::from_millis(100));
        drop(st);
        let (_, _, winner) = loser.join().unwrap();
        // Another test's drain may slip in between release and the loser's
        // wakeup, so assert the shape of the window, not its exact values.
        let (from, until) = winner.expect("blocked drain must report the winner's window");
        assert!(until >= from, "window must be ordered: [{from}, {until}]");
    }

    #[test]
    fn meta_roundtrip() {
        let m = encode_meta(Phase::SwapOut, SLOT_NONE, 0xDEAD_BEEF);
        let (phase, slot) = decode_meta(m).unwrap();
        assert_eq!(phase, Phase::SwapOut);
        assert_eq!(slot, SLOT_NONE);
        assert_eq!((m >> 32) as u32, 0xDEAD_BEEF);
        assert!(decode_meta(0xFF).is_none(), "invalid phase byte must not decode");
    }
}
