//! Flight recorder: low-overhead request/phase tracing for the serving
//! stack.
//!
//! Every instrumented site records a compact binary [`SpanEvent`] (request
//! id, slot, phase, nanosecond interval, one payload word) into a
//! per-thread lock-free ring ([`ring`]). The hot path costs one relaxed
//! atomic load when tracing is off, and two `Instant` reads plus five
//! relaxed stores when on; draining, sorting and rendering all happen
//! off-path (`GET /debug/trace`, tests, the loadgen dump).
//!
//! Levels, from the `FBQ_TRACE` environment variable:
//! * `FBQ_TRACE=0` / `off` — recorder disarmed; event sites are a single
//!   relaxed load.
//! * unset / `1` / `request` — request-lifecycle phases: queue wait,
//!   prefill, per-step decode/draft/verify/sampler, KV swap-out/in, and
//!   the overload markers (shed, cancel, degrade transitions).
//! * `kernel` — additionally records per-layer kernel phases
//!   (gemv / attention / lm-head) from inside the engine step.
//!
//! The drained dump renders as Chrome trace-event JSON ([`chrome`]) that
//! loads directly in Perfetto, one lane per slot plus one per recording
//! thread.

pub mod chrome;
mod ring;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Slot value for events not bound to a scheduler slot.
pub const SLOT_NONE: u16 = u16::MAX;

/// Tracing verbosity tiers (`FBQ_TRACE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Recorder disarmed: every event site is one relaxed atomic load.
    Off = 0,
    /// Request-lifecycle phases and overload markers (the default).
    Request = 1,
    /// Request level plus per-layer kernel phases (gemv/attention/lm-head).
    Kernel = 2,
}

/// Phase taxonomy. Span phases carry a real interval; marker phases
/// (`is_marker`) are instantaneous lifecycle events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Phase {
    /// Admission queue wait: submit → slot placement.
    Queue = 0,
    /// Prompt prefill for one request.
    Prefill = 1,
    /// One decode step's share for one slot (payload: tokens committed).
    DecodeStep = 2,
    /// Speculative drafting across the batch (payload: draft rows).
    Draft = 3,
    /// Speculative verification pass (payload: verified rows).
    Verify = 4,
    /// Token sampling for one step across the batch.
    Sampler = 5,
    /// KV swap-out to the parking buffer (payload: bytes).
    SwapOut = 6,
    /// KV swap-in from the parking buffer (payload: bytes).
    SwapIn = 7,
    /// Kernel: batched GEMV group (kernel level only; payload: rows).
    Gemv = 8,
    /// Kernel: attention score/mix for one layer (kernel level only).
    Attention = 9,
    /// Kernel: lm-head selection (kernel level only; payload: rows).
    LmHead = 10,
    /// Marker: request finished normally (payload: generated tokens).
    Done = 11,
    /// Marker: request shed by admission control or pool pressure.
    Shed = 12,
    /// Marker: request cancelled (client disconnect).
    Cancel = 13,
    /// Marker: degradation level transition (payload: new level; req 0).
    Degrade = 14,
    /// Marker: request rejected at the HTTP edge before admission.
    Reject = 15,
}

impl Phase {
    pub fn from_u8(v: u8) -> Option<Phase> {
        use Phase::*;
        Some(match v {
            0 => Queue,
            1 => Prefill,
            2 => DecodeStep,
            3 => Draft,
            4 => Verify,
            5 => Sampler,
            6 => SwapOut,
            7 => SwapIn,
            8 => Gemv,
            9 => Attention,
            10 => LmHead,
            11 => Done,
            12 => Shed,
            13 => Cancel,
            14 => Degrade,
            15 => Reject,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        use Phase::*;
        match self {
            Queue => "queue",
            Prefill => "prefill",
            DecodeStep => "decode_step",
            Draft => "draft",
            Verify => "verify",
            Sampler => "sampler",
            SwapOut => "swap_out",
            SwapIn => "swap_in",
            Gemv => "gemv",
            Attention => "attention",
            LmHead => "lm_head",
            Done => "done",
            Shed => "shed",
            Cancel => "cancel",
            Degrade => "degrade",
            Reject => "reject",
        }
    }

    /// Kernel-level phases are only recorded at [`Level::Kernel`].
    pub fn is_kernel(&self) -> bool {
        matches!(self, Phase::Gemv | Phase::Attention | Phase::LmHead)
    }

    /// Marker phases are instantaneous (start == end).
    pub fn is_marker(&self) -> bool {
        matches!(
            self,
            Phase::Done | Phase::Shed | Phase::Cancel | Phase::Degrade | Phase::Reject
        )
    }

    /// Terminal markers end a request's timeline.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Phase::Done | Phase::Shed | Phase::Cancel | Phase::Reject)
    }
}

/// One decoded flight-recorder event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanEvent {
    /// Request id (0 for batch-wide or process-wide events).
    pub req: u64,
    /// Start, nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// End, nanoseconds since the epoch (== start for markers).
    pub end_ns: u64,
    /// Phase-specific payload word (tokens, rows, bytes, level...).
    pub payload: u64,
    pub phase: Phase,
    /// Scheduler slot, or [`SLOT_NONE`].
    pub slot: u16,
    /// Recording thread's track id.
    pub track: u32,
}

impl SpanEvent {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// A drained flight-recorder snapshot.
#[derive(Debug, Clone, Default)]
pub struct TraceDump {
    /// Events sorted by start time.
    pub events: Vec<SpanEvent>,
    /// Events overwritten by writer lapping before they could be drained.
    pub lost: u64,
    /// When another drain ran concurrently and won the serialization race,
    /// the trace-epoch ns window `[from, until]` that winner consumed.
    /// `Some` means this dump is partial: it holds only events recorded
    /// after the winner's drain, and the missing window went to the winner.
    pub winner_window: Option<(u64, u64)>,
}

// ---------------------------------------------------------------------------
// Level plumbing.

/// u8::MAX = "not yet initialized from the environment".
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level_from_env() -> u8 {
    let lvl = match std::env::var("FBQ_TRACE").ok().as_deref().map(str::trim) {
        Some("0") | Some("off") | Some("none") => Level::Off,
        Some("kernel") | Some("2") => Level::Kernel,
        _ => Level::Request,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level as a raw u8 (one relaxed load on the fast path).
#[inline]
fn level_u8() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_level_from_env()
    } else {
        l
    }
}

/// Current tracing level.
pub fn level() -> Level {
    match level_u8() {
        0 => Level::Off,
        2 => Level::Kernel,
        _ => Level::Request,
    }
}

/// Override the level at runtime (tests, benches, admin tooling).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True when request-lifecycle tracing is armed.
#[inline]
pub fn request_on() -> bool {
    level_u8() >= Level::Request as u8
}

/// True when kernel-phase tracing is armed.
#[inline]
pub fn kernel_on() -> bool {
    level_u8() >= Level::Kernel as u8
}

#[inline]
fn armed_for(phase: Phase) -> bool {
    if phase.is_kernel() {
        kernel_on()
    } else {
        request_on()
    }
}

// ---------------------------------------------------------------------------
// Time base.

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Pin the trace epoch to "now" if it isn't already set. Called at
/// coordinator/server startup so request timestamps are small positive
/// offsets; safe to call repeatedly.
pub fn init() {
    let _ = epoch();
}

/// Nanoseconds since the trace epoch.
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// A captured [`Instant`] as nanoseconds since the trace epoch
/// (saturating at 0 for instants predating the epoch).
#[inline]
pub fn instant_ns(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

// ---------------------------------------------------------------------------
// Recording API.

/// RAII span: records `[construction, drop]` as one event when armed.
/// When the recorder is off, construction is one relaxed load and drop
/// is a no-op.
#[must_use = "the span records its interval when dropped"]
pub struct Span {
    armed: bool,
    phase: Phase,
    req: u64,
    slot: u16,
    payload: u64,
    start_ns: u64,
}

impl Span {
    /// Set the payload word carried by the event (tokens, rows, bytes...).
    #[inline]
    pub fn payload(&mut self, p: u64) {
        self.payload = p;
    }

    /// End the span now (equivalent to dropping it).
    #[inline]
    pub fn end(self) {}
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if self.armed {
            ring::record(self.req, self.start_ns, now_ns(), self.payload, self.phase, self.slot);
        }
    }
}

/// Open a span for `phase` attributed to request `req` on `slot`
/// (use 0 / [`SLOT_NONE`] when not applicable).
#[inline]
pub fn span(phase: Phase, req: u64, slot: u16) -> Span {
    let armed = armed_for(phase);
    let start_ns = if armed { now_ns() } else { 0 };
    Span { armed, phase, req, slot, payload: 0, start_ns }
}

/// Record a span whose interval the caller already measured.
#[inline]
pub fn span_closed(phase: Phase, req: u64, slot: u16, start_ns: u64, end_ns: u64, payload: u64) {
    if armed_for(phase) {
        ring::record_closed(phase, req, slot, start_ns, end_ns, payload);
    }
}

/// Record an instantaneous marker event.
#[inline]
pub fn instant(phase: Phase, req: u64, slot: u16, payload: u64) {
    if armed_for(phase) {
        ring::record_instant(phase, req, slot, now_ns(), payload);
    }
}

/// Drain every thread's ring into one time-sorted dump. Draining consumes:
/// a second immediate drain returns only events recorded in between.
///
/// Concurrent drains serialize; the one that had to wait gets
/// [`TraceDump::winner_window`] set so its caller can report the dump as
/// partial rather than silently serving half the stream.
pub fn drain() -> TraceDump {
    let (events, lost, winner_window) = ring::drain_all();
    TraceDump { events, lost, winner_window }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The recorder and level are process-global, so tests that toggle the
    /// level or drain must not interleave with each other; they also only
    /// assert on events carrying their own request ids, never on global
    /// emptiness (other tests in this binary may record concurrently).
    static SERIAL: Mutex<()> = Mutex::new(());

    #[test]
    fn span_records_when_armed() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Request);
        let req = 0x5EED_0001;
        {
            let mut s = span(Phase::Prefill, req, 4);
            s.payload(17);
        }
        instant(Phase::Done, req, 4, 9);
        let dump = drain();
        let mine: Vec<_> = dump.events.iter().filter(|e| e.req == req).collect();
        assert_eq!(mine.len(), 2, "span + marker expected: {mine:?}");
        let prefill = mine.iter().find(|e| e.phase == Phase::Prefill).unwrap();
        assert!(prefill.end_ns >= prefill.start_ns);
        assert_eq!(prefill.payload, 17);
        assert_eq!(prefill.slot, 4);
        let done = mine.iter().find(|e| e.phase == Phase::Done).unwrap();
        assert_eq!(done.start_ns, done.end_ns);
        assert_eq!(done.payload, 9);
    }

    #[test]
    fn disabled_recorder_drops_events() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Off);
        let req = 0x5EED_0002;
        span(Phase::Prefill, req, 0).end();
        instant(Phase::Done, req, 0, 0);
        set_level(Level::Request);
        let dump = drain();
        assert!(
            dump.events.iter().all(|e| e.req != req),
            "events recorded while the level was Off"
        );
    }

    #[test]
    fn kernel_phases_gated_by_level() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Request);
        let req = 0x5EED_0003;
        span(Phase::Gemv, req, SLOT_NONE).end();
        set_level(Level::Kernel);
        span(Phase::Attention, req, SLOT_NONE).end();
        set_level(Level::Request);
        let dump = drain();
        let mine: Vec<_> = dump.events.iter().filter(|e| e.req == req).collect();
        assert_eq!(mine.len(), 1, "{mine:?}");
        assert_eq!(mine[0].phase, Phase::Attention);
    }

    #[test]
    fn phase_roundtrip_and_taxonomy() {
        for v in 0..=15u8 {
            let p = Phase::from_u8(v).unwrap();
            assert_eq!(p as u8, v);
            assert!(!p.name().is_empty());
            if p.is_kernel() {
                assert!(!p.is_marker());
            }
            if p.is_terminal() {
                assert!(p.is_marker());
            }
        }
        assert!(Phase::from_u8(16).is_none());
    }
}
