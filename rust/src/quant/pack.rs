//! Nibble packing of quantization codes (shared spec with
//! `python/compile/quantize_all.py`).
//!
//! Codes run along the input dimension; 8 codes per `u32` word, code `j`
//! occupying bits `[4j, 4j+4)`. Both 3- and 4-bit codes use a nibble (the
//! logical bit-width governs the code range / quantization grid; see
//! DESIGN.md §2 for the storage-format note).

/// Pack int codes (values 0..=15) into u32 words. `codes.len()` must be a
/// multiple of 8 per row; rows are `cin` long.
pub fn pack_codes(codes: &[i8], rows: usize, cin: usize) -> Vec<u32> {
    assert_eq!(codes.len(), rows * cin);
    assert_eq!(cin % 8, 0, "cin must be a multiple of 8");
    let words_per_row = cin / 8;
    let mut out = vec![0u32; rows * words_per_row];
    for r in 0..rows {
        for wi in 0..words_per_row {
            let mut word = 0u32;
            for j in 0..8 {
                let c = codes[r * cin + wi * 8 + j] as u32 & 0xF;
                word |= c << (4 * j);
            }
            out[r * words_per_row + wi] = word;
        }
    }
    out
}

/// Inverse of [`pack_codes`].
pub fn unpack_codes(packed: &[u32], rows: usize, cin: usize) -> Vec<i8> {
    let words_per_row = cin / 8;
    assert_eq!(packed.len(), rows * words_per_row);
    let mut out = vec![0i8; rows * cin];
    for r in 0..rows {
        for wi in 0..words_per_row {
            let word = packed[r * words_per_row + wi];
            for j in 0..8 {
                out[r * cin + wi * 8 + j] = ((word >> (4 * j)) & 0xF) as i8;
            }
        }
    }
    out
}

/// Iterate the 8 codes of one packed word (hot-path helper).
#[inline(always)]
pub fn word_codes(word: u32) -> [f32; 8] {
    [
        (word & 0xF) as f32,
        ((word >> 4) & 0xF) as f32,
        ((word >> 8) & 0xF) as f32,
        ((word >> 12) & 0xF) as f32,
        ((word >> 16) & 0xF) as f32,
        ((word >> 20) & 0xF) as f32,
        ((word >> 24) & 0xF) as f32,
        ((word >> 28) & 0xF) as f32,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_random() {
        let mut rng = Pcg64::seeded(5);
        for &(rows, cin) in &[(1usize, 8usize), (3, 16), (7, 64), (16, 128)] {
            let codes: Vec<i8> = (0..rows * cin).map(|_| rng.below(16) as i8).collect();
            let packed = pack_codes(&codes, rows, cin);
            assert_eq!(packed.len(), rows * cin / 8);
            assert_eq!(unpack_codes(&packed, rows, cin), codes);
        }
    }

    #[test]
    fn word_codes_matches_unpack() {
        let codes: Vec<i8> = (0..8).map(|i| (i * 2 % 16) as i8).collect();
        let packed = pack_codes(&codes, 1, 8);
        let w = word_codes(packed[0]);
        for j in 0..8 {
            assert_eq!(w[j], codes[j] as f32);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_multiple_of_8() {
        pack_codes(&[0i8; 12], 1, 12);
    }
}
