//! Low-rank sub-branch algebra: Σ = B·A and the FBQuant feedback
//! reconstruction, used by tests, the ablation benches and the engine.

use super::groupwise;

/// Low-rank factors A: `[r, in]`, B: `[out, r]`.
#[derive(Debug, Clone)]
pub struct SubBranch {
    pub rank: usize,
    pub cin: usize,
    pub out: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
}

impl SubBranch {
    pub fn new(a: Vec<f32>, b: Vec<f32>, rank: usize, cin: usize, out: usize) -> Self {
        assert_eq!(a.len(), rank * cin);
        assert_eq!(b.len(), out * rank);
        SubBranch { rank, cin, out, a, b }
    }

    /// Materialize Σ = B·A as `[out, in]` (analysis only — the runtime
    /// never forms this product).
    pub fn dense_sigma(&self) -> Vec<f32> {
        let mut sigma = vec![0f32; self.out * self.cin];
        for o in 0..self.out {
            for r in 0..self.rank {
                let bv = self.b[o * self.rank + r];
                if bv == 0.0 {
                    continue;
                }
                let arow = &self.a[r * self.cin..(r + 1) * self.cin];
                let srow = &mut sigma[o * self.cin..(o + 1) * self.cin];
                for c in 0..self.cin {
                    srow[c] += bv * arow[c];
                }
            }
        }
        sigma
    }

    /// y += B·(A·x) for a single activation vector (decode shape).
    pub fn apply_gemv(&self, x: &[f32], y: &mut [f32]) {
        let mut xa = vec![0f32; self.rank];
        for r in 0..self.rank {
            let arow = &self.a[r * self.cin..(r + 1) * self.cin];
            let mut acc = 0f32;
            for c in 0..self.cin {
                acc += arow[c] * x[c];
            }
            xa[r] = acc;
        }
        for o in 0..self.out {
            let brow = &self.b[o * self.rank..(o + 1) * self.rank];
            let mut acc = 0f32;
            for r in 0..self.rank {
                acc += brow[r] * xa[r];
            }
            y[o] += acc;
        }
    }
}

/// FBQuant reconstruction W_F = Q(W − Σ) + Σ (paper Eq. 11), dense form.
pub fn fbq_reconstruct(w: &[f32], sigma: &[f32], out: usize, cin: usize,
                       bits: u8, group: usize) -> Vec<f32> {
    let resid: Vec<f32> = w.iter().zip(sigma).map(|(a, b)| a - b).collect();
    let q = groupwise::quantize_dequantize(&resid, out, cin, bits, group);
    q.iter().zip(sigma).map(|(a, b)| a + b).collect()
}

/// The per-element bound s/2 of Eq. 13, expanded to `[out, in]`.
pub fn fbq_bound(w: &[f32], sigma: &[f32], out: usize, cin: usize,
                 bits: u8, group: usize) -> Vec<f32> {
    let resid: Vec<f32> = w.iter().zip(sigma).map(|(a, b)| a - b).collect();
    let p = groupwise::quant_params(&resid, out, cin, bits, group);
    let ngroups = cin / group;
    let mut bound = vec![0f32; out * cin];
    for r in 0..out {
        for c in 0..cin {
            bound[r * cin + c] = p.scales[r * ngroups + c / group] / 2.0;
        }
    }
    bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn fbq_bound_holds_for_wild_sigma() {
        // Eq. 13: the bound holds regardless of Σ's magnitude.
        let mut rng = Pcg64::seeded(21);
        let (out, cin, group) = (5usize, 32usize, 16usize);
        for &sig_scale in &[0.01f64, 1.0, 50.0] {
            let w: Vec<f32> = (0..out * cin).map(|_| rng.normal() as f32).collect();
            let a: Vec<f32> = (0..3 * cin).map(|_| (rng.normal() * sig_scale) as f32).collect();
            let b: Vec<f32> = (0..out * 3).map(|_| (rng.normal() * sig_scale) as f32).collect();
            let sb = SubBranch::new(a, b, 3, cin, out);
            let sigma = sb.dense_sigma();
            let wf = fbq_reconstruct(&w, &sigma, out, cin, 3, group);
            let bound = fbq_bound(&w, &sigma, out, cin, 3, group);
            for i in 0..w.len() {
                assert!(
                    (w[i] - wf[i]).abs() <= bound[i] + 1e-5,
                    "sig_scale={sig_scale} i={i}"
                );
            }
        }
    }

    #[test]
    fn conventional_reconstruction_is_unbounded() {
        // contrast: W' = Q(W) + Σ drifts with Σ (paper §3.1)
        let mut rng = Pcg64::seeded(22);
        let (out, cin) = (4usize, 16usize);
        let w: Vec<f32> = (0..out * cin).map(|_| rng.normal() as f32).collect();
        let q = groupwise::quantize_dequantize(&w, out, cin, 3, 16);
        let sigma = vec![10f32; out * cin];
        let w_rec: Vec<f32> = q.iter().zip(&sigma).map(|(a, b)| a + b).collect();
        let max_dev = w.iter().zip(&w_rec).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_dev > 5.0);
    }

    #[test]
    fn apply_gemv_matches_dense() {
        let mut rng = Pcg64::seeded(23);
        let (out, cin, rank) = (6usize, 12usize, 3usize);
        let a: Vec<f32> = (0..rank * cin).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..out * rank).map(|_| rng.normal() as f32).collect();
        let sb = SubBranch::new(a, b, rank, cin, out);
        let x: Vec<f32> = (0..cin).map(|_| rng.normal() as f32).collect();
        let sigma = sb.dense_sigma();
        let mut y = vec![0f32; out];
        sb.apply_gemv(&x, &mut y);
        for o in 0..out {
            let want: f32 = (0..cin).map(|c| sigma[o * cin + c] * x[c]).sum();
            assert!((y[o] - want).abs() < 1e-4);
        }
    }
}
