//! Quantization substrate: the `.fbqw` archive format, group-wise RTN
//! quantization/de-quantization, nibble bit-packing and low-rank
//! sub-branch algebra.
//!
//! Mirrors `python/compile/{pack,kernels/ref}.py` — conventions are shared
//! by specification and round-trip tested (`tests/cross_format.rs`).

pub mod formats;
pub mod groupwise;
pub mod pack;
pub mod subbranch;

pub use formats::{Archive, Dtype, TensorView};
pub use groupwise::{GroupQuant, QuantParams};
pub use pack::{pack_codes, unpack_codes};
