//! Reader/writer for the `.fbqw` tensor-archive format.
//!
//! Layout (little endian; see `python/compile/pack.py`, the authoring
//! side):
//!
//! ```text
//! magic   b"FBQW"
//! version u32 (=1)
//! hdr_len u64
//! header  utf-8 JSON {"meta": {...}, "tensors": [{name,dtype,shape,offset,nbytes}]}
//! payload tensors at 64-byte-aligned offsets (relative to payload start)
//! ```

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I8,
    U8,
    U32,
}

impl Dtype {
    pub fn from_name(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "i8" => Dtype::I8,
            "u8" => Dtype::U8,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype '{other}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::I8 => "i8",
            Dtype::U8 => "u8",
            Dtype::U32 => "u32",
        }
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
            Dtype::I8 | Dtype::U8 => 1,
        }
    }
}

/// One tensor inside an [`Archive`].
#[derive(Debug, Clone)]
pub struct TensorView {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub raw: Vec<u8>,
}

impl TensorView {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        self.expect(Dtype::F32)?;
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u32(&self) -> Result<Vec<u32>> {
        self.expect(Dtype::U32)?;
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        self.expect(Dtype::I32)?;
        Ok(self
            .raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        self.expect(Dtype::U8)?;
        Ok(&self.raw)
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        self.expect(Dtype::I8)?;
        Ok(self.raw.iter().map(|&b| b as i8).collect())
    }

    fn expect(&self, dt: Dtype) -> Result<()> {
        if self.dtype != dt {
            bail!("tensor '{}' is {}, expected {}", self.name, self.dtype.name(), dt.name());
        }
        let want = self.numel() * dt.size();
        if self.raw.len() != want {
            bail!("tensor '{}': payload {} bytes, expected {}", self.name, self.raw.len(), want);
        }
        Ok(())
    }
}

/// A loaded `.fbqw` archive: ordered tensors + JSON metadata.
#[derive(Debug)]
pub struct Archive {
    pub meta: Json,
    order: Vec<String>,
    tensors: HashMap<String, TensorView>,
}

const MAGIC: &[u8; 4] = b"FBQW";
const ALIGN: usize = 64;

impl Archive {
    pub fn load(path: &Path) -> Result<Archive> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut head = [0u8; 16];
        f.read_exact(&mut head)?;
        if &head[0..4] != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if version != 1 {
            bail!("{}: unsupported version {version}", path.display());
        }
        let hdr_len = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
        let mut hdr = vec![0u8; hdr_len];
        f.read_exact(&mut hdr)?;
        let header = Json::parse(std::str::from_utf8(&hdr)?)
            .map_err(|e| anyhow::anyhow!("{}: header {e}", path.display()))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;

        let meta = header.get("meta").cloned().unwrap_or(Json::Obj(vec![]));
        let mut order = Vec::new();
        let mut tensors = HashMap::new();
        fn req<'a>(e: &'a Json, key: &str) -> Result<&'a Json> {
            e.req(key).map_err(anyhow::Error::msg)
        }
        let entries = header
            .req("tensors")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("tensors not array")?;
        for e in entries {
            let name = req(e, "name")?.as_str().context("name")?.to_string();
            let dtype = Dtype::from_name(req(e, "dtype")?.as_str().context("dtype")?)?;
            let shape = req(e, "shape")?.as_usize_vec().context("shape")?;
            let offset = e.req("offset").map_err(anyhow::Error::msg)?.as_usize().context("offset")?;
            let nbytes = e.req("nbytes").map_err(anyhow::Error::msg)?.as_usize().context("nbytes")?;
            if offset + nbytes > payload.len() {
                bail!("{}: tensor '{name}' out of bounds", path.display());
            }
            let raw = payload[offset..offset + nbytes].to_vec();
            order.push(name.clone());
            tensors.insert(name.clone(), TensorView { name, dtype, shape, raw });
        }
        Ok(Archive { meta, order, tensors })
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn get(&self, name: &str) -> Result<&TensorView> {
        self.tensors
            .get(name)
            .with_context(|| format!("archive has no tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|j| j.as_str())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    /// Write an archive (used by tests and weight-conversion tools).
    pub fn write(
        path: &Path,
        tensors: &[(String, Dtype, Vec<usize>, Vec<u8>)],
        meta: &Json,
    ) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        let mut blobs: Vec<(usize, &Vec<u8>)> = Vec::new();
        for (name, dtype, shape, raw) in tensors {
            if offset % ALIGN != 0 {
                offset += ALIGN - offset % ALIGN;
            }
            entries.push(Json::obj(vec![
                ("name", Json::from(name.as_str())),
                ("dtype", Json::from(dtype.name())),
                ("shape", Json::from(shape.clone())),
                ("offset", Json::from(offset)),
                ("nbytes", Json::from(raw.len())),
            ]));
            blobs.push((offset, raw));
            offset += raw.len();
        }
        let header = Json::obj(vec![("meta", meta.clone()), ("tensors", Json::Arr(entries))])
            .to_string_compact();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        let payload_start = header.len() + 16;
        let mut pos = payload_start;
        for (off, raw) in blobs {
            let target = payload_start + off;
            if target > pos {
                f.write_all(&vec![0u8; target - pos])?;
                pos = target;
            }
            f.write_all(raw)?;
            pos += raw.len();
        }
        Ok(())
    }
}

/// f32 slice -> raw little-endian bytes (writer helper).
pub fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    xs.iter().flat_map(|x| x.to_le_bytes()).collect()
}

/// u32 slice -> raw bytes.
pub fn u32_bytes(xs: &[u32]) -> Vec<u8> {
    xs.iter().flat_map(|x| x.to_le_bytes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("fbq_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.fbqw");
        let data = vec![1.5f32, -2.0, 3.25];
        let tensors = vec![
            ("x".to_string(), Dtype::F32, vec![3], f32_bytes(&data)),
            ("y".to_string(), Dtype::U8, vec![2, 2], vec![1, 2, 3, 4]),
        ];
        let meta = Json::obj(vec![("kind", Json::from("test"))]);
        Archive::write(&path, &tensors, &meta).unwrap();
        let arc = Archive::load(&path).unwrap();
        assert_eq!(arc.names(), &["x".to_string(), "y".to_string()]);
        assert_eq!(arc.get("x").unwrap().as_f32().unwrap(), data);
        assert_eq!(arc.get("y").unwrap().as_u8().unwrap(), &[1, 2, 3, 4]);
        assert_eq!(arc.meta_str("kind"), Some("test"));
        assert!(arc.get("zzz").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("fbq_fmt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.fbqw");
        std::fs::write(&path, b"NOPE____________").unwrap();
        assert!(Archive::load(&path).is_err());
    }
}
