//! Group-wise asymmetric RTN quantization (rust mirror of
//! `python/compile/kernels/ref.py`).
//!
//! Conventions: weights `[out, in]` row-major; groups of `group`
//! consecutive input channels share one `(scale, zero)`;
//! `code = clip(round(w/scale) + zero, 0, 2^bits − 1)`;
//! `dequant = (code − zero) · scale`. The range always covers zero.

/// Per-layer quantization parameters.
#[derive(Debug, Clone)]
pub struct QuantParams {
    pub bits: u8,
    pub group: usize,
    /// `[out, in/group]`
    pub scales: Vec<f32>,
    /// `[out, in/group]`
    pub zeros: Vec<f32>,
}

impl QuantParams {
    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// A fully quantized matrix (codes unpacked).
#[derive(Debug, Clone)]
pub struct GroupQuant {
    pub out: usize,
    pub cin: usize,
    pub params: QuantParams,
    /// `[out, in]` int codes
    pub codes: Vec<i8>,
}

/// Compute (scale, zero) per (row, group) for `w: [out, in]`.
pub fn quant_params(w: &[f32], out: usize, cin: usize, bits: u8, group: usize) -> QuantParams {
    assert_eq!(w.len(), out * cin);
    assert_eq!(cin % group, 0);
    let ngroups = cin / group;
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut scales = vec![0f32; out * ngroups];
    let mut zeros = vec![0f32; out * ngroups];
    for r in 0..out {
        for g in 0..ngroups {
            let seg = &w[r * cin + g * group..r * cin + (g + 1) * group];
            let mut lo = 0f32;
            let mut hi = 0f32;
            for &v in seg {
                if v < lo {
                    lo = v;
                }
                if v > hi {
                    hi = v;
                }
            }
            let scale = ((hi - lo) / qmax).max(1e-8);
            scales[r * ngroups + g] = scale;
            zeros[r * ngroups + g] = (-lo / scale).round();
        }
    }
    QuantParams { bits, group, scales, zeros }
}

/// RTN-quantize `w` with the given params.
pub fn quantize(w: &[f32], out: usize, cin: usize, p: &QuantParams) -> Vec<i8> {
    let ngroups = cin / p.group;
    let qmax = p.qmax();
    let mut codes = vec![0i8; out * cin];
    for r in 0..out {
        for g in 0..ngroups {
            let scale = p.scales[r * ngroups + g];
            let zero = p.zeros[r * ngroups + g];
            for c in 0..p.group {
                let idx = r * cin + g * p.group + c;
                let q = (w[idx] / scale).round() + zero;
                codes[idx] = q.clamp(0.0, qmax) as i8;
            }
        }
    }
    codes
}

/// De-quantize codes back to float `[out, in]`.
pub fn dequantize(codes: &[i8], out: usize, cin: usize, p: &QuantParams) -> Vec<f32> {
    let ngroups = cin / p.group;
    let mut w = vec![0f32; out * cin];
    for r in 0..out {
        for g in 0..ngroups {
            let scale = p.scales[r * ngroups + g];
            let zero = p.zeros[r * ngroups + g];
            for c in 0..p.group {
                let idx = r * cin + g * p.group + c;
                w[idx] = (codes[idx] as f32 - zero) * scale;
            }
        }
    }
    w
}

/// One-shot fake quantization (convenience for tests/benches).
pub fn quantize_dequantize(w: &[f32], out: usize, cin: usize, bits: u8, group: usize) -> Vec<f32> {
    let p = quant_params(w, out, cin, bits, group);
    let codes = quantize(w, out, cin, &p);
    dequantize(&codes, out, cin, &p)
}

/// Re-quantize an already-quantized matrix at a (lower) bit-width: the
/// **shadow pack** a self-speculative draft decodes on. The main branch
/// is de-quantized (sub-branch excluded — the draft is the bare branch)
/// and RTN-requantized at `bits` with the same group geometry, so the
/// shadow approximates the codes the verifier streams, at a fraction of
/// the weight bytes.
pub fn requantize(
    codes: &[i8],
    out: usize,
    cin: usize,
    p: &QuantParams,
    bits: u8,
) -> (Vec<i8>, QuantParams) {
    let w = dequantize(codes, out, cin, p);
    let p2 = quant_params(&w, out, cin, bits, p.group);
    let c2 = quantize(&w, out, cin, &p2);
    (c2, p2)
}

impl GroupQuant {
    pub fn from_weights(w: &[f32], out: usize, cin: usize, bits: u8, group: usize) -> Self {
        let params = quant_params(w, out, cin, bits, group);
        let codes = quantize(w, out, cin, &params);
        GroupQuant { out, cin, params, codes }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        dequantize(&self.codes, self.out, self.cin, &self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_w(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let mut rng = Pcg64::seeded(11);
        for &bits in &[2u8, 3, 4] {
            let (out, cin, group) = (6, 64, 16);
            let w = rand_w(&mut rng, out * cin, 0.7);
            let p = quant_params(&w, out, cin, bits, group);
            let codes = quantize(&w, out, cin, &p);
            let wq = dequantize(&codes, out, cin, &p);
            let ngroups = cin / group;
            for r in 0..out {
                for c in 0..cin {
                    let s = p.scales[r * ngroups + c / group];
                    let err = (w[r * cin + c] - wq[r * cin + c]).abs();
                    assert!(err <= s / 2.0 + 1e-6, "bits={bits} err={err} s/2={}", s / 2.0);
                }
            }
        }
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Pcg64::seeded(12);
        let w = rand_w(&mut rng, 4 * 32, 2.0);
        for &bits in &[3u8, 4] {
            let gq = GroupQuant::from_weights(&w, 4, 32, bits, 16);
            let qmax = (1i8 << bits) - 1;
            assert!(gq.codes.iter().all(|&c| (0..=qmax).contains(&c)));
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Pcg64::seeded(13);
        let (out, cin) = (8, 128);
        let w = rand_w(&mut rng, out * cin, 1.0);
        let mse = |bits: u8| -> f64 {
            let wq = quantize_dequantize(&w, out, cin, bits, 32);
            w.iter().zip(&wq).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        assert!(mse(4) < mse(3));
        assert!(mse(3) < mse(2));
    }

    #[test]
    fn requantize_tracks_the_dequantized_matrix() {
        let mut rng = Pcg64::seeded(14);
        let (out, cin, group) = (8usize, 64usize, 16usize);
        let w = rand_w(&mut rng, out * cin, 0.6);
        let p4 = quant_params(&w, out, cin, 4, group);
        let c4 = quantize(&w, out, cin, &p4);
        let w4 = dequantize(&c4, out, cin, &p4);
        let (c2, p2) = requantize(&c4, out, cin, &p4, 2);
        assert_eq!(p2.bits, 2);
        assert_eq!(p2.group, group);
        assert!(c2.iter().all(|&c| (0..=3).contains(&c)));
        // the shadow's error is bounded by its own grid, relative to the
        // 4-bit matrix it was re-packed from
        let w2 = dequantize(&c2, out, cin, &p2);
        let ngroups = cin / group;
        for r in 0..out {
            for c in 0..cin {
                let s = p2.scales[r * ngroups + c / group];
                let err = (w4[r * cin + c] - w2[r * cin + c]).abs();
                assert!(err <= s / 2.0 + 1e-6, "err={err} s/2={}", s / 2.0);
            }
        }
    }

    #[test]
    fn zero_weight_is_exact() {
        // the grid always covers 0, so 0.0 quantizes exactly
        let w = vec![0.0f32, 0.5, -0.25, 0.0, 1.0, -1.0, 0.75, 0.0];
        let p = quant_params(&w, 1, 8, 4, 8);
        let codes = quantize(&w, 1, 8, &p);
        let wq = dequantize(&codes, 1, 8, &p);
        assert!(wq[0].abs() < 1e-6 && wq[3].abs() < 1e-6 && wq[7].abs() < 1e-6);
    }
}
