//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! HLO **text** is the interchange format (see `aot.py` and
//! /opt/xla-example/README.md: serialized HloModuleProto from jax ≥ 0.5
//! carries 64-bit instruction ids that xla_extension 0.5.1 rejects).

use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT context (one CPU client).
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for PjrtContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtContext(platform={})", self.client.platform_name())
    }
}

impl PjrtContext {
    pub fn cpu() -> Result<PjrtContext> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtContext { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Build a literal from f32 data with a shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(&dims).context("reshaping f32 literal")
}

/// Build a literal from i32 data with a shape (scalar shape = rank 0).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    if shape.is_empty() {
        return Ok(xla::Literal::from(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(&dims).context("reshaping i32 literal")
}
