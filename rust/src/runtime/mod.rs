//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! * [`artifact`] — `manifest.json` parsing (artifact specs, tensor specs),
//! * [`pjrt`] — the `xla` crate wrapper: client, compile, literal
//!   marshalling,
//! * [`exec`] — executable registry + weight feeding from a
//!   [`crate::model::WeightStore`].

pub mod artifact;
pub mod exec;
pub mod pjrt;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use exec::{ExecRegistry, LoadedExec, Value};
pub use pjrt::PjrtContext;
