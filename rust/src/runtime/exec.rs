//! Executable registry: compile-once, run-many management of the AOT
//! artifacts, plus weight feeding from `.fbqw` checkpoints.
//!
//! The AOT graphs take weights as runtime parameters. The registry
//! marshals a checkpoint into the artifact's parameter order once and
//! caches the literals, so the per-request cost is only the data inputs
//! (tokens / kv state).

use super::artifact::{ArtifactSpec, Manifest, TensorSpec};
use super::pjrt::{literal_f32, literal_i32, PjrtContext};
use crate::model::WeightStore;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// A host value heading into (or out of) an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            _ => bail!("value is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            _ => bail!("value is not i32"),
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        match (self, spec.dtype.as_str()) {
            (Value::F32(v), "f32") => {
                if v.len() != spec.numel() {
                    bail!("input '{}': {} elements, expected {}", spec.name, v.len(), spec.numel());
                }
                literal_f32(v, &spec.shape)
            }
            (Value::I32(v), "i32") => {
                if v.len() != spec.numel() {
                    bail!("input '{}': {} elements, expected {}", spec.name, v.len(), spec.numel());
                }
                literal_i32(v, &spec.shape)
            }
            (v, dt) => bail!("input '{}': value/dtype mismatch ({v:?} vs {dt})", spec.name),
        }
    }
}

/// Data inputs (non-weight): fed per call.
const DATA_INPUTS: &[&str] = &["tokens", "pos0", "kv_k", "kv_v", "x"];

fn is_data_input(name: &str) -> bool {
    DATA_INPUTS.contains(&name)
}

/// One compiled artifact.
pub struct LoadedExec {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for LoadedExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "LoadedExec({})", self.spec.name)
    }
}

impl LoadedExec {
    /// Run with `data` values for the leading data inputs and `weights`
    /// literals for the remaining parameters. Outputs are flattened to
    /// host [`Value`]s in manifest order.
    pub fn run(&self, data: &[Value], weights: &[xla::Literal]) -> Result<Vec<Value>> {
        // data inputs are the leading parameters; weight literals cover the
        // rest (kernel artifacts have no weights — everything is data)
        if data.len() + weights.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} params total, got {} data + {} weights",
                self.spec.name,
                self.spec.inputs.len(),
                data.len(),
                weights.len()
            );
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(data.len());
        for (v, spec) in data.iter().zip(&self.spec.inputs) {
            args.push(v.to_literal(spec)?);
        }
        let mut borrowed: Vec<&xla::Literal> = args.iter().collect();
        borrowed.extend(weights.iter());

        let result = self.exe.execute::<&xla::Literal>(&borrowed)?;
        let out_lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple at top level.
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact '{}': {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            match ospec.dtype.as_str() {
                "f32" => out.push(Value::F32(lit.to_vec::<f32>()?)),
                "i32" => out.push(Value::I32(lit.to_vec::<i32>()?)),
                dt => bail!("unsupported output dtype {dt}"),
            }
        }
        Ok(out)
    }
}

/// Compile-and-feed cache keyed by artifact name / checkpoint identity.
pub struct ExecRegistry {
    pub ctx: PjrtContext,
    pub manifest: Manifest,
    execs: HashMap<String, Arc<LoadedExec>>,
    weight_feeds: HashMap<String, Arc<Vec<xla::Literal>>>,
}

impl std::fmt::Debug for ExecRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ExecRegistry({} compiled)", self.execs.len())
    }
}

impl ExecRegistry {
    pub fn new(ctx: PjrtContext, manifest: Manifest) -> ExecRegistry {
        ExecRegistry { ctx, manifest, execs: HashMap::new(), weight_feeds: HashMap::new() }
    }

    pub fn open(artifacts_root: &std::path::Path) -> Result<ExecRegistry> {
        Ok(ExecRegistry::new(PjrtContext::cpu()?, Manifest::load(artifacts_root)?))
    }

    /// Compile (or fetch) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<Arc<LoadedExec>> {
        if let Some(e) = self.execs.get(name) {
            return Ok(Arc::clone(e));
        }
        let spec = self.manifest.find(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        crate::log_info!("compiling artifact '{name}' from {}", path.display());
        let exe = self.ctx.compile_hlo_text(&path)?;
        let loaded = Arc::new(LoadedExec { spec, exe });
        self.execs.insert(name.to_string(), Arc::clone(&loaded));
        Ok(loaded)
    }

    /// Build (or fetch) the weight-literal feed for `(artifact, store)`.
    pub fn weight_feed(&mut self, exec: &LoadedExec, store: &WeightStore,
                       cache_key: &str) -> Result<Arc<Vec<xla::Literal>>> {
        let key = format!("{}::{cache_key}", exec.spec.name);
        if let Some(w) = self.weight_feeds.get(&key) {
            return Ok(Arc::clone(w));
        }
        let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
        self.weight_feeds.insert(key, Arc::clone(&feed));
        Ok(feed)
    }

    pub fn drop_weight_feeds(&mut self) {
        self.weight_feeds.clear();
    }
}

/// Marshal a checkpoint into an artifact's weight-parameter order.
pub fn build_weight_feed(spec: &ArtifactSpec, store: &WeightStore) -> Result<Vec<xla::Literal>> {
    let mut feed = Vec::new();
    for t in spec.inputs.iter().skip_while(|t| is_data_input(&t.name)) {
        let lit = if let Some((prefix, field)) = t.name.split_once('/') {
            // quantized-linear tensor
            let lw = store.linear(prefix)?;
            match (lw, field) {
                (crate::model::LinearWeights::Quant { .. }, "codes") => {
                    let codes = lw.unpacked_codes()?;
                    let i32s: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
                    literal_i32(&i32s, &t.shape)?
                }
                (crate::model::LinearWeights::Quant { scales, .. }, "scales") => {
                    literal_f32(scales, &t.shape)?
                }
                (crate::model::LinearWeights::Quant { zeros, .. }, "zeros") => {
                    literal_f32(zeros, &t.shape)?
                }
                (crate::model::LinearWeights::Quant { a, .. }, "a") => match a {
                    Some(a) if a.len() == t.numel() => literal_f32(a, &t.shape)?,
                    // methods without a sub-branch (or mismatched rank
                    // ablations) feed zeros: Σ = 0
                    _ => literal_f32(&vec![0f32; t.numel()], &t.shape)?,
                },
                (crate::model::LinearWeights::Quant { b, .. }, "b") => match b {
                    Some(b) if b.len() == t.numel() => literal_f32(b, &t.shape)?,
                    _ => literal_f32(&vec![0f32; t.numel()], &t.shape)?,
                },
                (crate::model::LinearWeights::Quant { col_scale, .. }, "col_scale") => {
                    match col_scale {
                        Some(cs) => literal_f32(cs, &t.shape)?,
                        None => literal_f32(&vec![1f32; t.numel()], &t.shape)?,
                    }
                }
                (crate::model::LinearWeights::Dense { .. }, _) => {
                    bail!(
                        "artifact '{}' is quantized but checkpoint layer '{prefix}' is dense",
                        spec.name
                    )
                }
                (_, other) => bail!("unknown quant field '{other}'"),
            }
        } else {
            // plain float parameter
            let v = store.float(&t.name)?;
            if v.len() != t.numel() {
                bail!("weight '{}': {} elements, artifact wants {}", t.name, v.len(), t.numel());
            }
            literal_f32(v, &t.shape)?
        };
        feed.push(lit);
    }
    Ok(feed)
}
