//! `artifacts/manifest.json` parsing: which HLO artifacts exist, and the
//! ordered input/output tensor specs the runtime marshals against.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name").map_err(anyhow::Error::msg)?.as_str().context("name")?.to_string(),
            shape: j.req("shape").map_err(anyhow::Error::msg)?.as_usize_vec().context("shape")?,
            dtype: j
                .req("dtype")
                .map_err(anyhow::Error::msg)?
                .as_str()
                .context("dtype")?
                .to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub kind: String,
    pub model: Option<String>,
    pub quantized: bool,
    pub batch: usize,
    pub seq: usize,
    pub t_step: usize,
    pub rank: usize,
    pub group: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(artifacts_root: &Path) -> Result<Manifest> {
        let path = artifacts_root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
        let mut artifacts = Vec::new();
        for a in j.req("artifacts").map_err(anyhow::Error::msg)?.as_arr().context("artifacts")? {
            let get_usize = |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            artifacts.push(ArtifactSpec {
                name: a
                    .req("name")
                    .map_err(anyhow::Error::msg)?
                    .as_str()
                    .context("name")?
                    .to_string(),
                path: a
                    .req("path")
                    .map_err(anyhow::Error::msg)?
                    .as_str()
                    .context("path")?
                    .to_string(),
                kind: a.get("kind").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                model: a.get("model").and_then(|v| v.as_str()).map(|s| s.to_string()),
                quantized: a.get("quantized").and_then(|v| v.as_bool()).unwrap_or(false),
                batch: get_usize("batch"),
                seq: get_usize("seq"),
                t_step: get_usize("t_step"),
                rank: get_usize("rank"),
                group: get_usize("group"),
                inputs: a
                    .req("inputs").map_err(anyhow::Error::msg)?
                    .as_arr().context("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs").map_err(anyhow::Error::msg)?
                    .as_arr().context("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(Manifest { root: artifacts_root.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("manifest has no artifact '{name}'"))
    }

    /// e.g. `score_llamoid-tiny_q`, `decode_llamoid-tiny_q_b4`
    pub fn score_name(model: &str, quantized: bool) -> String {
        format!("score_{model}_{}", if quantized { "q" } else { "fp" })
    }

    pub fn step_name(kind: &str, model: &str, quantized: bool, batch: usize) -> String {
        format!("{kind}_{model}_{}_b{batch}", if quantized { "q" } else { "fp" })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.root.join(&spec.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("fbq_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[{"name":"score_m_fp","path":"hlo/x.hlo.txt",
                "kind":"score","model":"m","quantized":false,"batch":4,"seq":256,
                "inputs":[{"name":"tokens","shape":[4,256],"dtype":"i32"}],
                "outputs":[{"name":"logits","shape":[4,256,256],"dtype":"f32"}]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("score_m_fp").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.inputs[0].numel(), 1024);
        assert!(m.find("nope").is_err());
        assert_eq!(Manifest::score_name("m", true), "score_m_q");
        assert_eq!(Manifest::step_name("decode", "m", false, 4), "decode_m_fp_b4");
    }
}
