//! Property-based testing mini-framework (offline substitute for proptest).
//!
//! A [`Gen`] produces random values from a seeded [`Pcg64`]; [`check`]
//! runs a property over N generated cases and, on failure, retries with a
//! simple halving shrink over the generator's `size` parameter to report a
//! smaller counterexample. Coordinator invariants and quantization
//! round-trip properties use this from `rust/tests/`. [`synth`] writes
//! tiny quantized checkpoints so engine-level tests and benches run
//! without build artifacts.

pub mod synth;

pub use synth::{synth_checkpoint, SynthSpec};

use crate::util::Pcg64;

/// Generation context: RNG + a size bound generators scale with.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1).min(self.size.max(1)))
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_normal(&mut self, scale: f32) -> f32 {
        (self.rng.normal() as f32) * scale
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_normal(scale)).collect()
    }

    pub fn vec_u32(&mut self, n: usize, below: usize) -> Vec<u32> {
        (0..n).map(|_| self.rng.below(below) as u32).collect()
    }

    pub fn pick<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { seed: u64, size: usize, message: String },
}

/// Run `prop` over `cases` generated inputs. The property returns
/// `Err(message)` to signal failure; panics are not caught (the test
/// harness reports them with the seed printed beforehand).
pub fn check<P>(name: &str, cases: usize, prop: P) -> PropResult
where
    P: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed =
        0xfb90_u64 ^ name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut size = 2 + case % 64;
        let mut rng = Pcg64::seeded(seed);
        let mut g = Gen { rng: &mut rng, size };
        if let Err(msg) = prop(&mut g) {
            // shrink: retry with halved sizes on the same seed
            let mut best = (size, msg);
            while size > 2 {
                size /= 2;
                let mut rng = Pcg64::seeded(seed);
                let mut g = Gen { rng: &mut rng, size };
                match prop(&mut g) {
                    Err(m) => best = (size, m),
                    Ok(()) => break,
                }
            }
            return PropResult::Failed { seed, size: best.0, message: best.1 };
        }
    }
    PropResult::Ok { cases }
}

/// Assert helper: unwraps a [`PropResult`] into a test failure message.
#[macro_export]
macro_rules! prop_assert_ok {
    ($res:expr) => {
        match $res {
            $crate::testing::PropResult::Ok { .. } => {}
            $crate::testing::PropResult::Failed { seed, size, message } => {
                panic!("property failed (seed={seed}, size={size}): {message}")
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = check("commutes", 50, |g| {
            let a = g.f32_normal(1.0);
            let b = g.f32_normal(1.0);
            if (a + b - (b + a)).abs() < 1e-9 {
                Ok(())
            } else {
                Err("addition does not commute?!".into())
            }
        });
        assert!(matches!(r, PropResult::Ok { cases: 50 }));
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = check("always-fails", 5, |g| {
            let n = g.usize_in(1, 100);
            Err(format!("n={n}"))
        });
        match r {
            PropResult::Failed { message, .. } => assert!(message.starts_with("n=")),
            _ => panic!("expected failure"),
        }
    }
}
