//! Synthesized tiny quantized checkpoints: artifact-free model fixtures
//! for tests and benches.
//!
//! Writes a 4-bit group-wise "llamoid" checkpoint (optional sub-branch
//! A/B and AWQ-style `col_scale`) into the system temp dir and loads it
//! back as a [`WeightStore`] — no python build required. Used by
//! `rust/tests/batched_decode.rs`, `rust/tests/spec_decode.rs` and the
//! `microbench_kernels` speculative sweep.

use crate::model::WeightStore;
use crate::quant::formats::{f32_bytes, u32_bytes, Archive, Dtype};
use crate::quant::groupwise;
use crate::quant::pack::pack_codes;
use crate::util::json::Json;
use crate::util::Pcg64;

/// Geometry + quantization knobs of a synthesized checkpoint.
#[derive(Debug, Clone, Copy)]
pub struct SynthSpec {
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub group: usize,
    /// 0 = no sub-branch tensors
    pub rank: usize,
    /// Scale of the random sub-branch A/B entries. 0.0 writes all-zero
    /// A/B: the layer still *reads* the sub-branch (full weight
    /// traffic) while contributing exactly nothing — the deterministic
    /// full-acceptance fixture for speculative-decode tests.
    pub sub_scale: f32,
    pub col_scale: bool,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            d: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 96,
            vocab: 50,
            max_seq: 64,
            group: 16,
            rank: 4,
            sub_scale: 0.05,
            col_scale: false,
        }
    }
}

/// Write a tiny quantized llamoid checkpoint (4-bit groupwise) named by
/// `tag` under the system temp dir and load it back. Deterministic for a
/// given `(tag, spec)`.
pub fn synth_checkpoint(tag: &str, spec: SynthSpec) -> WeightStore {
    let SynthSpec {
        d,
        n_layers,
        n_heads,
        d_ff,
        vocab,
        max_seq,
        group,
        rank,
        sub_scale,
        col_scale,
    } = spec;
    let dir = std::env::temp_dir().join("fbq_synth_ckpts");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.fbqw"));
    let mut rng = Pcg64::seeded(0xbd0 ^ (d as u64) ^ ((rank as u64) << 8));
    let mut tensors: Vec<(String, Dtype, Vec<usize>, Vec<u8>)> = Vec::new();

    let randn = |rng: &mut Pcg64, n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    };
    let tok_emb = randn(&mut rng, vocab * d, 0.5);
    let lm_head = randn(&mut rng, vocab * d, 0.2);
    tensors.push(("tok_emb".to_string(), Dtype::F32, vec![vocab, d], f32_bytes(&tok_emb)));
    tensors.push(("lm_head".to_string(), Dtype::F32, vec![vocab, d], f32_bytes(&lm_head)));
    let fnw: Vec<f32> = (0..d).map(|i| 1.0 + 0.01 * (i % 7) as f32).collect();
    tensors.push(("final_norm.w".to_string(), Dtype::F32, vec![d], f32_bytes(&fnw)));

    for l in 0..n_layers {
        for nm in ["attn_norm", "mlp_norm"] {
            let w: Vec<f32> = (0..d).map(|i| 1.0 + 0.02 * ((i + l) % 5) as f32).collect();
            tensors.push((format!("l{l}.{nm}.w"), Dtype::F32, vec![d], f32_bytes(&w)));
        }
        for name in ["q", "k", "v", "o", "gate", "up", "down"] {
            let (out, cin) = match name {
                "q" | "k" | "v" | "o" => (d, d),
                "gate" | "up" => (d_ff, d),
                _ => (d, d_ff),
            };
            let prefix = format!("l{l}.{name}");
            let w = randn(&mut rng, out * cin, 0.2);
            let p = groupwise::quant_params(&w, out, cin, 4, group);
            let codes = groupwise::quantize(&w, out, cin, &p);
            let packed = pack_codes(&codes, out, cin);
            tensors.push((
                format!("{prefix}/codes_packed"),
                Dtype::U32,
                vec![out, cin / 8],
                u32_bytes(&packed),
            ));
            tensors.push((
                format!("{prefix}/scales"),
                Dtype::F32,
                vec![out, cin / group],
                f32_bytes(&p.scales),
            ));
            tensors.push((
                format!("{prefix}/zeros"),
                Dtype::F32,
                vec![out, cin / group],
                f32_bytes(&p.zeros),
            ));
            if rank > 0 {
                let a = randn(&mut rng, rank * cin, sub_scale);
                let b = randn(&mut rng, out * rank, sub_scale);
                tensors.push((format!("{prefix}/a"), Dtype::F32, vec![rank, cin], f32_bytes(&a)));
                tensors.push((format!("{prefix}/b"), Dtype::F32, vec![out, rank], f32_bytes(&b)));
            }
            if col_scale {
                let cs: Vec<f32> = (0..cin).map(|_| 0.5 + rng.next_f32()).collect();
                tensors.push((
                    format!("{prefix}/col_scale"),
                    Dtype::F32,
                    vec![cin],
                    f32_bytes(&cs),
                ));
            }
        }
    }

    let cfg = Json::obj(vec![
        ("name", Json::from(tag)),
        ("family", Json::from("llamoid")),
        ("d_model", Json::from(d)),
        ("n_layers", Json::from(n_layers)),
        ("n_heads", Json::from(n_heads)),
        ("d_ff", Json::from(d_ff)),
        ("vocab", Json::from(vocab)),
        ("max_seq", Json::from(max_seq)),
        ("rope_theta", Json::from(10000.0f64)),
    ]);
    let meta = Json::obj(vec![
        ("config", cfg),
        ("scheme", Json::from("quant")),
        ("method", Json::from("synthetic")),
        ("bits", Json::from(4usize)),
        ("group", Json::from(group)),
        ("rank", Json::from(rank)),
    ]);
    Archive::write(&path, &tensors, &meta).unwrap();
    WeightStore::load(&path).unwrap()
}
