//! In-repo micro-benchmark harness (offline substitute for criterion).
//!
//! Benches are `harness = false` binaries under `rust/benches/`; each uses
//! [`Bench`] for warmup + repeated timed runs with mean/stddev reporting,
//! and [`table`] helpers to print paper-style tables.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    /// fastest observed iteration — robust to scheduler steal-time on
    /// shared vCPUs, and the statistic the latency benches report
    pub min_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn min_us(&self) -> f64 {
        self.min_s * 1e6
    }

    pub fn min_ms(&self) -> f64 {
        self.min_s * 1e3
    }
}

/// Benchmark runner: fixed warmup iterations, then `iters` timed runs.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 5 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Bench {
        Bench { warmup, iters }
    }

    /// Time `f` (which must do a full unit of work per call).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        BenchResult {
            name: name.to_string(),
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: min,
            iters: samples.len(),
        }
    }
}

/// Fixed-width table printing for bench output (paper-style rows).
pub mod table {
    /// Print a header row followed by a rule.
    pub fn header(cols: &[(&str, usize)]) {
        let mut line = String::new();
        let mut rule = String::new();
        for (name, w) in cols {
            line.push_str(&format!("{name:>w$}  ", w = w));
            rule.push_str(&"-".repeat(w + 2));
        }
        println!("{line}");
        println!("{rule}");
    }

    /// One formatted cell value.
    pub fn fmt_cell(v: f64, decimals: usize) -> String {
        format!("{v:.decimals$}")
    }
}

/// Environment knob: `FBQ_BENCH_FAST=1` shrinks bench workloads for smoke
/// runs (CI / `cargo bench` sanity) while keeping the full grid by default.
pub fn fast_mode() -> bool {
    std::env::var("FBQ_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench::new(1, 3);
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.mean_s > 0.0);
        assert_eq!(r.iters, 3);
        assert!(acc > 0);
    }
}
