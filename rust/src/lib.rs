//! # FBQuant — FeedBack Quantization for Large Language Models
//!
//! Rust layer-3 of the three-layer reproduction of *FBQuant: FeedBack
//! Quantization for Large Language Models* (IJCAI 2025).
//!
//! The crate hosts:
//! * [`tensor`] — dense tensor substrate (f32 / packed-int), BLAS-free
//!   matmul/GEMV and the NN ops the native engine needs,
//! * [`quant`] — group-wise RTN quantization, INT3/INT4 bit-packing, the
//!   `.fbqw` weight-archive format and low-rank sub-branch algebra,
//! * [`model`] — model configurations, weight stores and the byte tokenizer,
//! * [`engine`] — the native inference engine with fused / un-fused
//!   quantized kernels (the wall-clock testbed for Figs 1/4/7),
//! * [`spec`] — self-speculative decoding: draft on the bare quantized
//!   branch (or a lower-bit shadow pack), verify all draft positions in
//!   one weight-stationary multi-position pass,
//! * [`runtime`] — the PJRT runtime loading AOT HLO artifacts produced by
//!   `python/compile/aot.py`,
//! * [`coordinator`] — request router, dynamic batcher, prefill/decode
//!   scheduler, sessions, sampling and metrics,
//! * [`serve`] — the std-only HTTP/1.1 + SSE serving front end over the
//!   spawned coordinator, its loopback client and the open-loop load
//!   harness behind `BENCH_serve.json`,
//! * [`trace`] — the flight recorder: lock-free per-thread span rings,
//!   request/phase/kernel tracing levels (`FBQ_TRACE`), and the Chrome
//!   trace-event renderer behind `GET /debug/trace`,
//! * [`eval`] — perplexity, zero-shot multiple-choice and pairwise-judge
//!   harnesses reproducing the paper's Tables 1–8 and Fig 6,
//! * [`bench`] / [`testing`] — in-repo micro-benchmark and property-test
//!   frameworks (offline substitutes for criterion / proptest).

pub mod util;
pub mod tensor;
pub mod quant;
pub mod model;
pub mod engine;
pub mod spec;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod trace;
pub mod eval;
pub mod bench;
pub mod testing;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifact tree produced by `make artifacts`.
///
/// Resolution order: `$FBQ_ARTIFACTS`, then `./artifacts` relative to the
/// current working directory, then `../artifacts` (for tests running from
/// the crate dir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FBQ_ARTIFACTS") {
        return std::path::PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts", "/root/repo/artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}
