//! Per-session KV cache for the native engine.
//!
//! Layout: one contiguous buffer per layer per side, `[max_seq, n_heads,
//! head_dim]` row-major — a decode step appends one `[n_heads, head_dim]`
//! slab, and attention reads per-head strided slices.

#[derive(Debug, Clone)]
pub struct KvCache {
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub len: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(n_layers: usize, max_seq: usize, n_heads: usize, head_dim: usize) -> Self {
        let per = max_seq * n_heads * head_dim;
        KvCache {
            n_layers,
            max_seq,
            n_heads,
            head_dim,
            len: 0,
            k: (0..n_layers).map(|_| vec![0f32; per]).collect(),
            v: (0..n_layers).map(|_| vec![0f32; per]).collect(),
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Bytes resident for this session (coordinator memory accounting).
    pub fn resident_bytes(&self) -> usize {
        2 * self.n_layers * self.max_seq * self.n_heads * self.head_dim * 4
    }

    /// Append `k_t`/`v_t` (each `[n_heads * head_dim]`) for layer `l` at
    /// position `pos`. Positions must be appended in order by the caller;
    /// `advance()` moves the shared length after all layers are written.
    pub fn write(&mut self, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        let stride = self.n_heads * self.head_dim;
        debug_assert!(pos < self.max_seq, "kv overflow: pos {pos} >= {}", self.max_seq);
        debug_assert_eq!(k_t.len(), stride);
        self.k[l][pos * stride..(pos + 1) * stride].copy_from_slice(k_t);
        self.v[l][pos * stride..(pos + 1) * stride].copy_from_slice(v_t);
    }

    pub fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.max_seq);
    }

    /// K vector of (layer, position, head).
    #[inline]
    pub fn k_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let stride = self.n_heads * self.head_dim;
        let base = pos * stride + h * self.head_dim;
        &self.k[l][base..base + self.head_dim]
    }

    #[inline]
    pub fn v_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let stride = self.n_heads * self.head_dim;
        let base = pos * stride + h * self.head_dim;
        &self.v[l][base..base + self.head_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut kv = KvCache::new(2, 8, 2, 4);
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        kv.write(1, 3, &k, &v);
        kv.advance(4);
        assert_eq!(kv.len, 4);
        assert_eq!(kv.k_at(1, 3, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(kv.k_at(1, 3, 1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(kv.v_at(1, 3, 1), &[-4.0, -5.0, -6.0, -7.0]);
    }

    #[test]
    fn resident_bytes_accounting() {
        let kv = KvCache::new(2, 256, 4, 32);
        assert_eq!(kv.resident_bytes(), 2 * 2 * 256 * 4 * 32 * 4);
    }
}
