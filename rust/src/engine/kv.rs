//! KV storage for the native engine: a dense per-session cache and a
//! paged, prefix-sharing pool.
//!
//! Two backing stores implement one [`KvSlot`] interface the engine
//! decodes against:
//!
//! * [`KvCache`] — the dense baseline: one contiguous `[max_seq, n_heads,
//!   head_dim]` buffer per layer per side. Simple, but every sequence
//!   pays `max_seq` capacity up front, so slot count is bounded by
//!   worst-case memory, not by actual load.
//! * [`KvPagePool`] + [`PagedKv`] — the paged path (default for the
//!   native backend): the pool owns fixed-size **pages** of `page_size`
//!   positions (all layers, both sides) on a free list; a [`PagedKv`]
//!   view maps logical positions to pages on demand, so a slot's
//!   resident bytes track its true sequence length. Pages are
//!   **refcounted**: admissions whose prompt shares a cached prefix map
//!   the same read-only pages (see [`KvPagePool::adopt_prefix`]) and a
//!   write into a shared page triggers copy-on-write
//!   ([`KvPagePool::ensure_range`]). Speculative draft mirrors borrow a
//!   slot's committed pages through the same machinery
//!   ([`KvPagePool::alias_kv`] / [`KvPagePool::retain_shared_prefix`]),
//!   so drafting costs one CoW page per in-flight window instead of a
//!   second KV budget.
//!
//! Admission accounting follows the store: the dense cache's
//! [`KvCache::resident_bytes`] is its full allocation (capacity *is*
//! resident for a dense buffer), while the paged view reports
//! `pages * page_bytes` — the number that actually moves when sequences
//! are short, and the one shed decisions should watch (see
//! [`KvPoolStats`]).
//!
//! Layout inside a page: `[n_layers, page_size, n_heads * head_dim]`
//! row-major, K and V in separate arenas, so a whole page is one
//! contiguous slab per side (copy-on-write is two `copy_within` calls)
//! and attention reads gather page-contiguous runs.

use crate::tensor::ops;
use anyhow::{bail, Result};
use std::collections::{HashMap, VecDeque};

/// The engine-facing KV interface: one generation slot's readable and
/// appendable key/value history. Implemented by the dense [`KvCache`]
/// and by [`PagedKvRef`] (a [`PagedKv`] view bound to its pool).
///
/// `Sync` is a supertrait so batched views over slots can be shared
/// read-only across the attention-gather worker threads (see
/// [`KvSlotBatch`]); every implementor is plain owned data or exclusive
/// borrows of it.
pub trait KvSlot: Sync {
    /// Committed sequence length (next write position).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Positions left before this slot is full.
    fn remaining(&self) -> usize;

    /// Bytes actually backing this slot (admission accounting).
    fn resident_bytes(&self) -> usize;

    /// Store `k_t`/`v_t` (each `[n_heads * head_dim]`) for layer `l` at
    /// position `pos`. Positions are written in order by the engine;
    /// [`KvSlot::advance`] commits the shared length after all layers.
    fn write(&mut self, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]);

    fn advance(&mut self, n: usize);

    /// Roll the committed length back to `len` (`len <= self.len()`),
    /// discarding everything past it — the rollback primitive
    /// speculative decoding uses to drop rejected draft positions. On
    /// the paged store, pages past the new length are released (shared
    /// pages just drop one reference).
    fn truncate(&mut self, len: usize);

    /// K vector of (layer, position, head).
    fn k_at(&self, l: usize, pos: usize, h: usize) -> &[f32];

    fn v_at(&self, l: usize, pos: usize, h: usize) -> &[f32];

    /// Attention scores `q . k_j * scale` for `j` in `0..scores.len()`.
    fn score_keys(&self, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]) {
        for (j, s) in scores.iter_mut().enumerate() {
            *s = ops::dot(q, self.k_at(l, j, h)) * scale;
        }
    }

    /// `out += sum_j weights[j] * v_j` for `j` in `0..weights.len()`.
    fn accumulate_values(&self, l: usize, h: usize, weights: &[f32], out: &mut [f32]) {
        for (j, &w) in weights.iter().enumerate() {
            ops::axpy(w, self.v_at(l, j, h), out);
        }
    }
}

// ---------------------------------------------------------------------------
// Dense cache (baseline)
// ---------------------------------------------------------------------------

/// Dense per-session KV cache: one contiguous buffer per layer per side,
/// `[max_seq, n_heads, head_dim]` row-major. The full capacity is
/// allocated at construction — the paged pool below exists because this
/// is exactly what caps slot count under memory pressure.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub n_layers: usize,
    pub max_seq: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub len: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(n_layers: usize, max_seq: usize, n_heads: usize, head_dim: usize) -> Self {
        let per = max_seq * n_heads * head_dim;
        KvCache {
            n_layers,
            max_seq,
            n_heads,
            head_dim,
            len: 0,
            k: (0..n_layers).map(|_| vec![0f32; per]).collect(),
            v: (0..n_layers).map(|_| vec![0f32; per]).collect(),
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Bytes resident for this session. For a dense cache this is the
    /// full `max_seq` allocation regardless of `len` — the honest number
    /// for a buffer that really is allocated, and the reason dense slots
    /// admit poorly: a 10-token sequence pins the same memory as a full
    /// one. Compare [`KvCache::used_bytes`] and the paged pool's
    /// per-page accounting.
    pub fn resident_bytes(&self) -> usize {
        2 * self.n_layers * self.max_seq * self.n_heads * self.head_dim * 4
    }

    /// Bytes covering positions actually written (`len`), not capacity.
    pub fn used_bytes(&self) -> usize {
        2 * self.n_layers * self.len * self.n_heads * self.head_dim * 4
    }

    /// Append `k_t`/`v_t` (each `[n_heads * head_dim]`) for layer `l` at
    /// position `pos`. Positions must be appended in order by the caller;
    /// `advance()` moves the shared length after all layers are written.
    pub fn write(&mut self, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        let stride = self.n_heads * self.head_dim;
        debug_assert!(pos < self.max_seq, "kv overflow: pos {pos} >= {}", self.max_seq);
        debug_assert_eq!(k_t.len(), stride);
        self.k[l][pos * stride..(pos + 1) * stride].copy_from_slice(k_t);
        self.v[l][pos * stride..(pos + 1) * stride].copy_from_slice(v_t);
    }

    pub fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.max_seq);
    }

    /// Roll back to `len` positions; stale data past the new length is
    /// never read (gathers are bounded by `len`) and is overwritten by
    /// the next append.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate {len} past len {}", self.len);
        self.len = len;
    }

    /// K vector of (layer, position, head).
    #[inline]
    pub fn k_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let stride = self.n_heads * self.head_dim;
        let base = pos * stride + h * self.head_dim;
        &self.k[l][base..base + self.head_dim]
    }

    #[inline]
    pub fn v_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let stride = self.n_heads * self.head_dim;
        let base = pos * stride + h * self.head_dim;
        &self.v[l][base..base + self.head_dim]
    }
}

impl KvSlot for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn remaining(&self) -> usize {
        KvCache::remaining(self)
    }

    fn resident_bytes(&self) -> usize {
        KvCache::resident_bytes(self)
    }

    fn write(&mut self, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        KvCache::write(self, l, pos, k_t, v_t);
    }

    fn advance(&mut self, n: usize) {
        KvCache::advance(self, n);
    }

    fn truncate(&mut self, len: usize) {
        KvCache::truncate(self, len);
    }

    fn k_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        KvCache::k_at(self, l, pos, h)
    }

    fn v_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        KvCache::v_at(self, l, pos, h)
    }

    fn score_keys(&self, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]) {
        let stride = self.n_heads * self.head_dim;
        let base_h = h * self.head_dim;
        let kl = &self.k[l];
        for (j, s) in scores.iter_mut().enumerate() {
            let b = j * stride + base_h;
            *s = ops::dot(q, &kl[b..b + self.head_dim]) * scale;
        }
    }

    fn accumulate_values(&self, l: usize, h: usize, weights: &[f32], out: &mut [f32]) {
        let stride = self.n_heads * self.head_dim;
        let base_h = h * self.head_dim;
        let vl = &self.v[l];
        for (j, &w) in weights.iter().enumerate() {
            let b = j * stride + base_h;
            ops::axpy(w, &vl[b..b + self.head_dim], out);
        }
    }
}

// ---------------------------------------------------------------------------
// Parking buffer (preemption swap-out/swap-in)
// ---------------------------------------------------------------------------

/// Host-side parking buffer for a preempted slot: a bit-exact copy of
/// the committed positions `0..len`, detached from any backing store.
///
/// Produced by [`KvCache::park`] / [`KvPagePool::park_kv`] and restored
/// by [`KvCache::unpark`] / [`KvPagePool::unpark_kv`]. Parking a paged
/// view releases its pages back to the pool (that is the point:
/// swap-out frees the memory a higher-class admission needs); restoring
/// maps fresh private pages and writes the exact same values back, so a
/// resumed slot decodes bit-identically to one that was never parked.
#[derive(Debug, Clone)]
pub struct ParkedKv {
    len: usize,
    /// `n_heads * head_dim` (row width, for geometry checks on restore)
    stride: usize,
    /// per-layer `[len * stride]` rows
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl ParkedKv {
    /// Committed positions held by this parking buffer.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Host bytes held while parked (swap accounting).
    pub fn bytes(&self) -> usize {
        2 * 4 * self.k.len() * self.len * self.stride
    }
}

impl KvCache {
    /// Copy the committed positions `0..len` into a [`ParkedKv`]. The
    /// dense cache keeps its allocation (capacity is the dense cost
    /// model), so parking here exists for exactness parity with the
    /// paged path, not to free memory.
    pub fn park(&self) -> ParkedKv {
        let stride = self.n_heads * self.head_dim;
        let take = |side: &[Vec<f32>]| -> Vec<Vec<f32>> {
            side.iter().map(|l| l[..self.len * stride].to_vec()).collect()
        };
        ParkedKv { len: self.len, stride, k: take(&self.k), v: take(&self.v) }
    }

    /// Restore a parked slot: write the saved rows back over positions
    /// `0..parked.len` and set the committed length. The cache must
    /// have the same geometry it was parked from.
    pub fn unpark(&mut self, parked: &ParkedKv) {
        let stride = self.n_heads * self.head_dim;
        assert_eq!(parked.stride, stride, "unpark into a different geometry");
        assert_eq!(parked.k.len(), self.n_layers, "unpark layer mismatch");
        assert!(parked.len <= self.max_seq, "parked slot exceeds max_seq");
        for l in 0..self.n_layers {
            self.k[l][..parked.len * stride].copy_from_slice(&parked.k[l]);
            self.v[l][..parked.len * stride].copy_from_slice(&parked.v[l]);
        }
        self.len = parked.len;
    }
}

// ---------------------------------------------------------------------------
// Paged pool
// ---------------------------------------------------------------------------

/// Geometry of a [`KvPagePool`].
#[derive(Debug, Clone, Copy)]
pub struct KvPoolConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Positions covered by one page.
    pub page_size: usize,
    /// Total pages in the pool (the memory budget).
    pub n_pages: usize,
    /// Prefix-cache entry cap (0 disables prefix reuse).
    pub max_cached_prefixes: usize,
}

impl KvPoolConfig {
    /// Geometry with the default prefix-cache cap (64 entries).
    pub fn new(
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
        page_size: usize,
        n_pages: usize,
    ) -> KvPoolConfig {
        KvPoolConfig { n_layers, n_heads, head_dim, page_size, n_pages, max_cached_prefixes: 64 }
    }
}

/// Pool counters surfaced into serving metrics: real memory pressure
/// (`pages_in_use`, not dense capacity) plus prefix-reuse and
/// copy-on-write activity.
#[derive(Debug, Default, Clone, Copy)]
pub struct KvPoolStats {
    pub pages_total: usize,
    pub pages_in_use: usize,
    pub peak_pages_in_use: usize,
    /// Prompt admissions that consulted the prefix cache.
    pub prefix_lookups: usize,
    /// Admissions that mapped at least one cached page.
    pub prefix_hits: usize,
    /// Prompt positions served from shared pages instead of prefill.
    pub prefix_tokens_reused: usize,
    /// Shared pages privatized on first divergent write.
    pub cow_copies: usize,
    /// Pages adopted by reference into another view (draft mirrors
    /// aliasing a target slot's committed pages: a refcount bump, no
    /// copy and no new page).
    pub pages_aliased: usize,
    /// Page allocations that failed with the pool exhausted.
    pub alloc_failures: usize,
    /// Live prefix-cache entries.
    pub cached_prefixes: usize,
    /// Prefix-cache entries dropped (capacity cap or memory pressure).
    pub prefix_evictions: usize,
}

/// A per-slot paged view: logical positions `0..len` mapped to pool
/// pages in order. Created by [`KvPagePool::new_kv`]; all allocation,
/// sharing and release goes through the pool. Bind it to its pool with
/// [`PagedKvRef`] to read/write through the [`KvSlot`] interface.
///
/// Deliberately neither `Clone` nor `Default`: the page table encodes
/// pool refcounts, so a free-standing copy would alias pages without
/// the pool knowing (double release, writes through two views).
#[derive(Debug)]
pub struct PagedKv {
    pages: Vec<u32>,
    len: usize,
    max_seq: usize,
}

impl PagedKv {
    /// An empty view bound to no pages yet — [`KvPagePool::new_kv`]
    /// without borrowing the pool (the draft mirrors occupy slots before
    /// they can see the pool).
    pub(crate) fn empty(max_seq: usize) -> PagedKv {
        PagedKv { pages: Vec::new(), len: 0, max_seq }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Pages currently mapped by this view.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Physical page ids, in logical order (tests / introspection).
    pub fn page_ids(&self) -> &[u32] {
        &self.pages
    }
}

struct PrefixEntry {
    tokens: Vec<u32>,
    pages: Vec<u32>,
}

/// A shared arena of fixed-size KV pages with free-list allocation,
/// per-page refcounts, prompt-prefix sharing and copy-on-write.
///
/// ```
/// use fbquant::engine::kv::{KvPagePool, KvPoolConfig, KvSlot, PagedKvRef};
///
/// // 2 layers x 2 heads x 4 dims, 8 positions per page, 16 pages total
/// let mut pool = KvPagePool::new(KvPoolConfig::new(2, 2, 4, 8, 16));
/// let mut kv = pool.new_kv(64);
/// pool.ensure_range(&mut kv, 0, 1).unwrap();
/// let mut slot = PagedKvRef { pool: &mut pool, kv: &mut kv };
/// slot.write(0, 0, &[1.0; 8], &[2.0; 8]);
/// slot.write(1, 0, &[3.0; 8], &[4.0; 8]);
/// slot.advance(1);
/// assert_eq!(slot.len(), 1);
/// assert_eq!(slot.k_at(0, 0, 1), &[1.0; 4]);
/// drop(slot);
/// assert_eq!(pool.pages_in_use(), 1);
/// ```
pub struct KvPagePool {
    cfg: KvPoolConfig,
    /// `[n_pages, n_layers, page_size, n_heads * head_dim]`
    k: Vec<f32>,
    v: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<u32>,
    prefix: HashMap<u64, PrefixEntry>,
    /// insertion order for FIFO eviction
    prefix_order: VecDeque<u64>,
    stats: KvPoolStats,
}

// FNV-1a over token bytes; collisions are disambiguated by comparing
// the stored tokens. The streaming form lets one forward pass over a
// prompt yield the hash at every page boundary.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

#[inline]
fn fnv_step(mut h: u64, t: u32) -> u64 {
    for b in t.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hash of `tokens[..k * page_size]` for each k, in one pass.
fn page_boundary_hashes(tokens: &[u32], page_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / page_size);
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens.iter().enumerate() {
        h = fnv_step(h, t);
        if (i + 1) % page_size == 0 {
            out.push(h);
        }
    }
    out
}

impl KvPagePool {
    pub fn new(cfg: KvPoolConfig) -> KvPagePool {
        assert!(cfg.page_size > 0, "zero page size");
        assert!(cfg.n_pages > 0, "zero-page pool");
        let per_page = cfg.n_layers * cfg.page_size * cfg.n_heads * cfg.head_dim;
        KvPagePool {
            k: vec![0f32; cfg.n_pages * per_page],
            v: vec![0f32; cfg.n_pages * per_page],
            refcount: vec![0; cfg.n_pages],
            // pop() takes from the back: keep page 0 first out
            free: (0..cfg.n_pages as u32).rev().collect(),
            prefix: HashMap::new(),
            prefix_order: VecDeque::new(),
            stats: KvPoolStats { pages_total: cfg.n_pages, ..KvPoolStats::default() },
            cfg,
        }
    }

    pub fn cfg(&self) -> &KvPoolConfig {
        &self.cfg
    }

    /// K+V bytes held by one page.
    pub fn page_bytes(&self) -> usize {
        2 * 4 * self.cfg.n_layers * self.cfg.page_size * self.cfg.n_heads * self.cfg.head_dim
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.cfg.n_pages - self.free.len()
    }

    /// Refcount of a physical page (tests / introspection).
    pub fn page_refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// Counter snapshot with the live gauges filled in.
    pub fn stats(&self) -> KvPoolStats {
        let mut s = self.stats;
        s.pages_in_use = self.pages_in_use();
        s.cached_prefixes = self.prefix.len();
        s
    }

    /// An empty paged view for a sequence of at most `max_seq` positions.
    pub fn new_kv(&self, max_seq: usize) -> PagedKv {
        PagedKv { pages: Vec::new(), len: 0, max_seq }
    }

    fn page_span(&self) -> usize {
        self.cfg.n_layers * self.cfg.page_size * self.cfg.n_heads * self.cfg.head_dim
    }

    /// Pop a free page (refcount 1), evicting cached prefixes under
    /// memory pressure until one frees up.
    fn alloc_page(&mut self) -> Option<u32> {
        loop {
            if let Some(p) = self.free.pop() {
                debug_assert_eq!(self.refcount[p as usize], 0);
                self.refcount[p as usize] = 1;
                let in_use = self.pages_in_use();
                if in_use > self.stats.peak_pages_in_use {
                    self.stats.peak_pages_in_use = in_use;
                }
                return Some(p);
            }
            if !self.evict_oldest_prefix() {
                self.stats.alloc_failures += 1;
                return None;
            }
        }
    }

    fn release_page(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "releasing page {page} with refcount 0");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    fn evict_oldest_prefix(&mut self) -> bool {
        while let Some(key) = self.prefix_order.pop_front() {
            if let Some(e) = self.prefix.remove(&key) {
                for &p in &e.pages {
                    self.release_page(p);
                }
                self.stats.prefix_evictions += 1;
                return true;
            }
        }
        false
    }

    /// Make positions `start..end` writable for `kv`: map missing pages
    /// from the free list and privatize (copy-on-write) any shared page
    /// in the range. Errors — without touching engine state — when the
    /// pool is exhausted even after evicting cached prefixes; the caller
    /// should [`KvPagePool::release_kv`] and shed.
    pub fn ensure_range(&mut self, kv: &mut PagedKv, start: usize, end: usize) -> Result<()> {
        if end <= start {
            return Ok(());
        }
        if end > kv.max_seq {
            bail!("kv range {start}..{end} exceeds max_seq {}", kv.max_seq);
        }
        let ps = self.cfg.page_size;
        for page_idx in start / ps..=(end - 1) / ps {
            if page_idx < kv.pages.len() {
                let p = kv.pages[page_idx];
                if self.refcount[p as usize] > 1 {
                    // shared (prefix-cache or sibling slot): privatize
                    let Some(np) = self.alloc_page() else {
                        bail!(
                            "kv pool exhausted on copy-on-write ({} of {} pages in use)",
                            self.pages_in_use(),
                            self.cfg.n_pages
                        );
                    };
                    let span = self.page_span();
                    let (src, dst) = (p as usize * span, np as usize * span);
                    self.k.copy_within(src..src + span, dst);
                    self.v.copy_within(src..src + span, dst);
                    self.release_page(p);
                    kv.pages[page_idx] = np;
                    self.stats.cow_copies += 1;
                }
            } else {
                debug_assert_eq!(page_idx, kv.pages.len(), "pages must fill in order");
                let Some(p) = self.alloc_page() else {
                    bail!(
                        "kv pool exhausted ({} of {} pages in use)",
                        self.pages_in_use(),
                        self.cfg.n_pages
                    );
                };
                kv.pages.push(p);
            }
        }
        Ok(())
    }

    /// Drop all of `kv`'s page references (pages whose refcount reaches
    /// zero return to the free list) and reset the view.
    pub fn release_kv(&mut self, kv: &mut PagedKv) {
        for i in 0..kv.pages.len() {
            self.release_page(kv.pages[i]);
        }
        kv.pages.clear();
        kv.len = 0;
    }

    /// Roll `kv` back to `len` positions, releasing every page past the
    /// last one still needed (speculative rollback: rejected draft
    /// positions — and any pages over-reserved for them — return to the
    /// free list). A released page shared with the prefix cache or a
    /// sibling slot only drops this view's reference. The retained
    /// boundary page keeps any stale data past `len`; it is never read
    /// (gathers are bounded by `len`) and the next write to a shared
    /// boundary page still goes through [`KvPagePool::ensure_range`]'s
    /// copy-on-write.
    pub fn truncate_kv(&mut self, kv: &mut PagedKv, len: usize) {
        assert!(len <= kv.len, "truncate {len} past len {}", kv.len);
        let keep = if len == 0 { 0 } else { (len - 1) / self.cfg.page_size + 1 };
        while kv.pages.len() > keep {
            let p = kv.pages.pop().expect("len checked above");
            self.release_page(p);
        }
        kv.len = len;
    }

    /// Map the longest cached page-aligned prefix of `prompt` into the
    /// empty view `kv` (bumping page refcounts) and return the number of
    /// positions reused. At least one prompt position is always left
    /// unconsumed so prefill still produces last-token logits; when the
    /// prompt is *exactly* the cached pages, the final shared page is
    /// privatized by [`KvPagePool::ensure_range`] on the first write.
    ///
    /// Hit accounting is NOT committed here: call
    /// [`KvPagePool::record_reuse`] once the admission is certain to run
    /// (a shed admission must not count as a prefix hit).
    pub fn adopt_prefix(&mut self, kv: &mut PagedKv, prompt: &[u32]) -> usize {
        debug_assert!(kv.pages.is_empty() && kv.len == 0, "adopt into a used view");
        self.stats.prefix_lookups += 1;
        let ps = self.cfg.page_size;
        if self.prefix.is_empty() || prompt.len() < ps {
            return 0;
        }
        let hashes = page_boundary_hashes(prompt, ps);
        for k in (1..=hashes.len()).rev() {
            let want = &prompt[..k * ps];
            let Some(entry) = self.prefix.get(&hashes[k - 1]) else { continue };
            if entry.tokens != want {
                continue; // hash collision
            }
            let pages = entry.pages.clone();
            for &p in &pages {
                self.refcount[p as usize] += 1;
            }
            // LRU touch: a hit entry moves to the back of the eviction
            // queue so hot (template) prefixes survive cache churn
            let key = hashes[k - 1];
            if let Some(idx) = self.prefix_order.iter().position(|&q| q == key) {
                self.prefix_order.remove(idx);
                self.prefix_order.push_back(key);
            }
            let reuse = (k * ps).min(prompt.len() - 1);
            kv.pages = pages;
            kv.len = reuse;
            return reuse;
        }
        0
    }

    /// Commit reuse accounting for an admission that actually went
    /// through: call after [`KvPagePool::ensure_range`] succeeded for
    /// the rest of the prompt (shed admissions are not hits).
    pub fn record_reuse(&mut self, reused: usize) {
        if reused > 0 {
            self.stats.prefix_hits += 1;
            self.stats.prefix_tokens_reused += reused;
        }
    }

    /// Publish `prompt`'s full pages from `kv` into the prefix cache so
    /// later admissions can [`KvPagePool::adopt_prefix`] them. Entries
    /// are registered at every page boundary (so prompts sharing only a
    /// template prefix still match) and hold their own page references;
    /// the cache evicts least-recently-used (adoption hits refresh
    /// recency) past `max_cached_prefixes` or under pool memory
    /// pressure.
    pub fn register_prefix(&mut self, kv: &PagedKv, prompt: &[u32]) {
        let ps = self.cfg.page_size;
        if self.cfg.max_cached_prefixes == 0 {
            return;
        }
        debug_assert!(kv.len >= prompt.len(), "register before prefill completed");
        let hashes = page_boundary_hashes(prompt, ps);
        for k in 1..=hashes.len() {
            let want = &prompt[..k * ps];
            let key = hashes[k - 1];
            if self.prefix.contains_key(&key) {
                // already cached (or a hash collision: keep the incumbent)
                continue;
            }
            let pages: Vec<u32> = kv.pages[..k].to_vec();
            for &p in &pages {
                self.refcount[p as usize] += 1;
            }
            self.prefix.insert(key, PrefixEntry { tokens: want.to_vec(), pages });
            self.prefix_order.push_back(key);
        }
        while self.prefix.len() > self.cfg.max_cached_prefixes {
            if !self.evict_oldest_prefix() {
                break;
            }
        }
    }

    /// Swap a slot out: copy its committed positions `0..len` into a
    /// host-side [`ParkedKv`] and release every page reference (shared
    /// prefix pages just drop one ref; private pages return to the free
    /// list). The view is left empty and reusable.
    pub fn park_kv(&mut self, kv: &mut PagedKv) -> ParkedKv {
        let stride = self.cfg.n_heads * self.cfg.head_dim;
        let mut k = vec![vec![0f32; kv.len * stride]; self.cfg.n_layers];
        let mut v = vec![vec![0f32; kv.len * stride]; self.cfg.n_layers];
        for l in 0..self.cfg.n_layers {
            for pos in 0..kv.len {
                let off = paged_offset(&self.cfg, &kv.pages, l, pos, 0);
                k[l][pos * stride..(pos + 1) * stride]
                    .copy_from_slice(&self.k[off..off + stride]);
                v[l][pos * stride..(pos + 1) * stride]
                    .copy_from_slice(&self.v[off..off + stride]);
            }
        }
        let parked = ParkedKv { len: kv.len, stride, k, v };
        self.release_kv(kv);
        parked
    }

    /// Swap a parked slot back in: map fresh private pages for
    /// `0..parked.len` and write the saved values back, yielding a view
    /// that decodes bit-identically to the one that was parked. No
    /// prefix adoption happens here — the restored pages carry the
    /// exact parked values by construction. Errors (leaving the pool
    /// untouched) when the pool cannot supply the pages; the caller
    /// keeps the parking buffer and retries later.
    pub fn unpark_kv(&mut self, parked: &ParkedKv, max_seq: usize) -> Result<PagedKv> {
        let stride = self.cfg.n_heads * self.cfg.head_dim;
        assert_eq!(parked.stride, stride, "unpark into a different geometry");
        assert_eq!(parked.k.len(), self.cfg.n_layers, "unpark layer mismatch");
        let mut kv = self.new_kv(max_seq);
        if let Err(e) = self.ensure_range(&mut kv, 0, parked.len) {
            self.release_kv(&mut kv);
            return Err(e);
        }
        for l in 0..self.cfg.n_layers {
            for pos in 0..parked.len {
                let row = pos * stride..(pos + 1) * stride;
                paged_write(self, &kv, l, pos, &parked.k[l][row.clone()], &parked.v[l][row]);
            }
        }
        kv.len = parked.len;
        Ok(kv)
    }

    /// Make `dst` an alias of `src`'s pages covering positions `0..len`
    /// (`len <= src.len()`): pure refcount bumps, no copy and no new
    /// page. Pages `dst` already shares with `src` (a common page-table
    /// prefix from an earlier alias) are kept as-is; diverged or excess
    /// `dst` pages are released first, so calling this every step is an
    /// incremental sync, not a rebuild.
    ///
    /// This is how a speculative slot's draft mirror borrows the
    /// target's committed history out of the ONE shared pool: the draft
    /// pass reads the aliased positions read-only and its first append
    /// into a shared boundary page goes through
    /// [`KvPagePool::ensure_range`]'s copy-on-write, exactly like a
    /// prefix-cache adoption.
    pub fn alias_kv(&mut self, dst: &mut PagedKv, src: &PagedKv, len: usize) {
        assert!(len <= src.len, "alias {len} past src len {}", src.len);
        let ps = self.cfg.page_size;
        let need = if len == 0 { 0 } else { (len - 1) / ps + 1 };
        let mut common = 0usize;
        while common < dst.pages.len() && common < need && dst.pages[common] == src.pages[common] {
            common += 1;
        }
        while dst.pages.len() > common {
            let p = dst.pages.pop().expect("length checked above");
            self.release_page(p);
        }
        for i in common..need {
            let p = src.pages[i];
            debug_assert!(self.refcount[p as usize] > 0, "aliasing an unmapped page");
            self.refcount[p as usize] += 1;
            dst.pages.push(p);
            self.stats.pages_aliased += 1;
        }
        dst.len = len;
    }

    /// Roll `kv` back to the longest page-table prefix it shares with
    /// `src`, releasing everything past it — the speculative end-of-step
    /// cleanup: pages the draft pass privatized (copy-on-write) or
    /// appended diverge from the target's table and return to the pool,
    /// while still-shared aliases keep their reference. Only `src`'s
    /// FULL pages are ever retained: `src` keeps appending into its
    /// partially filled boundary page between syncs, and a lingering
    /// alias there would force `src` to copy-on-write its own boundary —
    /// so a boundary alias (possible when a sync's window reservation
    /// failed before privatizing it) is dropped here too.
    pub fn retain_shared_prefix(&mut self, kv: &mut PagedKv, src: &PagedKv) {
        let full = src.len / self.cfg.page_size;
        let keep = kv.pages.len().min(src.pages.len()).min(full);
        let mut common = 0usize;
        while common < keep && kv.pages[common] == src.pages[common] {
            common += 1;
        }
        while kv.pages.len() > common {
            let p = kv.pages.pop().expect("length checked above");
            self.release_page(p);
        }
        kv.len = common * self.cfg.page_size;
    }
}

// ---------------------------------------------------------------------------
// Paged gather core (shared by the single-slot ref and the batched view)
// ---------------------------------------------------------------------------

#[inline]
fn paged_offset(c: &KvPoolConfig, pages: &[u32], l: usize, pos: usize, h: usize) -> usize {
    let stride = c.n_heads * c.head_dim;
    let page = pages[pos / c.page_size] as usize;
    ((page * c.n_layers + l) * c.page_size + pos % c.page_size) * stride + h * c.head_dim
}

fn paged_write(
    pool: &mut KvPagePool,
    kv: &PagedKv,
    l: usize,
    pos: usize,
    k_t: &[f32],
    v_t: &[f32],
) {
    let c = pool.cfg;
    let stride = c.n_heads * c.head_dim;
    debug_assert!(pos / c.page_size < kv.pages.len(), "write to unmapped page");
    debug_assert_eq!(
        pool.refcount[kv.pages[pos / c.page_size] as usize],
        1,
        "write to a shared page without copy-on-write"
    );
    debug_assert_eq!(k_t.len(), stride);
    let off = paged_offset(&c, &kv.pages, l, pos, 0);
    pool.k[off..off + stride].copy_from_slice(k_t);
    pool.v[off..off + stride].copy_from_slice(v_t);
}

// Per-page gathers: one page-table lookup per contiguous run instead of
// one per position.
fn paged_score_keys(
    pool: &KvPagePool,
    kv: &PagedKv,
    l: usize,
    h: usize,
    q: &[f32],
    scale: f32,
    scores: &mut [f32],
) {
    let c = &pool.cfg;
    let (ps, hd) = (c.page_size, c.head_dim);
    let stride = c.n_heads * hd;
    let mut j = 0usize;
    while j < scores.len() {
        let run = (ps - j % ps).min(scores.len() - j);
        let page = kv.pages[j / ps] as usize;
        let base = ((page * c.n_layers + l) * ps + j % ps) * stride + h * hd;
        for r in 0..run {
            let kt = &pool.k[base + r * stride..base + r * stride + hd];
            scores[j + r] = ops::dot(q, kt) * scale;
        }
        j += run;
    }
}

fn paged_accumulate_values(
    pool: &KvPagePool,
    kv: &PagedKv,
    l: usize,
    h: usize,
    weights: &[f32],
    out: &mut [f32],
) {
    let c = &pool.cfg;
    let (ps, hd) = (c.page_size, c.head_dim);
    let stride = c.n_heads * hd;
    let mut j = 0usize;
    while j < weights.len() {
        let run = (ps - j % ps).min(weights.len() - j);
        let page = kv.pages[j / ps] as usize;
        let base = ((page * c.n_layers + l) * ps + j % ps) * stride + h * hd;
        for r in 0..run {
            let vt = &pool.v[base + r * stride..base + r * stride + hd];
            ops::axpy(weights[j + r], vt, out);
        }
        j += run;
    }
}

/// A [`PagedKv`] view bound to its pool: the borrow the engine decodes
/// through. Pages for the positions being written must have been mapped
/// first with [`KvPagePool::ensure_range`].
pub struct PagedKvRef<'a> {
    pub pool: &'a mut KvPagePool,
    pub kv: &'a mut PagedKv,
}

impl KvSlot for PagedKvRef<'_> {
    fn len(&self) -> usize {
        self.kv.len
    }

    fn remaining(&self) -> usize {
        self.kv.max_seq - self.kv.len
    }

    fn resident_bytes(&self) -> usize {
        self.kv.pages.len() * self.pool.page_bytes()
    }

    fn write(&mut self, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        paged_write(&mut *self.pool, &*self.kv, l, pos, k_t, v_t);
    }

    fn advance(&mut self, n: usize) {
        self.kv.len += n;
        debug_assert!(self.kv.len <= self.kv.max_seq);
    }

    fn truncate(&mut self, len: usize) {
        self.pool.truncate_kv(self.kv, len);
    }

    #[inline]
    fn k_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let off = paged_offset(&self.pool.cfg, &self.kv.pages, l, pos, h);
        &self.pool.k[off..off + self.pool.cfg.head_dim]
    }

    #[inline]
    fn v_at(&self, l: usize, pos: usize, h: usize) -> &[f32] {
        let off = paged_offset(&self.pool.cfg, &self.kv.pages, l, pos, h);
        &self.pool.v[off..off + self.pool.cfg.head_dim]
    }

    fn score_keys(&self, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]) {
        paged_score_keys(&*self.pool, &*self.kv, l, h, q, scale, scores);
    }

    fn accumulate_values(&self, l: usize, h: usize, weights: &[f32], out: &mut [f32]) {
        paged_accumulate_values(&*self.pool, &*self.kv, l, h, weights, out);
    }
}

// ---------------------------------------------------------------------------
// Batched slot views (one decode step over m slots)
// ---------------------------------------------------------------------------

/// The batched-decode KV interface: `m` independent generation slots
/// addressed by index, stepped together by
/// [`crate::engine::NativeEngine::step_batch`].
///
/// This exists because the paged store cannot hand out `m` simultaneous
/// [`PagedKvRef`]s (each would alias the pool mutably); a batch view
/// holds the pool borrow once and routes per-slot reads/writes through
/// it. [`SlotBatch`] adapts any collection of dense [`KvSlot`]s;
/// [`PagedSlotBatch`] is the pool-backed equivalent.
///
/// `Sync` is a supertrait: after the per-step writes complete, the
/// engine shares the view read-only across worker threads for the
/// per-row attention gathers (`FBQ_THREADS`); gathers only use `&self`
/// methods, so no synchronization beyond the type bound is needed.
pub trait KvSlotBatch: Sync {
    /// Number of slots in this batch.
    fn n_slots(&self) -> usize;

    /// Committed sequence length of slot `i` (its next write position).
    fn len(&self, i: usize) -> usize;

    /// Store `k_t`/`v_t` for slot `i`, layer `l`, position `pos`.
    fn write(&mut self, i: usize, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]);

    /// Commit `n` positions on slot `i` (after all layers are written).
    fn advance(&mut self, i: usize, n: usize);

    /// Attention scores `q . k_j * scale` over slot `i`'s history.
    fn score_keys(&self, i: usize, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]);

    /// `out += sum_j weights[j] * v_j` over slot `i`'s history.
    fn accumulate_values(&self, i: usize, l: usize, h: usize, weights: &[f32], out: &mut [f32]);
}

/// Batch adapter over independent [`KvSlot`]s (the dense path: each slot
/// owns its own storage, so distinct `&mut` borrows coexist).
pub struct SlotBatch<'a> {
    pub slots: Vec<&'a mut dyn KvSlot>,
}

impl<'a> SlotBatch<'a> {
    /// Select `ids` out of a dense slot table as a batch view (the
    /// split-the-borrows dance shared by every batched caller).
    ///
    /// Panics if a listed slot is unoccupied or repeated — callers
    /// validate occupancy up front and own the error reporting.
    pub fn select<S: KvSlot + 'a>(slots: &'a mut [Option<S>], ids: &[usize]) -> SlotBatch<'a> {
        let mut refs: Vec<Option<&'a mut S>> = slots.iter_mut().map(|s| s.as_mut()).collect();
        let mut batch: Vec<&'a mut dyn KvSlot> = Vec::with_capacity(ids.len());
        for &i in ids {
            let kv = refs
                .get_mut(i)
                .and_then(|r| r.take())
                .expect("selected slot occupied and listed once");
            batch.push(kv as &'a mut dyn KvSlot);
        }
        SlotBatch { slots: batch }
    }
}

impl KvSlotBatch for SlotBatch<'_> {
    fn n_slots(&self) -> usize {
        self.slots.len()
    }

    fn len(&self, i: usize) -> usize {
        self.slots[i].len()
    }

    fn write(&mut self, i: usize, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        self.slots[i].write(l, pos, k_t, v_t);
    }

    fn advance(&mut self, i: usize, n: usize) {
        self.slots[i].advance(n);
    }

    fn score_keys(&self, i: usize, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]) {
        self.slots[i].score_keys(l, h, q, scale, scores);
    }

    fn accumulate_values(&self, i: usize, l: usize, h: usize, weights: &[f32], out: &mut [f32]) {
        self.slots[i].accumulate_values(l, h, weights, out);
    }
}

/// Batched view over one shared [`KvPagePool`]: the pool is borrowed
/// once, per-slot page tables route every access. Pages for the
/// positions being written must have been mapped with
/// [`KvPagePool::ensure_range`] (the serving loop's `prepare_decode`).
pub struct PagedSlotBatch<'a> {
    pub pool: &'a mut KvPagePool,
    pub slots: Vec<&'a mut PagedKv>,
}

impl<'a> PagedSlotBatch<'a> {
    /// Pool-backed twin of [`SlotBatch::select`]: select `ids` out of a
    /// paged slot table, borrowing the pool once. Panics if a listed
    /// slot is unoccupied or repeated — callers validate occupancy up
    /// front and own the error reporting.
    pub fn select(
        pool: &'a mut KvPagePool,
        slots: &'a mut [Option<PagedKv>],
        ids: &[usize],
    ) -> PagedSlotBatch<'a> {
        let mut refs: Vec<Option<&'a mut PagedKv>> =
            slots.iter_mut().map(|s| s.as_mut()).collect();
        let mut sel: Vec<&'a mut PagedKv> = Vec::with_capacity(ids.len());
        for &i in ids {
            sel.push(
                refs.get_mut(i)
                    .and_then(|r| r.take())
                    .expect("selected slot occupied and listed once"),
            );
        }
        PagedSlotBatch { pool, slots: sel }
    }
}

impl KvSlotBatch for PagedSlotBatch<'_> {
    fn n_slots(&self) -> usize {
        self.slots.len()
    }

    fn len(&self, i: usize) -> usize {
        self.slots[i].len
    }

    fn write(&mut self, i: usize, l: usize, pos: usize, k_t: &[f32], v_t: &[f32]) {
        paged_write(&mut *self.pool, &*self.slots[i], l, pos, k_t, v_t);
    }

    fn advance(&mut self, i: usize, n: usize) {
        let kv = &mut *self.slots[i];
        kv.len += n;
        debug_assert!(kv.len <= kv.max_seq);
    }

    fn score_keys(&self, i: usize, l: usize, h: usize, q: &[f32], scale: f32, scores: &mut [f32]) {
        paged_score_keys(&*self.pool, &*self.slots[i], l, h, q, scale, scores);
    }

    fn accumulate_values(&self, i: usize, l: usize, h: usize, weights: &[f32], out: &mut [f32]) {
        paged_accumulate_values(&*self.pool, &*self.slots[i], l, h, weights, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut kv = KvCache::new(2, 8, 2, 4);
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        kv.write(1, 3, &k, &v);
        kv.advance(4);
        assert_eq!(kv.len, 4);
        assert_eq!(kv.k_at(1, 3, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(kv.k_at(1, 3, 1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(kv.v_at(1, 3, 1), &[-4.0, -5.0, -6.0, -7.0]);
    }

    #[test]
    fn resident_bytes_accounting() {
        let kv = KvCache::new(2, 256, 4, 32);
        assert_eq!(kv.resident_bytes(), 2 * 2 * 256 * 4 * 32 * 4);
        assert_eq!(kv.used_bytes(), 0);
    }

    #[test]
    fn paged_write_read_roundtrip() {
        let mut pool = KvPagePool::new(KvPoolConfig::new(2, 2, 4, 2, 8));
        let page_bytes = pool.page_bytes();
        let mut kv = pool.new_kv(16);
        pool.ensure_range(&mut kv, 0, 4).unwrap();
        assert_eq!(kv.n_pages(), 2);
        let k: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        let mut slot = PagedKvRef { pool: &mut pool, kv: &mut kv };
        slot.write(1, 3, &k, &v);
        slot.advance(4);
        assert_eq!(slot.len(), 4);
        assert_eq!(slot.k_at(1, 3, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(slot.k_at(1, 3, 1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(slot.v_at(1, 3, 1), &[-4.0, -5.0, -6.0, -7.0]);
        assert_eq!(slot.resident_bytes(), 2 * page_bytes);
        pool.release_kv(&mut kv);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn dense_truncate_rolls_back_and_rewrites() {
        let mut kv = KvCache::new(1, 8, 1, 2);
        for pos in 0..5 {
            kv.write(0, pos, &[pos as f32, 0.0], &[0.0, pos as f32]);
            kv.advance(1);
        }
        kv.truncate(2);
        assert_eq!(kv.len, 2);
        // re-append over the discarded positions
        kv.write(0, 2, &[9.0, 9.0], &[9.0, 9.0]);
        kv.advance(1);
        assert_eq!(kv.k_at(0, 2, 0), &[9.0, 9.0]);
        assert_eq!(kv.k_at(0, 1, 0), &[1.0, 0.0], "kept history untouched");
    }

    #[test]
    fn paged_truncate_releases_whole_pages_only() {
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
        let mut kv = pool.new_kv(32);
        pool.ensure_range(&mut kv, 0, 10).unwrap();
        assert_eq!(pool.pages_in_use(), 3);
        // 10 -> 6 positions: page 3 (positions 8..10) frees, page 2 stays
        kv.len = 10;
        pool.truncate_kv(&mut kv, 6);
        assert_eq!(kv.len(), 6);
        assert_eq!(kv.n_pages(), 2);
        assert_eq!(pool.pages_in_use(), 2);
        // truncate to a page boundary keeps exactly len/page_size pages
        pool.truncate_kv(&mut kv, 4);
        assert_eq!(kv.n_pages(), 1);
        // to zero: everything returns to the free list
        pool.truncate_kv(&mut kv, 0);
        assert_eq!(kv.n_pages(), 0);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn paged_truncate_releases_over_reserved_pages() {
        // ensure_range can map pages past the committed length (the
        // speculative path reserves K+1 positions up front); truncate
        // must return those to the free list even though len never
        // covered them
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 2, 8));
        let mut kv = pool.new_kv(32);
        pool.ensure_range(&mut kv, 0, 8).unwrap();
        kv.len = 3; // committed less than reserved
        assert_eq!(pool.pages_in_use(), 4);
        pool.truncate_kv(&mut kv, 3);
        assert_eq!(kv.n_pages(), 2);
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn dense_park_unpark_roundtrip() {
        let mut kv = KvCache::new(2, 8, 1, 2);
        for pos in 0..5 {
            kv.write(0, pos, &[pos as f32, 1.0], &[2.0, pos as f32]);
            kv.write(1, pos, &[-(pos as f32), 3.0], &[4.0, -(pos as f32)]);
            kv.advance(1);
        }
        let parked = kv.park();
        assert_eq!(parked.len(), 5);
        assert!(parked.bytes() > 0);
        let mut fresh = KvCache::new(2, 8, 1, 2);
        fresh.unpark(&parked);
        assert_eq!(fresh.len, 5);
        for pos in 0..5 {
            assert_eq!(fresh.k_at(0, pos, 0), kv.k_at(0, pos, 0));
            assert_eq!(fresh.v_at(1, pos, 0), kv.v_at(1, pos, 0));
        }
    }

    #[test]
    fn paged_park_frees_pages_and_unpark_restores_bits() {
        let mut pool = KvPagePool::new(KvPoolConfig::new(2, 1, 2, 4, 8));
        let mut kv = pool.new_kv(32);
        pool.ensure_range(&mut kv, 0, 10).unwrap();
        for l in 0..2 {
            for pos in 0..10 {
                let t = (l * 100 + pos) as f32;
                paged_write(&mut pool, &kv, l, pos, &[t, t + 0.5], &[-t, t - 0.5]);
            }
        }
        kv.len = 10;
        assert_eq!(pool.pages_in_use(), 3);
        let parked = pool.park_kv(&mut kv);
        assert_eq!(parked.len(), 10);
        assert_eq!(pool.pages_in_use(), 0, "park releases every page");
        assert_eq!(kv.len(), 0);
        let mut restored = pool.unpark_kv(&parked, 32).unwrap();
        assert_eq!(restored.len(), 10);
        assert_eq!(pool.pages_in_use(), 3);
        let slot = PagedKvRef { pool: &mut pool, kv: &mut restored };
        for pos in 0..10 {
            let t = (100 + pos) as f32;
            assert_eq!(slot.k_at(1, pos, 0), &[t, t + 0.5]);
            assert_eq!(slot.v_at(1, pos, 0), &[-t, t - 0.5]);
        }
    }

    #[test]
    fn paged_park_drops_shared_refs_and_unpark_fails_clean_when_exhausted() {
        // a parked slot that adopted a cached prefix must only drop its
        // own reference; the cached pages stay for other admissions
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 2, 4));
        let prompt: Vec<u32> = vec![7, 8, 9, 10];
        let mut kv = pool.new_kv(8);
        pool.ensure_range(&mut kv, 0, 4).unwrap();
        kv.len = 4;
        pool.register_prefix(&kv, &prompt);
        let shared = kv.page_ids().to_vec();
        let _parked = pool.park_kv(&mut kv);
        for &p in &shared {
            assert_eq!(pool.page_refcount(p), 1, "prefix cache keeps its ref");
        }
        // exhaust the pool (the prefix cache is evictable, so claim
        // every page with refcounted views)
        let mut hog = pool.new_kv(32);
        pool.ensure_range(&mut hog, 0, 8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        let big = ParkedKv { len: 6, stride: 2, k: vec![vec![0.0; 12]], v: vec![vec![0.0; 12]] };
        let before = pool.pages_in_use();
        assert!(pool.unpark_kv(&big, 8).is_err());
        assert_eq!(pool.pages_in_use(), before, "failed unpark leaks nothing");
    }

    #[test]
    fn alias_bumps_refcounts_and_cow_privatizes_the_boundary() {
        // ps=4: target commits 6 positions -> 2 pages (page 1 half full)
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
        let mut target = pool.new_kv(32);
        pool.ensure_range(&mut target, 0, 6).unwrap();
        for pos in 0..6 {
            let t = pos as f32;
            paged_write(&mut pool, &target, 0, pos, &[t, t], &[-t, -t]);
        }
        target.len = 6;
        let mut draft = pool.new_kv(32);
        pool.alias_kv(&mut draft, &target, 6);
        assert_eq!(draft.len(), 6);
        assert_eq!(draft.page_ids(), target.page_ids(), "alias shares the table");
        assert_eq!(pool.pages_in_use(), 2, "aliasing maps no new pages");
        for &p in target.page_ids() {
            assert_eq!(pool.page_refcount(p), 2);
        }
        assert_eq!(pool.stats().pages_aliased, 2);
        // draft appends at 6..8: the shared boundary page privatizes
        pool.ensure_range(&mut draft, 6, 8).unwrap();
        assert_eq!(pool.stats().cow_copies, 1);
        assert_ne!(draft.page_ids()[1], target.page_ids()[1], "boundary diverged");
        assert_eq!(draft.page_ids()[0], target.page_ids()[0], "full page still shared");
        assert_eq!(pool.page_refcount(target.page_ids()[1]), 1, "target owns its boundary again");
        // the aliased history reads the target's values through the copy
        let dref = PagedKvRef { pool: &mut pool, kv: &mut draft };
        for pos in 0..6 {
            assert_eq!(dref.k_at(0, pos, 0), &[pos as f32, pos as f32]);
        }
        pool.release_kv(&mut draft);
        pool.release_kv(&mut target);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn alias_is_an_incremental_sync_and_retain_drops_only_divergence() {
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
        let mut target = pool.new_kv(32);
        pool.ensure_range(&mut target, 0, 9).unwrap();
        target.len = 9;
        let mut draft = pool.new_kv(32);
        pool.alias_kv(&mut draft, &target, 9);
        let aliased_first = pool.stats().pages_aliased;
        assert_eq!(aliased_first, 3);
        // draft window: CoW the boundary page + one fresh page
        pool.ensure_range(&mut draft, 9, 13).unwrap();
        draft.len = 13;
        let in_use_mid = pool.pages_in_use();
        assert_eq!(in_use_mid, 5, "one CoW + one fresh window page");
        // end of step: only the diverged pages return to the pool
        pool.retain_shared_prefix(&mut draft, &target);
        assert_eq!(pool.pages_in_use(), 3, "target's pages survive");
        assert_eq!(draft.n_pages(), 2);
        assert_eq!(draft.len(), 8, "retained length is the shared full pages");
        // next-step sync re-aliases only what's missing
        pool.alias_kv(&mut draft, &target, 9);
        assert_eq!(
            pool.stats().pages_aliased,
            aliased_first + 1,
            "two pages were still shared; only the boundary re-aliases"
        );
        pool.release_kv(&mut draft);
        pool.release_kv(&mut target);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn pages_allocate_on_demand_not_upfront() {
        let mut pool = KvPagePool::new(KvPoolConfig::new(1, 1, 2, 4, 8));
        let mut kv = pool.new_kv(32);
        assert_eq!(pool.pages_in_use(), 0);
        pool.ensure_range(&mut kv, 0, 3).unwrap();
        assert_eq!(pool.pages_in_use(), 1, "3 positions fit one 4-slot page");
        pool.ensure_range(&mut kv, 3, 9).unwrap();
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.stats().peak_pages_in_use, 3);
    }
}
