//! The native transformer engine (scoring + prefill/decode).
//!
//! Numerically mirrors `python/compile/model.py` (same norm/activation/RoPE
//! conventions) so logits agree with the JAX reference to float tolerance —
//! asserted by `tests/cross_engine.rs` against the AOT selftest archive.
//!
//! Decode comes in three shapes: [`NativeEngine::decode_one`] steps a
//! single slot; [`NativeEngine::step_batch`] steps every occupied slot of
//! a continuous batch through one weight-stationary pass (weights stream
//! once per step, not once per slot) with bit-identical per-slot results;
//! and [`NativeEngine::step_batch_multi`] generalizes that from slot-rows
//! to **position-rows** — each slot consumes a group of consecutive
//! tokens in the same pass, which is how speculative verification scores
//! all K+1 draft positions and how concurrent prefills batch.
//! [`NativeEngine::step_batch_multi_sel`] adds a per-slot output
//! selection ([`RowsWant`]): greedy verification fetches only the argmax
//! id per position (no `rows × vocab` materialization) while stochastic
//! verification fetches the full rows it needs, all in one pass.

use super::kernels::{self, QuantLinear, SubMode, Traffic, Workspace};
use super::kv::{KvSlot, KvSlotBatch};
use crate::model::{Config, LinearWeights, WeightStore};
use crate::tensor::ops;
use anyhow::{bail, Result};

/// A linear layer prepared for execution.
#[derive(Debug, Clone)]
pub enum LinearExec {
    Dense { out: usize, cin: usize, w: Vec<f32>, bias: Option<Vec<f32>> },
    Quant(QuantLinear),
}

impl LinearExec {
    fn from_weights_shaped(lw: &LinearWeights, out: usize, cin: usize) -> LinearExec {
        match lw {
            LinearWeights::Dense { w, bias } => {
                LinearExec::Dense { out, cin, w: w.clone(), bias: bias.clone() }
            }
            LinearWeights::Quant {
                out, cin, bits, group, packed, scales, zeros, a, b, rank, col_scale, bias,
            } => LinearExec::Quant(QuantLinear {
                out: *out,
                cin: *cin,
                bits: *bits,
                group: *group,
                packed: packed.clone(),
                scales: scales.clone(),
                zeros: zeros.clone(),
                rank: *rank,
                a: a.clone(),
                b: b.clone(),
                col_scale: col_scale.clone(),
                bias: bias.clone(),
            }),
        }
    }

    pub fn out(&self) -> usize {
        match self {
            LinearExec::Dense { out, .. } => *out,
            LinearExec::Quant(q) => q.out,
        }
    }

    pub fn cin(&self) -> usize {
        match self {
            LinearExec::Dense { cin, .. } => *cin,
            LinearExec::Quant(q) => q.cin,
        }
    }

    pub fn gemv(
        &self,
        x: &[f32],
        y: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        match self {
            LinearExec::Dense { out, cin, w, bias } => {
                t.kernel_launches += 1;
                t.bytes_read += 4 * (w.len() + cin) as u64;
                t.weight_bytes += 4 * w.len() as u64;
                t.bytes_written += 4 * *out as u64;
                t.macs += (*out * *cin) as u64;
                for o in 0..*out {
                    y[o] = ops::dot(x, &w[o * cin..(o + 1) * cin]);
                }
                if let Some(b) = bias {
                    for (yi, bi) in y.iter_mut().zip(b) {
                        *yi += bi;
                    }
                }
            }
            LinearExec::Quant(q) => q.gemv(x, y, mode, ws, t),
        }
    }

    /// Batched-decode GEMV: `xs [m, cin]` → `ys [m, out]`, weights
    /// streamed once for all `m` slot rows. Row `i` is bit-identical to
    /// `gemv(&xs[i*cin..], ..)` — see [`QuantLinear::gemv_multi`].
    pub fn gemv_multi(
        &self,
        xs: &[f32],
        m: usize,
        ys: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        match self {
            LinearExec::Dense { out, cin, w, bias } => {
                t.kernel_launches += 1;
                t.bytes_read += 4 * (w.len() + m * cin) as u64;
                t.weight_bytes += 4 * w.len() as u64;
                t.bytes_written += 4 * (m * out) as u64;
                t.macs += (m * out * cin) as u64;
                // weight-row outer: W really streams once for all m rows
                for o in 0..*out {
                    let wrow = &w[o * cin..(o + 1) * cin];
                    for i in 0..m {
                        ys[i * out + o] = ops::dot(&xs[i * cin..(i + 1) * cin], wrow);
                    }
                }
                if let Some(b) = bias {
                    for i in 0..m {
                        for (yv, bv) in ys[i * out..(i + 1) * out].iter_mut().zip(b) {
                            *yv += bv;
                        }
                    }
                }
            }
            LinearExec::Quant(q) => q.gemv_multi(xs, m, ys, mode, ws, t),
        }
    }

    pub fn gemm(
        &self,
        x: &[f32],
        m: usize,
        y: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        match self {
            LinearExec::Dense { out, cin, w, bias } => {
                t.kernel_launches += 1;
                t.bytes_read += 4 * (w.len() + m * cin) as u64;
                t.weight_bytes += 4 * w.len() as u64;
                t.bytes_written += 4 * (m * out) as u64;
                t.macs += (m * out * cin) as u64;
                ops::matmul_t(x, w, y, m, *cin, *out);
                if let Some(b) = bias {
                    for i in 0..m {
                        for (yi, bi) in y[i * out..(i + 1) * out].iter_mut().zip(b) {
                            *yi += bi;
                        }
                    }
                }
            }
            LinearExec::Quant(q) => q.gemm(x, m, y, mode, ws, t),
        }
    }

    /// Shadow variant for self-speculative drafting: quantized layers
    /// re-packed at `bits` with the sub-branch dropped
    /// ([`QuantLinear::shadow`]); dense layers pass through unchanged.
    pub fn shadow(&self, bits: u8) -> LinearExec {
        match self {
            LinearExec::Dense { .. } => self.clone(),
            LinearExec::Quant(q) => LinearExec::Quant(q.shadow(bits)),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match self {
            LinearExec::Dense { w, bias, .. } => {
                4 * (w.len() + bias.as_ref().map_or(0, |b| b.len()))
            }
            LinearExec::Quant(q) => {
                (q.code_bytes() as usize)
                    + 4 * (q.scales.len() + q.zeros.len())
                    + q.a.as_ref().map_or(0, |v| 4 * v.len())
                    + q.b.as_ref().map_or(0, |v| 4 * v.len())
                    + q.col_scale.as_ref().map_or(0, |v| 4 * v.len())
            }
        }
    }
}

#[derive(Debug, Clone)]
struct Block {
    attn_norm_w: Vec<f32>,
    attn_norm_b: Option<Vec<f32>>,
    mlp_norm_w: Vec<f32>,
    mlp_norm_b: Option<Vec<f32>>,
    q: LinearExec,
    k: LinearExec,
    v: LinearExec,
    o: LinearExec,
    // gated: (gate, up, down); non-gated: (fc, proj, unused down slot)
    m1: LinearExec,
    m2: LinearExec,
    m3: Option<LinearExec>,
}

/// Per-slot request for what a multi-position batched step returns (see
/// [`NativeEngine::step_batch_multi_sel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowsWant {
    /// Full logits at the last position only (the prefill / plain-decode
    /// shape).
    Last,
    /// Full logits at every position of the slot's group (stochastic
    /// verification scores the target distribution at every draft
    /// position).
    All,
    /// Only the argmax token id per position: greedy verification
    /// reduces each row to one id, so no `rows × vocab` floats are
    /// materialized for the slot.
    Argmax,
}

/// Per-slot result of [`NativeEngine::step_batch_multi_sel`].
#[derive(Debug, Clone)]
pub enum SlotLogits {
    /// One `[vocab]` row per requested position ([`RowsWant::Last`]
    /// yields exactly one).
    Rows(Vec<Vec<f32>>),
    /// One argmax id per position ([`RowsWant::Argmax`]).
    Argmax(Vec<u32>),
}

impl SlotLogits {
    /// The full logits rows (panics on an argmax-only result).
    pub fn into_rows(self) -> Vec<Vec<f32>> {
        match self {
            SlotLogits::Rows(r) => r,
            SlotLogits::Argmax(_) => panic!("argmax-only result has no logits rows"),
        }
    }

    /// The argmax ids (panics on a full-rows result).
    pub fn into_argmax(self) -> Vec<u32> {
        match self {
            SlotLogits::Argmax(ids) => ids,
            SlotLogits::Rows(_) => panic!("full-rows result; use into_rows"),
        }
    }
}

/// Reusable engine buffers (one per worker thread / session).
#[derive(Debug, Default)]
pub struct EngineWs {
    pub kernel: Workspace,
    pub traffic: Traffic,
    x: Vec<f32>,
    h: Vec<f32>,
    qb: Vec<f32>,
    kb: Vec<f32>,
    vb: Vec<f32>,
    attn: Vec<f32>,
    scores: Vec<f32>,
    m1: Vec<f32>,
    m2: Vec<f32>,
    m3: Vec<f32>,
    /// final-norm output row(s) — hoisted so decode steps allocate nothing
    hrow: Vec<f32>,
}

/// The native model.
#[derive(Debug)]
pub struct NativeEngine {
    pub cfg: Config,
    pub mode: SubMode,
    tok_emb: Vec<f32>,
    pos_emb: Option<Vec<f32>>,
    lm_head: Vec<f32>,
    final_norm_w: Vec<f32>,
    final_norm_b: Option<Vec<f32>>,
    blocks: Vec<Block>,
}

impl NativeEngine {
    pub fn from_store(store: &WeightStore, mode: SubMode) -> Result<NativeEngine> {
        let cfg = store.cfg.clone();
        if cfg.vocab == 0 || cfg.d_model % cfg.n_heads != 0 {
            bail!("malformed config");
        }
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let lin = |name: &str| -> Result<LinearExec> {
                let (out, cin) = cfg.linear_shape(name);
                let lw = store.linear(&format!("l{l}.{name}"))?;
                Ok(LinearExec::from_weights_shaped(lw, out, cin))
            };
            let get_opt = |n: String| store.float(&n).ok().map(|v| v.to_vec());
            let (m1, m2, m3) = if cfg.gated() {
                (lin("gate")?, lin("up")?, Some(lin("down")?))
            } else {
                (lin("fc")?, lin("proj")?, None)
            };
            blocks.push(Block {
                attn_norm_w: store.float(&format!("l{l}.attn_norm.w"))?.to_vec(),
                attn_norm_b: get_opt(format!("l{l}.attn_norm.b")),
                mlp_norm_w: store.float(&format!("l{l}.mlp_norm.w"))?.to_vec(),
                mlp_norm_b: get_opt(format!("l{l}.mlp_norm.b")),
                q: lin("q")?,
                k: lin("k")?,
                v: lin("v")?,
                o: lin("o")?,
                m1,
                m2,
                m3,
            });
        }
        Ok(NativeEngine {
            tok_emb: store.float("tok_emb")?.to_vec(),
            pos_emb: store.float("pos_emb").ok().map(|v| v.to_vec()),
            lm_head: store.float("lm_head")?.to_vec(),
            final_norm_w: store.float("final_norm.w")?.to_vec(),
            final_norm_b: store.float("final_norm.b").ok().map(|v| v.to_vec()),
            blocks,
            cfg,
            mode,
        })
    }

    /// Build the **shadow draft engine** for self-speculative decoding:
    /// every quantized linear re-packed at `bits` with the sub-branch
    /// dropped ([`QuantLinear::shadow`]); embeddings, norms and the
    /// lm-head are copied as-is. The shadow always runs `SubMode::None`
    /// — it *is* the bare branch, just on a coarser grid.
    pub fn shadow(&self, bits: u8) -> NativeEngine {
        let blocks = self
            .blocks
            .iter()
            .map(|b| Block {
                attn_norm_w: b.attn_norm_w.clone(),
                attn_norm_b: b.attn_norm_b.clone(),
                mlp_norm_w: b.mlp_norm_w.clone(),
                mlp_norm_b: b.mlp_norm_b.clone(),
                q: b.q.shadow(bits),
                k: b.k.shadow(bits),
                v: b.v.shadow(bits),
                o: b.o.shadow(bits),
                m1: b.m1.shadow(bits),
                m2: b.m2.shadow(bits),
                m3: b.m3.as_ref().map(|m| m.shadow(bits)),
            })
            .collect();
        NativeEngine {
            cfg: self.cfg.clone(),
            mode: SubMode::None,
            tok_emb: self.tok_emb.clone(),
            pos_emb: self.pos_emb.clone(),
            lm_head: self.lm_head.clone(),
            final_norm_w: self.final_norm_w.clone(),
            final_norm_b: self.final_norm_b.clone(),
            blocks,
        }
    }

    /// Total weight bytes resident (Fig. 1 memory axis).
    pub fn resident_bytes(&self) -> usize {
        let mut n = 4 * (self.tok_emb.len() + self.lm_head.len() + self.final_norm_w.len());
        if let Some(p) = &self.pos_emb {
            n += 4 * p.len();
        }
        for b in &self.blocks {
            n += 4 * (b.attn_norm_w.len() + b.mlp_norm_w.len());
            for lin in [&b.q, &b.k, &b.v, &b.o, &b.m1, &b.m2] {
                n += lin.resident_bytes();
            }
            if let Some(m3) = &b.m3 {
                n += m3.resident_bytes();
            }
        }
        n
    }

    fn norm(&self, w: &[f32], b: Option<&Vec<f32>>, x: &[f32], out: &mut [f32]) {
        if self.cfg.rms() {
            ops::rmsnorm(x, w, out, 1e-5);
        } else {
            ops::layernorm(x, w, b.expect("layernorm bias"), out, 1e-5);
        }
    }

    fn mlp(&self, blk: &Block, h: &[f32], m: usize, ws: &mut EngineWs, out: &mut [f32]) {
        let d_ff = self.cfg.d_ff;
        let mode = self.mode;
        if let Some(down) = &blk.m3 {
            // gated: down( silu(gate(h)) * up(h) )
            ws.m1.resize(m * d_ff, 0.0);
            ws.m2.resize(m * d_ff, 0.0);
            let (m1, m2) = (&mut ws.m1, &mut ws.m2);
            blk.m1.gemm(h, m, m1, mode, &mut ws.kernel, &mut ws.traffic);
            blk.m2.gemm(h, m, m2, mode, &mut ws.kernel, &mut ws.traffic);
            for i in 0..m * d_ff {
                m1[i] = ops::silu(m1[i]) * m2[i];
            }
            down.gemm(m1, m, out, mode, &mut ws.kernel, &mut ws.traffic);
        } else {
            // gelu MLP: proj(gelu(fc(h)))
            ws.m1.resize(m * d_ff, 0.0);
            let m1 = &mut ws.m1;
            blk.m1.gemm(h, m, m1, mode, &mut ws.kernel, &mut ws.traffic);
            for v in m1.iter_mut() {
                *v = ops::gelu(*v);
            }
            blk.m2.gemm(m1, m, out, mode, &mut ws.kernel, &mut ws.traffic);
        }
    }

    /// Full-sequence scoring forward: logits `[T, vocab]`.
    pub fn forward_full(&self, tokens: &[u32], ws: &mut EngineWs) -> Vec<f32> {
        let t_len = tokens.len();
        let cfg = &self.cfg;
        let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        assert!(t_len <= cfg.max_seq, "sequence longer than max_seq");

        // embed
        ws.x.resize(t_len * d, 0.0);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = &self.tok_emb[tok as usize * d..(tok as usize + 1) * d];
            ws.x[i * d..(i + 1) * d].copy_from_slice(e);
            if let Some(pe) = &self.pos_emb {
                for (xv, pv) in ws.x[i * d..(i + 1) * d].iter_mut().zip(&pe[i * d..(i + 1) * d]) {
                    *xv += pv;
                }
            }
        }

        for blk in &self.blocks {
            // --- attention ---
            ws.h.resize(t_len * d, 0.0);
            {
                let (xs, hs) = (&ws.x, &mut ws.h);
                for i in 0..t_len {
                    let (xrow, hrow) = (&xs[i * d..(i + 1) * d], &mut hs[i * d..(i + 1) * d]);
                    if self.cfg.rms() {
                        ops::rmsnorm(xrow, &blk.attn_norm_w, hrow, 1e-5);
                    } else {
                        let b = blk.attn_norm_b.as_ref().unwrap();
                        ops::layernorm(xrow, &blk.attn_norm_w, b, hrow, 1e-5);
                    }
                }
            }
            ws.qb.resize(t_len * d, 0.0);
            ws.kb.resize(t_len * d, 0.0);
            ws.vb.resize(t_len * d, 0.0);
            blk.q.gemm(&ws.h, t_len, &mut ws.qb, self.mode, &mut ws.kernel, &mut ws.traffic);
            blk.k.gemm(&ws.h, t_len, &mut ws.kb, self.mode, &mut ws.kernel, &mut ws.traffic);
            blk.v.gemm(&ws.h, t_len, &mut ws.vb, self.mode, &mut ws.kernel, &mut ws.traffic);
            if cfg.rope() {
                for i in 0..t_len {
                    for h in 0..nh {
                        let span = i * d + h * hd..i * d + (h + 1) * hd;
                        ops::rope_rotate(&mut ws.qb[span.clone()], i, cfg.rope_theta);
                        ops::rope_rotate(&mut ws.kb[span], i, cfg.rope_theta);
                    }
                }
            }
            // attention per head, causal
            ws.attn.resize(t_len * d, 0.0);
            ws.scores.resize(t_len, 0.0);
            let scale = 1.0 / (hd as f32).sqrt();
            for h in 0..nh {
                for i in 0..t_len {
                    let qv = &ws.qb[i * d + h * hd..i * d + (h + 1) * hd];
                    for j in 0..=i {
                        let kv = &ws.kb[j * d + h * hd..j * d + (h + 1) * hd];
                        ws.scores[j] = ops::dot(qv, kv) * scale;
                    }
                    ops::softmax_rows(&mut ws.scores[..i + 1], 1, i + 1);
                    let out = &mut ws.attn[i * d + h * hd..i * d + (h + 1) * hd];
                    out.fill(0.0);
                    for j in 0..=i {
                        let vv = &ws.vb[j * d + h * hd..j * d + (h + 1) * hd];
                        ops::axpy(ws.scores[j], vv, out);
                    }
                }
            }
            // o-projection into h, then residual
            ws.h.resize(t_len * d, 0.0);
            let mut htmp = std::mem::take(&mut ws.h);
            blk.o.gemm(&ws.attn, t_len, &mut htmp, self.mode, &mut ws.kernel, &mut ws.traffic);
            for (xv, hv) in ws.x.iter_mut().zip(&htmp) {
                *xv += hv;
            }
            ws.h = htmp;

            // --- mlp ---
            {
                let mut hbuf = std::mem::take(&mut ws.h);
                for i in 0..t_len {
                    let xrow = &ws.x[i * d..(i + 1) * d];
                    let hrow = &mut hbuf[i * d..(i + 1) * d];
                    if self.cfg.rms() {
                        ops::rmsnorm(xrow, &blk.mlp_norm_w, hrow, 1e-5);
                    } else {
                        let b = blk.mlp_norm_b.as_ref().unwrap();
                        ops::layernorm(xrow, &blk.mlp_norm_w, b, hrow, 1e-5);
                    }
                }
                ws.m3.resize(t_len * d, 0.0);
                let mut mout = std::mem::take(&mut ws.m3);
                self.mlp(blk, &hbuf, t_len, ws, &mut mout);
                for (xv, mv) in ws.x.iter_mut().zip(&mout) {
                    *xv += mv;
                }
                ws.m3 = mout;
                ws.h = hbuf;
            }
        }

        // final norm + lm head
        let vocab = cfg.vocab;
        let mut logits = vec![0f32; t_len * vocab];
        ws.hrow.resize(d, 0.0);
        for i in 0..t_len {
            self.norm(
                &self.final_norm_w,
                self.final_norm_b.as_ref(),
                &ws.x[i * d..(i + 1) * d],
                &mut ws.hrow,
            );
            ws.traffic.kernel_launches += 1;
            ws.traffic.bytes_read += 4 * (self.lm_head.len() + d) as u64;
            ws.traffic.weight_bytes += 4 * self.lm_head.len() as u64;
            ws.traffic.bytes_written += 4 * vocab as u64;
            ws.traffic.macs += (vocab * d) as u64;
            for o in 0..vocab {
                logits[i * vocab + o] = ops::dot(&ws.hrow, &self.lm_head[o * d..(o + 1) * d]);
            }
        }
        logits
    }

    /// Prefill `tokens` into `kv` starting at `kv.len()`; returns the
    /// logits of the last position. `kv` is any [`KvSlot`] — the dense
    /// cache or a pool-bound paged view (whose pages for the written
    /// range must already be ensured).
    pub fn prefill(&self, tokens: &[u32], kv: &mut dyn KvSlot, ws: &mut EngineWs) -> Vec<f32> {
        let mut logits = Vec::new();
        for (off, &tok) in tokens.iter().enumerate() {
            let last = off == tokens.len() - 1;
            logits = self.step(tok, kv, ws, last);
        }
        logits
    }

    /// One decode step at position `kv.len()`; returns logits `[vocab]`.
    pub fn decode_one(&self, token: u32, kv: &mut dyn KvSlot, ws: &mut EngineWs) -> Vec<f32> {
        self.step(token, kv, ws, true)
    }

    fn step(
        &self,
        token: u32,
        kv: &mut dyn KvSlot,
        ws: &mut EngineWs,
        want_logits: bool,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let pos = kv.len();
        assert!(pos < cfg.max_seq, "kv cache full");

        ws.x.resize(d, 0.0);
        ws.x.copy_from_slice(&self.tok_emb[token as usize * d..(token as usize + 1) * d]);
        if let Some(pe) = &self.pos_emb {
            for (xv, pv) in ws.x.iter_mut().zip(&pe[pos * d..(pos + 1) * d]) {
                *xv += pv;
            }
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            ws.h.resize(d, 0.0);
            {
                let mut hbuf = std::mem::take(&mut ws.h);
                self.norm(&blk.attn_norm_w, blk.attn_norm_b.as_ref(), &ws.x, &mut hbuf);
                ws.qb.resize(d, 0.0);
                ws.kb.resize(d, 0.0);
                ws.vb.resize(d, 0.0);
                let mut qb = std::mem::take(&mut ws.qb);
                let mut kb = std::mem::take(&mut ws.kb);
                let mut vb = std::mem::take(&mut ws.vb);
                blk.q.gemv(&hbuf, &mut qb, self.mode, &mut ws.kernel, &mut ws.traffic);
                blk.k.gemv(&hbuf, &mut kb, self.mode, &mut ws.kernel, &mut ws.traffic);
                blk.v.gemv(&hbuf, &mut vb, self.mode, &mut ws.kernel, &mut ws.traffic);
                if cfg.rope() {
                    for h in 0..nh {
                        ops::rope_rotate(&mut qb[h * hd..(h + 1) * hd], pos, cfg.rope_theta);
                        ops::rope_rotate(&mut kb[h * hd..(h + 1) * hd], pos, cfg.rope_theta);
                    }
                }
                kv.write(l, pos, &kb, &vb);

                // attention over 0..=pos: the KvSlot gathers keys/values
                // (per-page runs on the paged store, strided on dense)
                ws.attn.resize(d, 0.0);
                ws.scores.resize(pos + 1, 0.0);
                let scale = 1.0 / (hd as f32).sqrt();
                for h in 0..nh {
                    let qv = &qb[h * hd..(h + 1) * hd];
                    kv.score_keys(l, h, qv, scale, &mut ws.scores[..pos + 1]);
                    ops::softmax_rows(&mut ws.scores[..pos + 1], 1, pos + 1);
                    let out = &mut ws.attn[h * hd..(h + 1) * hd];
                    out.fill(0.0);
                    kv.accumulate_values(l, h, &ws.scores[..pos + 1], out);
                }
                blk.o.gemv(&ws.attn, &mut hbuf, self.mode, &mut ws.kernel, &mut ws.traffic);
                for (xv, hv) in ws.x.iter_mut().zip(&hbuf) {
                    *xv += hv;
                }
                ws.qb = qb;
                ws.kb = kb;
                ws.vb = vb;
                ws.h = hbuf;
            }

            {
                let mut hbuf = std::mem::take(&mut ws.h);
                self.norm(&blk.mlp_norm_w, blk.mlp_norm_b.as_ref(), &ws.x, &mut hbuf);
                ws.m3.resize(d, 0.0);
                let mut mout = std::mem::take(&mut ws.m3);
                self.mlp(blk, &hbuf, 1, ws, &mut mout);
                for (xv, mv) in ws.x.iter_mut().zip(&mout) {
                    *xv += mv;
                }
                ws.m3 = mout;
                ws.h = hbuf;
            }
        }
        kv.advance(1);

        if !want_logits {
            return Vec::new();
        }
        ws.hrow.resize(d, 0.0);
        self.norm(&self.final_norm_w, self.final_norm_b.as_ref(), &ws.x, &mut ws.hrow);
        let vocab = cfg.vocab;
        let mut logits = vec![0f32; vocab];
        ws.traffic.kernel_launches += 1;
        ws.traffic.bytes_read += 4 * (self.lm_head.len() + d) as u64;
        ws.traffic.weight_bytes += 4 * self.lm_head.len() as u64;
        ws.traffic.bytes_written += 4 * vocab as u64;
        ws.traffic.macs += (vocab * d) as u64;
        for o in 0..vocab {
            logits[o] = ops::dot(&ws.hrow, &self.lm_head[o * d..(o + 1) * d]);
        }
        logits
    }

    /// One **weight-stationary batched decode step** over `m` occupied
    /// slots: `tokens[i]` is slot `i`'s last sampled token, `kv` the
    /// batched KV view pairing each row with its history (see
    /// [`KvSlotBatch`]). Returns next-token logits per slot.
    ///
    /// This is [`NativeEngine::step_batch_multi`] with exactly one
    /// position per slot — see there for the execution contract (weights
    /// stream once per step; per-row float operations bit-identical to
    /// [`NativeEngine::decode_one`]).
    pub fn step_batch(
        &self,
        tokens: &[u32],
        kv: &mut dyn KvSlotBatch,
        ws: &mut EngineWs,
    ) -> Vec<Vec<f32>> {
        let groups: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.step_batch_multi(&groups, kv, ws, true)
            .into_iter()
            .map(|mut per_pos| per_pos.pop().expect("one position per slot"))
            .collect()
    }

    /// One weight-stationary **multi-position** batched step: slot `i`
    /// consumes the `groups[i]` tokens at consecutive positions starting
    /// from its current length, all `Σ len(groups[i])` position-rows
    /// flowing through the same batched kernels in ONE pass. This is the
    /// entry point speculative verification scores `m·(K+1)` rows
    /// through, and the one concurrent prefills batch through — the
    /// generalization of [`NativeEngine::step_batch`] from slot-rows to
    /// position-rows.
    ///
    /// All norms, projections and MLPs run as row-batched kernels
    /// ([`QuantLinear::gemv_multi`]), so quantized weights, scales and
    /// sub-branch matrices stream **once per step** regardless of slot
    /// count or positions per slot — [`Traffic::weight_bytes`] per step
    /// is independent of both. Execution only forks per row where state
    /// genuinely differs: the embedding position, RoPE rotation, the KV
    /// append and the attention gathers (threaded over rows via
    /// `FBQ_THREADS` above the work floor). Within a slot, rows append
    /// K/V in position order before any row gathers, so later rows
    /// attend over earlier same-step rows exactly as sequential decode
    /// would — every row performs bit-identical float operations to
    /// [`NativeEngine::decode_one`] at that position.
    ///
    /// Returns logits per slot per position when `all_logits` (the
    /// full-rows verifier shape), or only each slot's last position when
    /// not (the prefill shape — one `[vocab]` row per slot). This is
    /// [`NativeEngine::step_batch_multi_sel`] with a uniform
    /// [`RowsWant`] across slots.
    pub fn step_batch_multi(
        &self,
        groups: &[&[u32]],
        kv: &mut dyn KvSlotBatch,
        ws: &mut EngineWs,
        all_logits: bool,
    ) -> Vec<Vec<Vec<f32>>> {
        let want = vec![if all_logits { RowsWant::All } else { RowsWant::Last }; groups.len()];
        self.step_batch_multi_sel(groups, kv, ws, &want)
            .into_iter()
            .map(SlotLogits::into_rows)
            .collect()
    }

    /// [`NativeEngine::step_batch_multi`] with a **per-slot output
    /// selection**: `want[i]` picks what slot `i` gets back — its last
    /// full row, every full row, or only the argmax id per row. All
    /// selections ride the same single weight-stationary pass (the
    /// transformer body is identical; only the final-norm + lm-head tail
    /// differs), and the lm-head weights stream **once** for the whole
    /// batch regardless of the mix, so verify weight traffic is
    /// independent of both K and the greedy/sampled composition.
    /// Argmax rows reduce to a running `(value, id)` maximum inside the
    /// lm-head kernel — no `rows × vocab` logits buffer exists for them,
    /// and ties resolve exactly as `ops::argmax` (first maximum).
    pub fn step_batch_multi_sel(
        &self,
        groups: &[&[u32]],
        kv: &mut dyn KvSlotBatch,
        ws: &mut EngineWs,
        want: &[RowsWant],
    ) -> Vec<SlotLogits> {
        let m = groups.len();
        assert_eq!(m, want.len(), "one RowsWant per slot group");
        assert!(m > 0, "batched step over zero slots");
        assert_eq!(m, kv.n_slots(), "group/slot count mismatch");
        let cfg = &self.cfg;
        let (d, hd, nh) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let rows: usize = groups.iter().map(|g| g.len()).sum();
        let mut pos = Vec::with_capacity(rows);
        let mut row_slot = Vec::with_capacity(rows);
        for (i, g) in groups.iter().enumerate() {
            assert!(!g.is_empty(), "empty token group for slot {i}");
            let p0 = kv.len(i);
            assert!(p0 + g.len() <= cfg.max_seq, "kv cache full on slot {i}");
            for j in 0..g.len() {
                row_slot.push(i);
                pos.push(p0 + j);
            }
        }

        // embed (per-row fork: each row has its own token and position)
        ws.x.resize(rows * d, 0.0);
        {
            let mut r = 0usize;
            for g in groups {
                for &tok in g.iter() {
                    let tok = tok as usize;
                    let xrow = &mut ws.x[r * d..(r + 1) * d];
                    xrow.copy_from_slice(&self.tok_emb[tok * d..(tok + 1) * d]);
                    if let Some(pe) = &self.pos_emb {
                        for (xv, pv) in xrow.iter_mut().zip(&pe[pos[r] * d..(pos[r] + 1) * d]) {
                            *xv += pv;
                        }
                    }
                    r += 1;
                }
            }
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            // --- attention ---
            ws.h.resize(rows * d, 0.0);
            let mut hbuf = std::mem::take(&mut ws.h);
            for r in 0..rows {
                self.norm(
                    &blk.attn_norm_w,
                    blk.attn_norm_b.as_ref(),
                    &ws.x[r * d..(r + 1) * d],
                    &mut hbuf[r * d..(r + 1) * d],
                );
            }
            ws.qb.resize(rows * d, 0.0);
            ws.kb.resize(rows * d, 0.0);
            ws.vb.resize(rows * d, 0.0);
            let mut qb = std::mem::take(&mut ws.qb);
            let mut kb = std::mem::take(&mut ws.kb);
            let mut vb = std::mem::take(&mut ws.vb);
            // kernel-level flight-recorder phases (FBQ_TRACE=kernel): the
            // span constructor is one relaxed load when disarmed
            let mut tr_qkv = crate::trace::span(crate::trace::Phase::Gemv, 0, crate::trace::SLOT_NONE);
            tr_qkv.payload(rows as u64);
            blk.q.gemv_multi(&hbuf, rows, &mut qb, self.mode, &mut ws.kernel, &mut ws.traffic);
            blk.k.gemv_multi(&hbuf, rows, &mut kb, self.mode, &mut ws.kernel, &mut ws.traffic);
            blk.v.gemv_multi(&hbuf, rows, &mut vb, self.mode, &mut ws.kernel, &mut ws.traffic);
            tr_qkv.end();
            // per-row fork: rotate at the row's own position, append.
            // Same-slot rows append in position order so the gathers
            // below see this step's earlier keys (prefill causality).
            for r in 0..rows {
                if cfg.rope() {
                    for h in 0..nh {
                        ops::rope_rotate(
                            &mut qb[r * d + h * hd..r * d + (h + 1) * hd],
                            pos[r],
                            cfg.rope_theta,
                        );
                        ops::rope_rotate(
                            &mut kb[r * d + h * hd..r * d + (h + 1) * hd],
                            pos[r],
                            cfg.rope_theta,
                        );
                    }
                }
                kv.write(row_slot[r], l, pos[r], &kb[r * d..(r + 1) * d], &vb[r * d..(r + 1) * d]);
            }
            // per-row fork: attention over each row's own causal history,
            // fanned over the FBQ_THREADS workers when large enough
            ws.attn.resize(rows * d, 0.0);
            let mut attn = std::mem::take(&mut ws.attn);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut tr_attn =
                crate::trace::span(crate::trace::Phase::Attention, 0, crate::trace::SLOT_NONE);
            tr_attn.payload(rows as u64);
            attention_rows(
                &*kv,
                l,
                nh,
                hd,
                d,
                scale,
                &qb,
                &pos,
                &row_slot,
                &mut attn,
                &mut ws.scores,
            );
            tr_attn.end();
            let mut tr_proj =
                crate::trace::span(crate::trace::Phase::Gemv, 0, crate::trace::SLOT_NONE);
            tr_proj.payload(rows as u64);
            blk.o.gemv_multi(&attn, rows, &mut hbuf, self.mode, &mut ws.kernel, &mut ws.traffic);
            for (xv, hv) in ws.x.iter_mut().zip(&hbuf) {
                *xv += hv;
            }
            ws.attn = attn;
            ws.qb = qb;
            ws.kb = kb;
            ws.vb = vb;

            // --- mlp ---
            for r in 0..rows {
                self.norm(
                    &blk.mlp_norm_w,
                    blk.mlp_norm_b.as_ref(),
                    &ws.x[r * d..(r + 1) * d],
                    &mut hbuf[r * d..(r + 1) * d],
                );
            }
            ws.m3.resize(rows * d, 0.0);
            let mut mout = std::mem::take(&mut ws.m3);
            self.mlp_multi(blk, &hbuf, rows, ws, &mut mout);
            tr_proj.end();
            for (xv, mv) in ws.x.iter_mut().zip(&mout) {
                *xv += mv;
            }
            ws.m3 = mout;
            ws.h = hbuf;
        }
        for (i, g) in groups.iter().enumerate() {
            kv.advance(i, g.len());
        }

        // final norm + ONE batched lm-head pass over exactly the rows
        // the caller selected: full-logits rows first, then argmax-only
        // rows (which never materialize a vocab-sized buffer)
        let vocab = cfg.vocab;
        let mut row0 = Vec::with_capacity(m);
        {
            let mut r = 0usize;
            for g in groups {
                row0.push(r);
                r += g.len();
            }
        }
        let mut full_rows: Vec<usize> = Vec::new();
        let mut amax_rows: Vec<usize> = Vec::new();
        for (i, g) in groups.iter().enumerate() {
            match want[i] {
                RowsWant::Last => full_rows.push(row0[i] + g.len() - 1),
                RowsWant::All => full_rows.extend(row0[i]..row0[i] + g.len()),
                RowsWant::Argmax => amax_rows.extend(row0[i]..row0[i] + g.len()),
            }
        }
        let (n_full, n_amax) = (full_rows.len(), amax_rows.len());
        ws.hrow.resize((n_full + n_amax) * d, 0.0);
        let mut hbuf = std::mem::take(&mut ws.hrow);
        for (j, &r) in full_rows.iter().chain(amax_rows.iter()).enumerate() {
            self.norm(
                &self.final_norm_w,
                self.final_norm_b.as_ref(),
                &ws.x[r * d..(r + 1) * d],
                &mut hbuf[j * d..(j + 1) * d],
            );
        }
        let mut flat = vec![0f32; n_full * vocab];
        let mut best = vec![(f32::NEG_INFINITY, 0u32); n_amax];
        let mut tr_lm = crate::trace::span(crate::trace::Phase::LmHead, 0, crate::trace::SLOT_NONE);
        tr_lm.payload((n_full + n_amax) as u64);
        self.lm_head_select(&hbuf, n_full, n_amax, &mut flat, &mut best, ws);
        tr_lm.end();
        ws.hrow = hbuf;
        let mut out = Vec::with_capacity(m);
        let (mut fi, mut ai) = (0usize, 0usize);
        for (i, g) in groups.iter().enumerate() {
            match want[i] {
                RowsWant::Last => {
                    out.push(SlotLogits::Rows(vec![flat[fi * vocab..(fi + 1) * vocab].to_vec()]));
                    fi += 1;
                }
                RowsWant::All => {
                    let per = (0..g.len())
                        .map(|_| {
                            let row = flat[fi * vocab..(fi + 1) * vocab].to_vec();
                            fi += 1;
                            row
                        })
                        .collect();
                    out.push(SlotLogits::Rows(per));
                }
                RowsWant::Argmax => {
                    let ids = (0..g.len())
                        .map(|_| {
                            let id = best[ai].1;
                            ai += 1;
                            id
                        })
                        .collect();
                    out.push(SlotLogits::Argmax(ids));
                }
            }
        }
        out
    }

    /// Batched MLP mirroring [`NativeEngine::mlp`] with the
    /// weight-stationary kernels (bit-identical per row).
    fn mlp_multi(&self, blk: &Block, h: &[f32], m: usize, ws: &mut EngineWs, out: &mut [f32]) {
        let d_ff = self.cfg.d_ff;
        let mode = self.mode;
        if let Some(down) = &blk.m3 {
            // gated: down( silu(gate(h)) * up(h) )
            ws.m1.resize(m * d_ff, 0.0);
            ws.m2.resize(m * d_ff, 0.0);
            let (m1, m2) = (&mut ws.m1, &mut ws.m2);
            blk.m1.gemv_multi(h, m, m1, mode, &mut ws.kernel, &mut ws.traffic);
            blk.m2.gemv_multi(h, m, m2, mode, &mut ws.kernel, &mut ws.traffic);
            for i in 0..m * d_ff {
                m1[i] = ops::silu(m1[i]) * m2[i];
            }
            down.gemv_multi(m1, m, out, mode, &mut ws.kernel, &mut ws.traffic);
        } else {
            // gelu MLP: proj(gelu(fc(h)))
            ws.m1.resize(m * d_ff, 0.0);
            let m1 = &mut ws.m1;
            blk.m1.gemv_multi(h, m, m1, mode, &mut ws.kernel, &mut ws.traffic);
            for v in m1.iter_mut() {
                *v = ops::gelu(*v);
            }
            blk.m2.gemv_multi(m1, m, out, mode, &mut ws.kernel, &mut ws.traffic);
        }
    }

    /// Batched dense lm-head: `h [m, d]` → `out [m, vocab]`. The weight
    /// matrix streams once for all rows; vocab rows fan out over the
    /// `FBQ_THREADS` pool when the call is large enough (each logit is
    /// still computed by exactly one worker with the serial operation
    /// order, so threading never changes results).
    fn lm_head_multi(&self, h: &[f32], m: usize, out: &mut [f32], ws: &mut EngineWs) {
        let (d, vocab) = (self.cfg.d_model, self.cfg.vocab);
        {
            let t = &mut ws.traffic;
            t.kernel_launches += 1;
            t.bytes_read += 4 * (self.lm_head.len() + m * d) as u64;
            t.weight_bytes += 4 * self.lm_head.len() as u64;
            t.bytes_written += 4 * (m * vocab) as u64;
            t.macs += (m * vocab * d) as u64;
        }
        let threads = kernels::plan_threads(m * vocab * d);
        // weight-row outer: each lm-head row streams once for all slots
        kernels::row_parallel(vocab, m, threads, &mut ws.kernel.ytile, out, |lo, hi, tile| {
            for o in lo..hi {
                let wrow = &self.lm_head[o * d..(o + 1) * d];
                for i in 0..m {
                    tile[(o - lo) * m + i] = ops::dot(&h[i * d..(i + 1) * d], wrow);
                }
            }
        });
    }

    /// One lm-head pass over `n_full + n_amax` normed rows (`h` holds
    /// the full-logits rows first, then the argmax-only rows): full rows
    /// land in `flat [n_full, vocab]`, argmax rows reduce to a running
    /// `(value, id)` maximum in `best` — no vocab-sized buffer is ever
    /// written for them. The weight matrix streams once for the whole
    /// mix (one traffic charge, independent of the full/argmax split);
    /// vocab rows fan out over the `FBQ_THREADS` pool when large enough,
    /// and chunk results merge in ascending vocab order with a strict
    /// `>` so argmax ties resolve exactly as the serial first-max scan
    /// (`ops::argmax`).
    fn lm_head_select(
        &self,
        h: &[f32],
        n_full: usize,
        n_amax: usize,
        flat: &mut [f32],
        best: &mut [(f32, u32)],
        ws: &mut EngineWs,
    ) {
        let (d, vocab) = (self.cfg.d_model, self.cfg.vocab);
        if n_amax == 0 {
            // pure full-rows shape: the allocation-free tiled kernel
            self.lm_head_multi(h, n_full, flat, ws);
            return;
        }
        {
            let t = &mut ws.traffic;
            t.kernel_launches += 1;
            t.bytes_read += 4 * (self.lm_head.len() + (n_full + n_amax) * d) as u64;
            t.weight_bytes += 4 * self.lm_head.len() as u64;
            t.bytes_written += 4 * (n_full * vocab + n_amax) as u64;
            t.macs += ((n_full + n_amax) * vocab * d) as u64;
        }
        let (h_full, h_amax) = h.split_at(n_full * d);
        let threads = kernels::plan_threads((n_full + n_amax) * vocab * d);
        if threads <= 1 {
            for o in 0..vocab {
                let wrow = &self.lm_head[o * d..(o + 1) * d];
                for i in 0..n_full {
                    flat[i * vocab + o] = ops::dot(&h_full[i * d..(i + 1) * d], wrow);
                }
                for j in 0..n_amax {
                    let v = ops::dot(&h_amax[j * d..(j + 1) * d], wrow);
                    if v > best[j].0 {
                        best[j] = (v, o as u32);
                    }
                }
            }
            return;
        }
        let chunks = kernels::split_rows(vocab, threads);
        // per-chunk scratch owned by the submitter so pool workers only
        // borrow disjoint &mut slices (no allocation inside the jobs)
        let mut tiles: Vec<Vec<f32>> = chunks
            .iter()
            .map(|&(lo, hi)| vec![0f32; (hi - lo) * n_full])
            .collect();
        let mut lbests: Vec<Vec<(f32, u32)>> =
            vec![vec![(f32::NEG_INFINITY, 0u32); n_amax]; chunks.len()];
        let jobs: Vec<crate::util::pool::Task<'_>> = chunks
            .iter()
            .zip(tiles.iter_mut().zip(lbests.iter_mut()))
            .map(|(&(lo, hi), (tile, lbest))| {
                Box::new(move || {
                    for o in lo..hi {
                        let wrow = &self.lm_head[o * d..(o + 1) * d];
                        for i in 0..n_full {
                            tile[(o - lo) * n_full + i] =
                                ops::dot(&h_full[i * d..(i + 1) * d], wrow);
                        }
                        for j in 0..n_amax {
                            let v = ops::dot(&h_amax[j * d..(j + 1) * d], wrow);
                            if v > lbest[j].0 {
                                lbest[j] = (v, o as u32);
                            }
                        }
                    }
                }) as crate::util::pool::Task<'_>
            })
            .collect();
        crate::util::pool::run_jobs(jobs);
        // merge in ascending chunk order: strict `>` keeps first-max ties
        for (&(lo, hi), (tile, lbest)) in chunks.iter().zip(tiles.iter().zip(lbests.iter())) {
            for o in lo..hi {
                for i in 0..n_full {
                    flat[i * vocab + o] = tile[(o - lo) * n_full + i];
                }
            }
            for j in 0..n_amax {
                if lbest[j].0 > best[j].0 {
                    best[j] = lbest[j];
                }
            }
        }
    }
}

/// Per-row attention gathers (scores → softmax → weighted values) of the
/// batched step: row `r` attends over slot `row_slot[r]`'s history
/// `0..=pos[r]` through the shared [`KvSlotBatch`] view. Rows fan out
/// over the `FBQ_THREADS` workers when the gathered work clears the
/// parallel floor (gathers are read-only and rows write disjoint `attn`
/// slices — embarrassingly parallel); each row is produced by exactly
/// one worker with the serial operation order, so threading never
/// changes results.
#[allow(clippy::too_many_arguments)]
fn attention_rows(
    kv: &dyn KvSlotBatch,
    l: usize,
    nh: usize,
    hd: usize,
    d: usize,
    scale: f32,
    qb: &[f32],
    pos: &[usize],
    row_slot: &[usize],
    attn: &mut [f32],
    scores: &mut Vec<f32>,
) {
    let rows = pos.len();
    let gather = |r: usize, out_row: &mut [f32], scores: &mut Vec<f32>| {
        let i = row_slot[r];
        let plen = pos[r] + 1;
        scores.resize(plen, 0.0);
        for h in 0..nh {
            let qv = &qb[r * d + h * hd..r * d + (h + 1) * hd];
            kv.score_keys(i, l, h, qv, scale, &mut scores[..plen]);
            ops::softmax_rows(&mut scores[..plen], 1, plen);
            let out = &mut out_row[h * hd..(h + 1) * hd];
            out.fill(0.0);
            kv.accumulate_values(i, l, h, &scores[..plen], out);
        }
    };
    // ~2·d MACs per history position per row (score + accumulate)
    let total_macs: usize = pos.iter().map(|&p| 2 * (p + 1) * d).sum();
    let threads = kernels::plan_threads(total_macs);
    if threads <= 1 || rows == 1 {
        for r in 0..rows {
            let out_row = &mut attn[r * d..(r + 1) * d];
            gather(r, out_row, &mut *scores);
        }
        return;
    }
    let chunks = kernels::split_rows(rows, threads);
    // carve attn into one disjoint [rows_chunk, d] tile per worker
    let mut tiles: Vec<&mut [f32]> = Vec::with_capacity(chunks.len());
    let mut rest: &mut [f32] = attn;
    for &(lo, hi) in &chunks {
        let taken = std::mem::take(&mut rest);
        let (tile, tail) = taken.split_at_mut((hi - lo) * d);
        tiles.push(tile);
        rest = tail;
    }
    let gather = &gather;
    let jobs: Vec<crate::util::pool::Task<'_>> = chunks
        .iter()
        .zip(tiles)
        .map(|(&(lo, hi), tile)| {
            Box::new(move || {
                let mut local: Vec<f32> = Vec::new();
                for r in lo..hi {
                    gather(r, &mut tile[(r - lo) * d..(r - lo + 1) * d], &mut local);
                }
            }) as crate::util::pool::Task<'_>
        })
        .collect();
    crate::util::pool::run_jobs(jobs);
}
