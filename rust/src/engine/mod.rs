//! Native inference engine: the wall-clock testbed for the paper's
//! latency/throughput figures (Figs 1, 4, 7).
//!
//! CPU GEMV at batch 1 is memory-bandwidth-bound on weight bytes — the same
//! regime as single-stream LLM decoding on a GPU — so the *shapes* of the
//! paper's results (INT4 beats FP, naive sub-branches blow up decode,
//! fusion recovers it) reproduce here with real measured wall-clock.
//!
//! * [`kernels`] — quantized GEMV/GEMM in fused (one pass, shared
//!   accumulator) and un-fused (4 passes, materialized intermediates)
//!   variants, with byte-traffic accounting,
//! * [`kv`] — KV storage behind the [`kv::KvSlot`] interface: the dense
//!   per-session cache and the paged, prefix-sharing [`kv::KvPagePool`],
//!   plus the [`kv::KvSlotBatch`] views the batched decode steps through,
//! * [`native`] — the full transformer forward (prefill, single-slot
//!   decode, and the weight-stationary batched step — including its
//!   multi-position generalization backing speculative verification and
//!   batched prefill).

pub mod kernels;
pub mod kv;
pub mod native;

pub use kernels::{QuantLinear, SubMode, Traffic};
pub use kv::{
    KvCache, KvPagePool, KvPoolConfig, KvPoolStats, KvSlot, KvSlotBatch, PagedKv, PagedKvRef,
    PagedSlotBatch, SlotBatch,
};
pub use native::{NativeEngine, RowsWant, SlotLogits};
