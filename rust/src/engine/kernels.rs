//! Quantized linear kernels: the rust materialization of the paper's §4.3
//! fusion study.
//!
//! A reconstructed layer computes `y = Wd·x + B·(A·x)` with
//! `Wd = dequant(codes)`. Two execution strategies:
//!
//! * **Fused** (`SubMode::Fused`, FBQuant's kernel): one pass — codes are
//!   de-quantized on the fly inside the dot-product loop (never
//!   materialized), and the sub-branch up-projection accumulates into the
//!   same output buffer while it is still hot. 2 logical kernels
//!   (down-projection + fused main).
//! * **Un-fused** (`SubMode::Unfused`, the conventional "INT4-Sub"
//!   pipeline): 4 passes with materialized intermediates — (1) dequantize
//!   the whole weight matrix to a float scratch buffer, (2) dense GEMV
//!   from the scratch, (3) down-projection to an `xa` buffer, (4)
//!   re-read + re-write the output while adding `B·xa`.
//!
//! Every pass accounts its bytes into [`Traffic`]; the un-fused path's
//! extra traffic is *real* (the scratch materialization actually happens),
//! so wall-clock differences measured by the Fig-4/7 benches are genuine
//! memory effects, not simulated sleeps.

use crate::quant::pack::word_codes;

/// Byte-traffic and dispatch accounting (one per engine/bench run).
#[derive(Debug, Clone, Default)]
pub struct Traffic {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub kernel_launches: u64,
    pub macs: u64,
}

impl Traffic {
    pub fn reset(&mut self) {
        *self = Traffic::default();
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }
}

/// How to execute the sub-branch (and the main path) of quantized layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubMode {
    /// Ignore A/B even if present (the plain "INT4" series).
    None,
    /// Conventional 4-kernel pipeline ("INT4-Sub").
    Unfused,
    /// FBQuant fused kernels ("INT4-FBQuant").
    Fused,
}

/// A prepared quantized linear layer.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub out: usize,
    pub cin: usize,
    pub bits: u8,
    pub group: usize,
    /// `[out, cin/8]` nibble-packed codes
    pub packed: Vec<u32>,
    /// `[out, cin/group]`
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub rank: usize,
    /// A `[rank, cin]`, B `[out, rank]`
    pub a: Option<Vec<f32>>,
    pub b: Option<Vec<f32>>,
    pub col_scale: Option<Vec<f32>>,
    pub bias: Option<Vec<f32>>,
}

/// Reusable scratch to keep the hot path allocation-free.
#[derive(Debug, Default)]
pub struct Workspace {
    pub dequant: Vec<f32>,
    pub xa: Vec<f32>,
    pub xs: Vec<f32>,
    pub bt: Vec<f32>,
}

/// Transpose B `[out, rank]` into `bt [rank, out]` (GEMM up-projection runs
/// as rank-many axpys over contiguous rows — small-dot call overhead is
/// what made the naive loop slow).
fn transpose_b(b: &[f32], out: usize, rank: usize, bt: &mut Vec<f32>) {
    bt.clear();
    bt.resize(rank * out, 0.0);
    for o in 0..out {
        for r in 0..rank {
            bt[r * out + o] = b[o * rank + r];
        }
    }
}

impl QuantLinear {
    /// Logical weight bytes of the packed main path (bits/8 per code).
    pub fn code_bytes(&self) -> u64 {
        (self.out * self.cin) as u64 * self.bits as u64 / 8
    }

    fn meta_bytes(&self) -> u64 {
        4 * (self.scales.len() + self.zeros.len()) as u64
    }

    /// y = quantized-GEMV(x), dispatching on `mode`. `x: [cin]`,
    /// `y: [out]` (overwritten; bias included).
    pub fn gemv(&self, x: &[f32], y: &mut [f32], mode: SubMode, ws: &mut Workspace, t: &mut Traffic) {
        debug_assert_eq!(x.len(), self.cin);
        debug_assert_eq!(y.len(), self.out);
        let Workspace { dequant, xa, xs, .. } = ws;
        // optional AWQ column scaling, applied once — both branches then
        // read the scaled buffer.
        let x: &[f32] = match &self.col_scale {
            None => x,
            Some(cs) => {
                xs.clear();
                xs.extend(x.iter().zip(cs).map(|(xi, ci)| xi * ci));
                xs
            }
        };
        match mode {
            SubMode::None => {
                self.gemv_main_fused(x, y, t);
            }
            SubMode::Fused => {
                // kernel 1: down-projection (xa stays hot for kernel 2)
                let has_sub = self.compute_xa(x, xa, t);
                // kernel 2: dequant + main GEMV + up-projection, one pass
                self.gemv_main_fused(x, y, t);
                if has_sub {
                    self.add_up_projection_inline(xa, y, t);
                }
            }
            SubMode::Unfused => {
                // kernel 1: materialize the dequantized weights
                self.dequant_to(dequant, t);
                // kernel 2: dense GEMV from the scratch buffer
                t.kernel_launches += 1;
                t.bytes_read += 4 * (self.out * self.cin + self.cin) as u64;
                t.bytes_written += 4 * self.out as u64;
                t.macs += (self.out * self.cin) as u64;
                for o in 0..self.out {
                    y[o] = crate::tensor::ops::dot(x, &dequant[o * self.cin..(o + 1) * self.cin]);
                }
                // kernel 3: down-projection writes xa to memory
                let has_sub = self.compute_xa(x, xa, t);
                // kernel 4: up-projection re-reads and re-writes y
                if has_sub {
                    t.kernel_launches += 1;
                    t.bytes_read += 4 * (self.out + self.out * self.rank + self.rank) as u64;
                    t.bytes_written += 4 * self.out as u64;
                    t.macs += (self.out * self.rank) as u64;
                    let b = self.b.as_ref().unwrap();
                    for o in 0..self.out {
                        y[o] += crate::tensor::ops::dot(xa, &b[o * self.rank..(o + 1) * self.rank]);
                    }
                }
            }
        }
        if let Some(bias) = &self.bias {
            for (yi, bi) in y.iter_mut().zip(bias) {
                *yi += bi;
            }
        }
    }

    /// Fused single-pass main path: dequantize per packed word inside the
    /// accumulation loop using the per-group partial-sum identity
    /// Σ (c−z)·s·x = s·(Σ c·x − z·Σ x).
    fn gemv_main_fused(&self, x: &[f32], y: &mut [f32], t: &mut Traffic) {
        t.kernel_launches += 1;
        t.bytes_read += self.code_bytes() + self.meta_bytes() + 4 * self.cin as u64;
        t.bytes_written += 4 * self.out as u64;
        t.macs += (self.out * self.cin) as u64;
        let ngroups = self.cin / self.group;
        let words_per_group = self.group / 8;
        let words_per_row = self.cin / 8;
        // per-group Σx is shared across all output rows: precompute.
        let mut xsum = vec![0f32; ngroups];
        for g in 0..ngroups {
            xsum[g] = x[g * self.group..(g + 1) * self.group].iter().sum();
        }
        for o in 0..self.out {
            let row_words = &self.packed[o * words_per_row..(o + 1) * words_per_row];
            let mut acc = 0f32;
            for g in 0..ngroups {
                let scale = self.scales[o * ngroups + g];
                let zero = self.zeros[o * ngroups + g];
                let mut s1 = 0f32;
                for wi in 0..words_per_group {
                    let codes = word_codes(row_words[g * words_per_group + wi]);
                    let xb = &x[g * self.group + wi * 8..g * self.group + wi * 8 + 8];
                    s1 += codes[0] * xb[0]
                        + codes[1] * xb[1]
                        + codes[2] * xb[2]
                        + codes[3] * xb[3]
                        + codes[4] * xb[4]
                        + codes[5] * xb[5]
                        + codes[6] * xb[6]
                        + codes[7] * xb[7];
                }
                acc += scale * (s1 - zero * xsum[g]);
            }
            y[o] = acc;
        }
    }

    /// xa = A·x (kernel; returns false when the layer has no sub-branch).
    fn compute_xa(&self, x: &[f32], xa: &mut Vec<f32>, t: &mut Traffic) -> bool {
        let Some(a) = &self.a else { return false };
        if self.b.is_none() {
            return false;
        }
        t.kernel_launches += 1;
        t.bytes_read += 4 * (self.rank * self.cin + self.cin) as u64;
        t.bytes_written += 4 * self.rank as u64;
        t.macs += (self.rank * self.cin) as u64;
        xa.clear();
        xa.resize(self.rank, 0.0);
        for r in 0..self.rank {
            xa[r] = crate::tensor::ops::dot(x, &a[r * self.cin..(r + 1) * self.cin]);
        }
        true
    }

    /// Fused up-projection: y is still hot (no extra output round-trip is
    /// charged; only B and xa are read).
    fn add_up_projection_inline(&self, xa: &[f32], y: &mut [f32], t: &mut Traffic) {
        let b = self.b.as_ref().unwrap();
        t.bytes_read += 4 * (self.out * self.rank) as u64;
        t.macs += (self.out * self.rank) as u64;
        for o in 0..self.out {
            y[o] += crate::tensor::ops::dot(xa, &b[o * self.rank..(o + 1) * self.rank]);
        }
    }

    /// Dequantize the whole matrix into `dq` (the un-fused pipeline's
    /// materialization kernel).
    fn dequant_to(&self, dq: &mut Vec<f32>, t: &mut Traffic) {
        t.kernel_launches += 1;
        t.bytes_read += self.code_bytes() + self.meta_bytes();
        t.bytes_written += 4 * (self.out * self.cin) as u64;
        dq.clear();
        dq.resize(self.out * self.cin, 0.0);
        let ngroups = self.cin / self.group;
        let words_per_row = self.cin / 8;
        for o in 0..self.out {
            let row_words = &self.packed[o * words_per_row..(o + 1) * words_per_row];
            let drow = &mut dq[o * self.cin..(o + 1) * self.cin];
            for wi in 0..words_per_row {
                let codes = word_codes(row_words[wi]);
                let base = wi * 8;
                for j in 0..8 {
                    let g = (base + j) / self.group;
                    let scale = self.scales[o * ngroups + g];
                    let zero = self.zeros[o * ngroups + g];
                    drow[base + j] = (codes[j] - zero) * scale;
                }
            }
        }
    }

    /// GEMM variant for prefill: x `[m, cin]` → y `[m, out]`.
    ///
    /// Fused: each weight row is de-quantized once into a stack tile and
    /// reused across all m activation rows (the VMEM-tile analogue);
    /// un-fused: full materialization then dense GEMM + two extra passes.
    pub fn gemm(&self, x: &[f32], m: usize, y: &mut [f32], mode: SubMode, ws: &mut Workspace, t: &mut Traffic) {
        debug_assert_eq!(x.len(), m * self.cin);
        debug_assert_eq!(y.len(), m * self.out);
        if m == 1 {
            // decode shape: take the single-pass GEMV path (the GEMM path
            // would materialize the whole weight matrix per token)
            return self.gemv(x, y, mode, ws, t);
        }
        let Workspace { dequant, xa: xa_buf, xs, bt } = ws;
        // column scaling applied once to the whole block
        let xbuf: &[f32] = match &self.col_scale {
            None => x,
            Some(cs) => {
                xs.clear();
                xs.reserve(m * self.cin);
                for i in 0..m {
                    xs.extend(
                        x[i * self.cin..(i + 1) * self.cin].iter().zip(cs).map(|(xi, ci)| xi * ci),
                    );
                }
                xs
            }
        };
        // Main path (all modes): the weight tile is de-quantized into a
        // cache-resident scratch and consumed by a dense GEMM. At prefill
        // the matmul is compute-bound on this scalar CPU, so the fusion
        // story plays out in the *sub-branch* handling below (and in the
        // traffic counters, which model the device-level difference: the
        // fused kernel keeps the tile in VMEM/registers and never
        // round-trips the output).
        self.dequant_to(dequant, t);
        if mode == SubMode::Unfused {
            // materialization charged as a real kernel with HBM round-trip
            t.kernel_launches += 1;
            t.bytes_read += 4 * (self.out * self.cin + m * self.cin) as u64;
            t.bytes_written += 4 * (m * self.out) as u64;
        } else {
            // fused accounting: the dequant pass above charged a
            // materialization; rebate it to model the in-register tile
            t.kernel_launches -= 1;
            t.bytes_written -= 4 * (self.out * self.cin) as u64;
            t.kernel_launches += 1;
            t.bytes_read += 4 * (m * self.cin) as u64;
            t.bytes_written += 4 * (m * self.out) as u64;
        }
        t.macs += (m * self.out * self.cin) as u64;
        crate::tensor::ops::matmul_t(xbuf, dequant, y, m, self.cin, self.out);

        let has_sub = matches!(mode, SubMode::Fused | SubMode::Unfused)
            && self.a.is_some()
            && self.b.is_some();
        if has_sub {
            let has = self.compute_xa_gemm(xbuf, m, xa_buf, t);
            if has {
                let b = self.b.as_ref().unwrap();
                if mode == SubMode::Unfused {
                    // separate up-projection kernel: y round-trips memory
                    t.kernel_launches += 1;
                    t.bytes_read += 4 * (m * self.out + self.out * self.rank + m * self.rank) as u64;
                    t.bytes_written += 4 * (m * self.out) as u64;
                } else {
                    // fused into the main kernel's accumulator tile
                    t.bytes_read += 4 * (self.out * self.rank) as u64;
                }
                t.macs += (m * self.out * self.rank) as u64;
                transpose_b(b, self.out, self.rank, bt);
                for i in 0..m {
                    let xa = &xa_buf[i * self.rank..(i + 1) * self.rank];
                    let yi = &mut y[i * self.out..(i + 1) * self.out];
                    for r in 0..self.rank {
                        crate::tensor::ops::axpy(xa[r], &bt[r * self.out..(r + 1) * self.out], yi);
                    }
                }
            }
        }
        if let Some(bias) = &self.bias {
            for i in 0..m {
                for (yi, bi) in y[i * self.out..(i + 1) * self.out].iter_mut().zip(bias) {
                    *yi += bi;
                }
            }
        }
    }

    fn compute_xa_gemm(&self, x: &[f32], m: usize, xa: &mut Vec<f32>, t: &mut Traffic) -> bool {
        let Some(a) = &self.a else { return false };
        if self.b.is_none() {
            return false;
        }
        t.kernel_launches += 1;
        t.bytes_read += 4 * (self.rank * self.cin + m * self.cin) as u64;
        t.bytes_written += 4 * (m * self.rank) as u64;
        t.macs += (m * self.rank * self.cin) as u64;
        xa.clear();
        xa.resize(m * self.rank, 0.0);
        for i in 0..m {
            let xi = &x[i * self.cin..(i + 1) * self.cin];
            for r in 0..self.rank {
                xa[i * self.rank + r] = crate::tensor::ops::dot(xi, &a[r * self.cin..(r + 1) * self.cin]);
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::groupwise;
    use crate::quant::pack::pack_codes;
    use crate::util::Pcg64;

    fn make_layer(rng: &mut Pcg64, out: usize, cin: usize, rank: usize, bits: u8, group: usize,
                  col_scale: bool) -> (QuantLinear, Vec<f32>) {
        let w: Vec<f32> = (0..out * cin).map(|_| rng.normal() as f32 * 0.5).collect();
        let p = groupwise::quant_params(&w, out, cin, bits, group);
        let codes = groupwise::quantize(&w, out, cin, &p);
        let a: Vec<f32> = (0..rank * cin).map(|_| rng.normal() as f32 * 0.05).collect();
        let b: Vec<f32> = (0..out * rank).map(|_| rng.normal() as f32 * 0.05).collect();
        let cs: Option<Vec<f32>> = col_scale
            .then(|| (0..cin).map(|_| 0.5 + rng.next_f32()).collect());
        let ql = QuantLinear {
            out,
            cin,
            bits,
            group,
            packed: pack_codes(&codes, out, cin),
            scales: p.scales.clone(),
            zeros: p.zeros.clone(),
            rank,
            a: Some(a.clone()),
            b: Some(b.clone()),
            col_scale: cs.clone(),
            bias: None,
        };
        // reference effective weight
        let mut wd = groupwise::dequantize(&codes, out, cin, &p);
        for o in 0..out {
            for c in 0..cin {
                let mut s = 0f32;
                for r in 0..rank {
                    s += b[o * rank + r] * a[r * cin + c];
                }
                wd[o * cin + c] += s;
                if let Some(cs) = &cs {
                    wd[o * cin + c] *= cs[c];
                }
            }
        }
        (ql, wd)
    }

    #[test]
    fn fused_unfused_agree_with_dense() {
        let mut rng = Pcg64::seeded(41);
        for &(out, cin, rank, cs) in
            &[(16usize, 32usize, 4usize, false), (24, 64, 8, true), (8, 128, 0, false)]
        {
            let (mut ql, wd) = make_layer(&mut rng, out, cin, rank, 4, 16, cs);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            let x: Vec<f32> = (0..cin).map(|_| rng.normal() as f32).collect();
            let want: Vec<f32> = (0..out)
                .map(|o| crate::tensor::ops::dot(&x, &wd[o * cin..(o + 1) * cin]))
                .collect();
            let mut ws = Workspace::default();
            let mut t = Traffic::default();
            for mode in [SubMode::Fused, SubMode::Unfused] {
                let mut y = vec![0f32; out];
                ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
                for o in 0..out {
                    assert!((y[o] - want[o]).abs() < 1e-3, "{mode:?} o={o}: {} vs {}", y[o], want[o]);
                }
            }
            // SubMode::None drops the sub-branch
            let mut y = vec![0f32; out];
            ql.gemv(&x, &mut y, SubMode::None, &mut ws, &mut t);
            if rank > 0 {
                let diff: f32 = y.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
                assert!(diff > 0.0);
            }
        }
    }

    #[test]
    fn gemm_matches_gemv() {
        let mut rng = Pcg64::seeded(42);
        let (ql, _) = make_layer(&mut rng, 24, 64, 8, 4, 16, true);
        let m = 5;
        let x: Vec<f32> = (0..m * 64).map(|_| rng.normal() as f32).collect();
        let mut ws = Workspace::default();
        let mut t = Traffic::default();
        for mode in [SubMode::None, SubMode::Fused, SubMode::Unfused] {
            let mut yg = vec![0f32; m * 24];
            ql.gemm(&x, m, &mut yg, mode, &mut ws, &mut t);
            for i in 0..m {
                let mut yv = vec![0f32; 24];
                ql.gemv(&x[i * 64..(i + 1) * 64], &mut yv, mode, &mut ws, &mut t);
                for o in 0..24 {
                    assert!((yg[i * 24 + o] - yv[o]).abs() < 1e-3, "{mode:?}");
                }
            }
        }
    }

    #[test]
    fn traffic_fused_less_than_unfused() {
        let mut rng = Pcg64::seeded(43);
        let (ql, _) = make_layer(&mut rng, 128, 128, 16, 4, 32, false);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let mut ws = Workspace::default();
        let mut y = vec![0f32; 128];

        let mut tf = Traffic::default();
        ql.gemv(&x, &mut y, SubMode::Fused, &mut ws, &mut tf);
        let mut tu = Traffic::default();
        ql.gemv(&x, &mut y, SubMode::Unfused, &mut ws, &mut tu);

        assert!(tf.total_bytes() < tu.total_bytes(),
                "fused {} !< unfused {}", tf.total_bytes(), tu.total_bytes());
        assert_eq!(tf.kernel_launches, 2);
        assert_eq!(tu.kernel_launches, 4);
        assert_eq!(tf.macs, tu.macs); // fusion changes traffic, not math
    }

    #[test]
    fn bits_affect_logical_code_bytes() {
        let mut rng = Pcg64::seeded(44);
        let (ql4, _) = make_layer(&mut rng, 16, 64, 0, 4, 16, false);
        let (ql3, _) = make_layer(&mut rng, 16, 64, 0, 3, 16, false);
        assert_eq!(ql4.code_bytes(), 16 * 64 / 2);
        assert_eq!(ql3.code_bytes(), 16 * 64 * 3 / 8);
    }
}
