//! Quantized linear kernels: the rust materialization of the paper's §4.3
//! fusion study.
//!
//! A reconstructed layer computes `y = Wd·x + B·(A·x)` with
//! `Wd = dequant(codes)`. Two execution strategies:
//!
//! * **Fused** (`SubMode::Fused`, FBQuant's kernel): one pass — codes are
//!   de-quantized on the fly inside the dot-product loop (never
//!   materialized), and the sub-branch up-projection accumulates into the
//!   same output buffer while it is still hot. 2 logical kernels
//!   (down-projection + fused main).
//! * **Un-fused** (`SubMode::Unfused`, the conventional "INT4-Sub"
//!   pipeline): 4 passes with materialized intermediates — (1) dequantize
//!   the whole weight matrix to a float scratch buffer, (2) dense GEMV
//!   from the scratch, (3) down-projection to an `xa` buffer, (4)
//!   re-read + re-write the output while adding `B·xa`.
//!
//! Every pass accounts its bytes into [`Traffic`]; the un-fused path's
//! extra traffic is *real* (the scratch materialization actually happens),
//! so wall-clock differences measured by the Fig-4/7 benches are genuine
//! memory effects, not simulated sleeps.

use crate::quant::groupwise::{self, QuantParams};
use crate::quant::pack::{pack_codes, unpack_codes};
use crate::tensor::simd;

/// Byte-traffic and dispatch accounting (one per engine/bench run).
#[derive(Debug, Clone, Default)]
pub struct Traffic {
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Persistent model-tensor bytes within `bytes_read`: packed codes,
    /// scales/zeros, sub-branch A/B and dense weight matrices. This is
    /// the component the weight-stationary batched decode amortizes —
    /// on [`QuantLinear::gemv_multi`] it is charged once per step
    /// regardless of how many slot activations ride along.
    pub weight_bytes: u64,
    pub kernel_launches: u64,
    pub macs: u64,
}

impl Traffic {
    pub fn reset(&mut self) {
        *self = Traffic::default();
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }
}

/// How to execute the sub-branch (and the main path) of quantized layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubMode {
    /// Ignore A/B even if present (the plain "INT4" series).
    None,
    /// Conventional 4-kernel pipeline ("INT4-Sub").
    Unfused,
    /// FBQuant fused kernels ("INT4-FBQuant").
    Fused,
}

/// A prepared quantized linear layer.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub out: usize,
    pub cin: usize,
    pub bits: u8,
    pub group: usize,
    /// `[out, cin/8]` nibble-packed codes
    pub packed: Vec<u32>,
    /// `[out, cin/group]`
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub rank: usize,
    /// A `[rank, cin]`, B `[out, rank]`
    pub a: Option<Vec<f32>>,
    pub b: Option<Vec<f32>>,
    pub col_scale: Option<Vec<f32>>,
    pub bias: Option<Vec<f32>>,
}

/// Reusable scratch to keep the hot path allocation-free.
#[derive(Debug, Default)]
pub struct Workspace {
    pub dequant: Vec<f32>,
    pub xa: Vec<f32>,
    pub xs: Vec<f32>,
    pub bt: Vec<f32>,
    /// per-(slot, group) activation sums for the fused partial-sum identity
    pub xsum: Vec<f32>,
    /// `[out, m]` output tile of the serial weight-stationary kernel
    pub ytile: Vec<f32>,
}

/// Clamp range for the parallel work floor (MACs). The floor itself is
/// derived from the persistent pool's *measured* dispatch overhead (see
/// [`par_floor_macs`]); the clamp keeps a mis-calibrated measurement
/// from either serializing real kernels (upper bound = the old hard
/// 4M-MAC floor) or fanning out toy ones (lower bound 256K MACs).
const PAR_FLOOR_MIN_MACS: usize = 1 << 18;
const PAR_FLOOR_MAX_MACS: usize = 1 << 22;

/// Fan out only when each extra worker amortizes its dispatch cost this
/// many times over, assuming ~1 scalar MAC/ns: a kernel at the floor
/// spends ≲1/16 of its serial runtime on pool dispatch.
const MACS_PER_OVERHEAD_NS: usize = 16;

/// Work floor (MACs) below which row-parallel kernels stay serial,
/// re-derived once per process from the persistent pool's measured
/// dispatch overhead instead of the old hard 4M-MAC cliff (which kept
/// mid-size kernels — e.g. rank-64 sub-branch A/B at small m — serial
/// even though pool dispatch is nearly free). `FBQ_PAR_FLOOR` overrides
/// the measurement (in MACs) for benchmarking.
pub(crate) fn par_floor_macs() -> usize {
    static FLOOR: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *FLOOR.get_or_init(|| {
        if let Ok(v) = std::env::var("FBQ_PAR_FLOOR") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        if crate::util::pool::decode_threads() <= 1 {
            return PAR_FLOOR_MAX_MACS; // serial config: floor is moot
        }
        let overhead_ns = crate::util::pool::global().dispatch_overhead_ns() as usize;
        (overhead_ns * MACS_PER_OVERHEAD_NS).clamp(PAR_FLOOR_MIN_MACS, PAR_FLOOR_MAX_MACS)
    })
}

/// Worker count for a row-parallel kernel invocation of `macs` total
/// work: 1 (serial) under the floor, then ramping one extra worker per
/// floor's-worth of MACs up to the `FBQ_THREADS` pool width — monotone
/// non-decreasing in `macs`, so no granularity cliff.
pub(crate) fn plan_threads(macs: usize) -> usize {
    plan_threads_with(macs, par_floor_macs(), crate::util::pool::decode_threads())
}

/// [`plan_threads`] with the floor and pool width explicit (unit tests
/// pin the ramp shape without depending on machine timing).
pub(crate) fn plan_threads_with(macs: usize, floor: usize, threads: usize) -> usize {
    if threads <= 1 || macs < floor {
        return 1;
    }
    threads.min(macs / floor + 1)
}

/// Split `n` rows into at most `parts` contiguous `(start, end)` chunks.
pub(crate) fn split_rows(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let (base, rem) = (n / parts, n % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Scatter a row-major `[rows, m]` tile into `ys [m, out]` at row offset
/// `o0` (the transpose from the kernel's weight-stationary layout back to
/// the engine's slot-major layout).
pub(crate) fn scatter_tile(tile: &[f32], m: usize, out: usize, o0: usize, ys: &mut [f32]) {
    let rows = tile.len() / m;
    for r in 0..rows {
        for i in 0..m {
            ys[i * out + o0 + r] = tile[r * m + i];
        }
    }
}

/// Shared row-parallel scaffold for the weight-stationary kernels: run
/// `fill(lo, hi, tile)` over chunks of `n_rows` output rows — serially
/// when `threads <= 1`, otherwise fanned out over the persistent worker
/// pool (`util::pool`; the per-call scoped-spawn baseline remains
/// selectable via `pool::force_dispatch`), each worker owning a
/// disjoint slice of the same `ytile` scratch (no per-chunk allocation)
/// — then scatter the `[rows, m]` tile back into slot-major `ys`. Every
/// output element is produced by exactly one `fill` invocation, so the
/// fan-out never changes results.
pub(crate) fn row_parallel<F>(
    n_rows: usize,
    m: usize,
    threads: usize,
    ytile: &mut Vec<f32>,
    ys: &mut [f32],
    fill: F,
) where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    ytile.clear();
    ytile.resize(n_rows * m, 0.0);
    if threads <= 1 {
        fill(0, n_rows, ytile);
    } else {
        let chunks = split_rows(n_rows, threads);
        // carve ytile into one disjoint [rows, m] tile per worker
        let mut tiles: Vec<&mut [f32]> = Vec::with_capacity(chunks.len());
        let mut rest: &mut [f32] = ytile;
        for &(lo, hi) in &chunks {
            let taken = std::mem::take(&mut rest);
            let (tile, tail) = taken.split_at_mut((hi - lo) * m);
            tiles.push(tile);
            rest = tail;
        }
        let fill = &fill;
        let jobs: Vec<crate::util::pool::Task<'_>> = chunks
            .iter()
            .zip(tiles)
            .map(|(&(lo, hi), tile)| {
                Box::new(move || fill(lo, hi, tile)) as crate::util::pool::Task<'_>
            })
            .collect();
        crate::util::pool::run_jobs(jobs);
    }
    scatter_tile(ytile, m, n_rows, 0, ys);
}

/// Transpose B `[out, rank]` into `bt [rank, out]` (GEMM up-projection runs
/// as rank-many axpys over contiguous rows — small-dot call overhead is
/// what made the naive loop slow).
fn transpose_b(b: &[f32], out: usize, rank: usize, bt: &mut Vec<f32>) {
    bt.clear();
    bt.resize(rank * out, 0.0);
    for o in 0..out {
        for r in 0..rank {
            bt[r * out + o] = b[o * rank + r];
        }
    }
}

impl QuantLinear {
    /// Logical weight bytes of the packed main path (bits/8 per code).
    pub fn code_bytes(&self) -> u64 {
        (self.out * self.cin) as u64 * self.bits as u64 / 8
    }

    fn meta_bytes(&self) -> u64 {
        4 * (self.scales.len() + self.zeros.len()) as u64
    }

    /// Shadow re-pack for self-speculative drafting: the main branch is
    /// de-quantized and RTN-requantized at `bits`
    /// ([`groupwise::requantize`]), the sub-branch is dropped (the draft
    /// is the bare branch by construction) and `col_scale`/`bias` are
    /// kept — they act on activations/outputs, not on the codes. The
    /// result streams `bits/8` logical bytes per weight where the target
    /// streams `self.bits/8` plus A/B.
    pub fn shadow(&self, bits: u8) -> QuantLinear {
        let codes = unpack_codes(&self.packed, self.out, self.cin);
        let p = QuantParams {
            bits: self.bits,
            group: self.group,
            scales: self.scales.clone(),
            zeros: self.zeros.clone(),
        };
        let (codes2, p2) = groupwise::requantize(&codes, self.out, self.cin, &p, bits);
        QuantLinear {
            out: self.out,
            cin: self.cin,
            bits,
            group: self.group,
            packed: pack_codes(&codes2, self.out, self.cin),
            scales: p2.scales,
            zeros: p2.zeros,
            rank: 0,
            a: None,
            b: None,
            col_scale: self.col_scale.clone(),
            bias: self.bias.clone(),
        }
    }

    /// y = quantized-GEMV(x), dispatching on `mode`. `x: [cin]`,
    /// `y: [out]` (overwritten; bias included).
    pub fn gemv(
        &self,
        x: &[f32],
        y: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        debug_assert_eq!(x.len(), self.cin);
        debug_assert_eq!(y.len(), self.out);
        let Workspace { dequant, xa, xs, xsum, ytile, .. } = ws;
        // optional AWQ column scaling, applied once — both branches then
        // read the scaled buffer.
        let x: &[f32] = match &self.col_scale {
            None => x,
            Some(cs) => {
                xs.clear();
                xs.extend(x.iter().zip(cs).map(|(xi, ci)| xi * ci));
                xs
            }
        };
        match mode {
            SubMode::None => {
                self.gemv_main_fused(x, y, xsum, ytile, t);
            }
            SubMode::Fused => {
                // kernel 1: down-projection (xa stays hot for kernel 2)
                let has_sub = self.compute_xa(x, xa, t);
                // kernel 2: dequant + main GEMV + up-projection, one pass
                self.gemv_main_fused(x, y, xsum, ytile, t);
                if has_sub {
                    self.add_up_projection_inline(xa, y, t);
                }
            }
            SubMode::Unfused => {
                // kernel 1: materialize the dequantized weights
                self.dequant_to(dequant, t);
                // kernel 2: dense GEMV from the scratch buffer
                t.kernel_launches += 1;
                t.bytes_read += 4 * (self.out * self.cin + self.cin) as u64;
                t.bytes_written += 4 * self.out as u64;
                t.macs += (self.out * self.cin) as u64;
                for o in 0..self.out {
                    y[o] = crate::tensor::ops::dot(x, &dequant[o * self.cin..(o + 1) * self.cin]);
                }
                // kernel 3: down-projection writes xa to memory
                let has_sub = self.compute_xa(x, xa, t);
                // kernel 4: up-projection re-reads and re-writes y
                if has_sub {
                    t.kernel_launches += 1;
                    t.bytes_read += 4 * (self.out + self.out * self.rank + self.rank) as u64;
                    t.weight_bytes += 4 * (self.out * self.rank) as u64;
                    t.bytes_written += 4 * self.out as u64;
                    t.macs += (self.out * self.rank) as u64;
                    let b = self.b.as_ref().unwrap();
                    for o in 0..self.out {
                        y[o] += crate::tensor::ops::dot(xa, &b[o * self.rank..(o + 1) * self.rank]);
                    }
                }
            }
        }
        if let Some(bias) = &self.bias {
            for (yi, bi) in y.iter_mut().zip(bias) {
                *yi += bi;
            }
        }
    }

    /// Fused single-pass main path: dequantize per packed word inside the
    /// accumulation loop using the per-group partial-sum identity
    /// Σ (c−z)·s·x = s·(Σ c·x − z·Σ x). `xsum`/`ytile` are
    /// caller-provided scratch (the hot loop stays allocation-free).
    ///
    /// This is the `m = 1` case of the weight-stationary row kernel
    /// ([`QuantLinear::fused_rows_multi`]) — one implementation serves
    /// both shapes, so the single-slot decode path gets the vectorized
    /// unpack+dot core, software prefetch, and (above the work floor)
    /// the persistent-pool row fan-out for free.
    fn gemv_main_fused(
        &self,
        x: &[f32],
        y: &mut [f32],
        xsum: &mut Vec<f32>,
        ytile: &mut Vec<f32>,
        t: &mut Traffic,
    ) {
        t.kernel_launches += 1;
        t.bytes_read += self.code_bytes() + self.meta_bytes() + 4 * self.cin as u64;
        t.weight_bytes += self.code_bytes() + self.meta_bytes();
        t.bytes_written += 4 * self.out as u64;
        t.macs += (self.out * self.cin) as u64;
        let ngroups = self.cin / self.group;
        // per-group Σx is shared across all output rows: precompute.
        xsum.clear();
        xsum.resize(ngroups, 0.0);
        for g in 0..ngroups {
            xsum[g] = x[g * self.group..(g + 1) * self.group].iter().sum();
        }
        let threads = plan_threads(self.out * self.cin);
        let xsum: &[f32] = xsum;
        row_parallel(self.out, 1, threads, ytile, y, |lo, hi, tile| {
            self.fused_rows_multi(x, 1, lo, hi, xsum, tile);
        });
    }

    /// xa = A·x (kernel; returns false when the layer has no sub-branch).
    fn compute_xa(&self, x: &[f32], xa: &mut Vec<f32>, t: &mut Traffic) -> bool {
        let Some(a) = &self.a else { return false };
        if self.b.is_none() {
            return false;
        }
        t.kernel_launches += 1;
        t.bytes_read += 4 * (self.rank * self.cin + self.cin) as u64;
        t.weight_bytes += 4 * (self.rank * self.cin) as u64;
        t.bytes_written += 4 * self.rank as u64;
        t.macs += (self.rank * self.cin) as u64;
        xa.clear();
        xa.resize(self.rank, 0.0);
        for r in 0..self.rank {
            xa[r] = crate::tensor::ops::dot(x, &a[r * self.cin..(r + 1) * self.cin]);
        }
        true
    }

    /// Fused up-projection: y is still hot (no extra output round-trip is
    /// charged; only B and xa are read).
    fn add_up_projection_inline(&self, xa: &[f32], y: &mut [f32], t: &mut Traffic) {
        let b = self.b.as_ref().unwrap();
        t.bytes_read += 4 * (self.out * self.rank) as u64;
        t.weight_bytes += 4 * (self.out * self.rank) as u64;
        t.macs += (self.out * self.rank) as u64;
        for o in 0..self.out {
            y[o] += crate::tensor::ops::dot(xa, &b[o * self.rank..(o + 1) * self.rank]);
        }
    }

    /// Dequantize the whole matrix into `dq` (the un-fused pipeline's
    /// materialization kernel). Iterates group-major like
    /// [`QuantLinear::gemv_main_fused`] — scale/zero are loop-invariant
    /// per group, so the baseline pays no per-element integer division —
    /// with the per-group unpack/scale vectorized via
    /// `simd::dequant_group` (element-wise, so the lane path is
    /// trivially bit-identical to scalar).
    fn dequant_to(&self, dq: &mut Vec<f32>, t: &mut Traffic) {
        t.kernel_launches += 1;
        t.bytes_read += self.code_bytes() + self.meta_bytes();
        t.weight_bytes += self.code_bytes() + self.meta_bytes();
        t.bytes_written += 4 * (self.out * self.cin) as u64;
        dq.clear();
        dq.resize(self.out * self.cin, 0.0);
        let ngroups = self.cin / self.group;
        let words_per_group = self.group / 8;
        let words_per_row = self.cin / 8;
        let path = simd::active();
        for o in 0..self.out {
            let row_words = &self.packed[o * words_per_row..(o + 1) * words_per_row];
            if o + 1 < self.out {
                let next = &self.packed[(o + 1) * words_per_row..(o + 2) * words_per_row];
                simd::prefetch_words(next);
            }
            let drow = &mut dq[o * self.cin..(o + 1) * self.cin];
            for g in 0..ngroups {
                let scale = self.scales[o * ngroups + g];
                let zero = self.zeros[o * ngroups + g];
                simd::dequant_group(
                    &row_words[g * words_per_group..(g + 1) * words_per_group],
                    scale,
                    zero,
                    &mut drow[g * self.group..(g + 1) * self.group],
                    path,
                );
            }
        }
    }

    /// Weight-stationary batched decode GEMV: `xs [m, cin]` → `ys [m, out]`,
    /// one slot activation per row.
    ///
    /// Unlike [`QuantLinear::gemm`] (which materializes a dequantized tile
    /// for the compute-bound prefill shape), this streams the packed codes
    /// exactly once per call: each packed word is unpacked while hot and
    /// applied to all `m` rows via the per-group partial-sum identity, so
    /// [`Traffic`] charges codes/scales (and sub-branch A/B) once per step
    /// and only the activations `m` times. Row `i` performs bit-identical
    /// float operations to `gemv(&xs[i*cin..], ..)` — batched and
    /// sequential decode produce identical logits.
    ///
    /// Output rows are fanned out over the persistent worker pool when
    /// the call is large enough (`FBQ_THREADS` workers, see
    /// [`crate::util::pool`]); each output element is still computed by
    /// exactly one worker with the same operation order, so threading
    /// never changes results.
    pub fn gemv_multi(
        &self,
        xs: &[f32],
        m: usize,
        ys: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        debug_assert_eq!(xs.len(), m * self.cin);
        debug_assert_eq!(ys.len(), m * self.out);
        if m == 1 {
            // trivially weight-stationary already
            return self.gemv(xs, ys, mode, ws, t);
        }
        let Workspace { dequant, xa, xs: xsb, xsum, ytile, .. } = ws;
        // optional AWQ column scaling, applied once per row
        let xs: &[f32] = match &self.col_scale {
            None => xs,
            Some(cs) => {
                xsb.clear();
                xsb.reserve(m * self.cin);
                for i in 0..m {
                    xsb.extend(
                        xs[i * self.cin..(i + 1) * self.cin]
                            .iter()
                            .zip(cs)
                            .map(|(xi, ci)| xi * ci),
                    );
                }
                xsb
            }
        };
        match mode {
            SubMode::None => {
                self.gemv_main_fused_multi(xs, m, ys, xsum, ytile, t);
            }
            SubMode::Fused => {
                let has_sub = self.compute_xa_multi(xs, m, xa, t);
                self.gemv_main_fused_multi(xs, m, ys, xsum, ytile, t);
                if has_sub {
                    self.add_up_projection_multi(xa, m, ys, t);
                }
            }
            SubMode::Unfused => {
                // batch-amortized unfused pipeline: one materialization,
                // then dense GEMVs from the scratch for every row
                self.dequant_to(dequant, t);
                t.kernel_launches += 1;
                t.bytes_read += 4 * (self.out * self.cin + m * self.cin) as u64;
                t.bytes_written += 4 * (m * self.out) as u64;
                t.macs += (m * self.out * self.cin) as u64;
                // row-outer so the scratch row really streams once
                for o in 0..self.out {
                    let drow = &dequant[o * self.cin..(o + 1) * self.cin];
                    for i in 0..m {
                        ys[i * self.out + o] =
                            crate::tensor::ops::dot(&xs[i * self.cin..(i + 1) * self.cin], drow);
                    }
                }
                let has_sub = self.compute_xa_multi(xs, m, xa, t);
                if has_sub {
                    t.kernel_launches += 1;
                    t.bytes_read +=
                        4 * (m * self.out + self.out * self.rank + m * self.rank) as u64;
                    t.weight_bytes += 4 * (self.out * self.rank) as u64;
                    t.bytes_written += 4 * (m * self.out) as u64;
                    t.macs += (m * self.out * self.rank) as u64;
                    let b = self.b.as_ref().unwrap();
                    for o in 0..self.out {
                        let brow = &b[o * self.rank..(o + 1) * self.rank];
                        for i in 0..m {
                            ys[i * self.out + o] += crate::tensor::ops::dot(
                                &xa[i * self.rank..(i + 1) * self.rank],
                                brow,
                            );
                        }
                    }
                }
            }
        }
        if let Some(bias) = &self.bias {
            for i in 0..m {
                for (yi, bi) in ys[i * self.out..(i + 1) * self.out].iter_mut().zip(bias) {
                    *yi += bi;
                }
            }
        }
    }

    /// Multi-activation fused main path. Codes/scales stream once; the
    /// row loop optionally fans out over the thread pool.
    fn gemv_main_fused_multi(
        &self,
        xs: &[f32],
        m: usize,
        ys: &mut [f32],
        xsum: &mut Vec<f32>,
        ytile: &mut Vec<f32>,
        t: &mut Traffic,
    ) {
        t.kernel_launches += 1;
        t.bytes_read += self.code_bytes() + self.meta_bytes() + 4 * (m * self.cin) as u64;
        t.weight_bytes += self.code_bytes() + self.meta_bytes();
        t.bytes_written += 4 * (m * self.out) as u64;
        t.macs += (m * self.out * self.cin) as u64;
        let ngroups = self.cin / self.group;
        // per-(slot, group) Σx, shared across all output rows
        xsum.clear();
        xsum.resize(m * ngroups, 0.0);
        for i in 0..m {
            for g in 0..ngroups {
                xsum[i * ngroups + g] = xs
                    [i * self.cin + g * self.group..i * self.cin + (g + 1) * self.group]
                    .iter()
                    .sum();
            }
        }
        let threads = plan_threads(m * self.out * self.cin);
        let xsum: &[f32] = xsum;
        row_parallel(self.out, m, threads, ytile, ys, |lo, hi, tile| {
            self.fused_rows_multi(xs, m, lo, hi, xsum, tile);
        });
    }

    /// Weight-stationary inner kernel over output rows `lo..hi`: unpack
    /// each packed word once per activation row while the word is hot in
    /// cache, accumulating in the crate-wide canonical lane order
    /// (`tensor::simd`): per word, code `j` multiplies lane `j` into an
    /// independent accumulator (no FMA), and each row's eight lanes
    /// reduce through the fixed `simd::reduce8` tree at group end. The
    /// scalar and AVX2/NEON paths of `simd::accum_group` perform those
    /// float ops identically, so the lane path never changes results —
    /// per activation row the operation order matches
    /// [`QuantLinear::gemv_main_fused`] (its `m = 1` case) exactly.
    /// `tile` is `[hi-lo, m]` row-major. The next row's packed words are
    /// software-prefetched while the current row computes.
    fn fused_rows_multi(
        &self,
        xs: &[f32],
        m: usize,
        lo: usize,
        hi: usize,
        xsum: &[f32],
        tile: &mut [f32],
    ) {
        let ngroups = self.cin / self.group;
        let words_per_group = self.group / 8;
        let words_per_row = self.cin / 8;
        let path = simd::active();
        // per-row scratch: stack for realistic slot counts, heap beyond
        // (the hot loop stays allocation-free up to 16 slots)
        const STACK_M: usize = 16;
        let mut lanes_arr = [0f32; 8 * STACK_M];
        let mut acc_arr = [0f32; STACK_M];
        let mut lanes_vec = Vec::new();
        let mut acc_vec = Vec::new();
        let (lanes, acc): (&mut [f32], &mut [f32]) = if m <= STACK_M {
            (&mut lanes_arr[..8 * m], &mut acc_arr[..m])
        } else {
            lanes_vec.resize(8 * m, 0.0);
            acc_vec.resize(m, 0.0);
            (&mut lanes_vec[..], &mut acc_vec[..])
        };
        for o in lo..hi {
            let row_words = &self.packed[o * words_per_row..(o + 1) * words_per_row];
            if o + 1 < hi {
                let next = &self.packed[(o + 1) * words_per_row..(o + 2) * words_per_row];
                simd::prefetch_words(next);
            }
            acc.iter_mut().for_each(|v| *v = 0.0);
            for g in 0..ngroups {
                let scale = self.scales[o * ngroups + g];
                let zero = self.zeros[o * ngroups + g];
                lanes.iter_mut().for_each(|v| *v = 0.0);
                simd::accum_group(
                    &row_words[g * words_per_group..(g + 1) * words_per_group],
                    xs,
                    m,
                    self.cin,
                    g * self.group,
                    lanes,
                    path,
                );
                for i in 0..m {
                    let s1 = simd::reduce8(&lanes[i * 8..i * 8 + 8]);
                    acc[i] += scale * (s1 - zero * xsum[i * ngroups + g]);
                }
            }
            tile[(o - lo) * m..(o - lo + 1) * m].copy_from_slice(&*acc);
        }
    }

    /// xa `[m, rank]` = A·xᵢ for every row (A streams once).
    fn compute_xa_multi(&self, xs: &[f32], m: usize, xa: &mut Vec<f32>, t: &mut Traffic) -> bool {
        let Some(a) = &self.a else { return false };
        if self.b.is_none() {
            return false;
        }
        t.kernel_launches += 1;
        t.bytes_read += 4 * (self.rank * self.cin + m * self.cin) as u64;
        t.weight_bytes += 4 * (self.rank * self.cin) as u64;
        t.bytes_written += 4 * (m * self.rank) as u64;
        t.macs += (m * self.rank * self.cin) as u64;
        xa.clear();
        xa.resize(m * self.rank, 0.0);
        // A-row outer: each row of A is read once for all m activations
        for r in 0..self.rank {
            let arow = &a[r * self.cin..(r + 1) * self.cin];
            for i in 0..m {
                xa[i * self.rank + r] =
                    crate::tensor::ops::dot(&xs[i * self.cin..(i + 1) * self.cin], arow);
            }
        }
        true
    }

    /// Fused multi-row up-projection: B streams once for all `m` rows.
    fn add_up_projection_multi(&self, xa: &[f32], m: usize, ys: &mut [f32], t: &mut Traffic) {
        let b = self.b.as_ref().unwrap();
        t.bytes_read += 4 * (self.out * self.rank) as u64;
        t.weight_bytes += 4 * (self.out * self.rank) as u64;
        t.macs += (m * self.out * self.rank) as u64;
        for o in 0..self.out {
            let brow = &b[o * self.rank..(o + 1) * self.rank];
            for i in 0..m {
                ys[i * self.out + o] +=
                    crate::tensor::ops::dot(&xa[i * self.rank..(i + 1) * self.rank], brow);
            }
        }
    }

    /// GEMM variant for prefill: x `[m, cin]` → y `[m, out]`.
    ///
    /// Fused: each weight row is de-quantized once into a stack tile and
    /// reused across all m activation rows (the VMEM-tile analogue);
    /// un-fused: full materialization then dense GEMM + two extra passes.
    pub fn gemm(
        &self,
        x: &[f32],
        m: usize,
        y: &mut [f32],
        mode: SubMode,
        ws: &mut Workspace,
        t: &mut Traffic,
    ) {
        debug_assert_eq!(x.len(), m * self.cin);
        debug_assert_eq!(y.len(), m * self.out);
        if m == 1 {
            // decode shape: take the single-pass GEMV path (the GEMM path
            // would materialize the whole weight matrix per token)
            return self.gemv(x, y, mode, ws, t);
        }
        let Workspace { dequant, xa: xa_buf, xs, bt, .. } = ws;
        // column scaling applied once to the whole block
        let xbuf: &[f32] = match &self.col_scale {
            None => x,
            Some(cs) => {
                xs.clear();
                xs.reserve(m * self.cin);
                for i in 0..m {
                    xs.extend(
                        x[i * self.cin..(i + 1) * self.cin].iter().zip(cs).map(|(xi, ci)| xi * ci),
                    );
                }
                xs
            }
        };
        // Main path (all modes): the weight tile is de-quantized into a
        // cache-resident scratch and consumed by a dense GEMM. At prefill
        // the matmul is compute-bound on this scalar CPU, so the fusion
        // story plays out in the *sub-branch* handling below (and in the
        // traffic counters, which model the device-level difference: the
        // fused kernel keeps the tile in VMEM/registers and never
        // round-trips the output).
        self.dequant_to(dequant, t);
        if mode == SubMode::Unfused {
            // materialization charged as a real kernel with HBM round-trip
            t.kernel_launches += 1;
            t.bytes_read += 4 * (self.out * self.cin + m * self.cin) as u64;
            t.bytes_written += 4 * (m * self.out) as u64;
        } else {
            // fused accounting: the dequant pass above charged a
            // materialization; rebate it to model the in-register tile
            t.kernel_launches -= 1;
            t.bytes_written -= 4 * (self.out * self.cin) as u64;
            t.kernel_launches += 1;
            t.bytes_read += 4 * (m * self.cin) as u64;
            t.bytes_written += 4 * (m * self.out) as u64;
        }
        t.macs += (m * self.out * self.cin) as u64;
        crate::tensor::ops::matmul_t(xbuf, dequant, y, m, self.cin, self.out);

        let has_sub = matches!(mode, SubMode::Fused | SubMode::Unfused)
            && self.a.is_some()
            && self.b.is_some();
        if has_sub {
            let has = self.compute_xa_gemm(xbuf, m, xa_buf, t);
            if has {
                let b = self.b.as_ref().unwrap();
                if mode == SubMode::Unfused {
                    // separate up-projection kernel: y round-trips memory
                    t.kernel_launches += 1;
                    t.bytes_read +=
                        4 * (m * self.out + self.out * self.rank + m * self.rank) as u64;
                    t.bytes_written += 4 * (m * self.out) as u64;
                } else {
                    // fused into the main kernel's accumulator tile
                    t.bytes_read += 4 * (self.out * self.rank) as u64;
                }
                t.weight_bytes += 4 * (self.out * self.rank) as u64;
                t.macs += (m * self.out * self.rank) as u64;
                transpose_b(b, self.out, self.rank, bt);
                for i in 0..m {
                    let xa = &xa_buf[i * self.rank..(i + 1) * self.rank];
                    let yi = &mut y[i * self.out..(i + 1) * self.out];
                    for r in 0..self.rank {
                        crate::tensor::ops::axpy(xa[r], &bt[r * self.out..(r + 1) * self.out], yi);
                    }
                }
            }
        }
        if let Some(bias) = &self.bias {
            for i in 0..m {
                for (yi, bi) in y[i * self.out..(i + 1) * self.out].iter_mut().zip(bias) {
                    *yi += bi;
                }
            }
        }
    }

    fn compute_xa_gemm(&self, x: &[f32], m: usize, xa: &mut Vec<f32>, t: &mut Traffic) -> bool {
        let Some(a) = &self.a else { return false };
        if self.b.is_none() {
            return false;
        }
        t.kernel_launches += 1;
        t.bytes_read += 4 * (self.rank * self.cin + m * self.cin) as u64;
        t.weight_bytes += 4 * (self.rank * self.cin) as u64;
        t.bytes_written += 4 * (m * self.rank) as u64;
        t.macs += (m * self.rank * self.cin) as u64;
        xa.clear();
        xa.resize(m * self.rank, 0.0);
        for i in 0..m {
            let xi = &x[i * self.cin..(i + 1) * self.cin];
            for r in 0..self.rank {
                let arow = &a[r * self.cin..(r + 1) * self.cin];
                xa[i * self.rank + r] = crate::tensor::ops::dot(xi, arow);
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::groupwise;
    use crate::quant::pack::pack_codes;
    use crate::util::Pcg64;

    fn make_layer(rng: &mut Pcg64, out: usize, cin: usize, rank: usize, bits: u8, group: usize,
                  col_scale: bool) -> (QuantLinear, Vec<f32>) {
        let w: Vec<f32> = (0..out * cin).map(|_| rng.normal() as f32 * 0.5).collect();
        let p = groupwise::quant_params(&w, out, cin, bits, group);
        let codes = groupwise::quantize(&w, out, cin, &p);
        let a: Vec<f32> = (0..rank * cin).map(|_| rng.normal() as f32 * 0.05).collect();
        let b: Vec<f32> = (0..out * rank).map(|_| rng.normal() as f32 * 0.05).collect();
        let cs: Option<Vec<f32>> = col_scale
            .then(|| (0..cin).map(|_| 0.5 + rng.next_f32()).collect());
        let ql = QuantLinear {
            out,
            cin,
            bits,
            group,
            packed: pack_codes(&codes, out, cin),
            scales: p.scales.clone(),
            zeros: p.zeros.clone(),
            rank,
            a: Some(a.clone()),
            b: Some(b.clone()),
            col_scale: cs.clone(),
            bias: None,
        };
        // reference effective weight
        let mut wd = groupwise::dequantize(&codes, out, cin, &p);
        for o in 0..out {
            for c in 0..cin {
                let mut s = 0f32;
                for r in 0..rank {
                    s += b[o * rank + r] * a[r * cin + c];
                }
                wd[o * cin + c] += s;
                if let Some(cs) = &cs {
                    wd[o * cin + c] *= cs[c];
                }
            }
        }
        (ql, wd)
    }

    #[test]
    fn fused_unfused_agree_with_dense() {
        let mut rng = Pcg64::seeded(41);
        for &(out, cin, rank, cs) in
            &[(16usize, 32usize, 4usize, false), (24, 64, 8, true), (8, 128, 0, false)]
        {
            let (mut ql, wd) = make_layer(&mut rng, out, cin, rank, 4, 16, cs);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            let x: Vec<f32> = (0..cin).map(|_| rng.normal() as f32).collect();
            let want: Vec<f32> = (0..out)
                .map(|o| crate::tensor::ops::dot(&x, &wd[o * cin..(o + 1) * cin]))
                .collect();
            let mut ws = Workspace::default();
            let mut t = Traffic::default();
            for mode in [SubMode::Fused, SubMode::Unfused] {
                let mut y = vec![0f32; out];
                ql.gemv(&x, &mut y, mode, &mut ws, &mut t);
                for o in 0..out {
                    let (got, exp) = (y[o], want[o]);
                    assert!((got - exp).abs() < 1e-3, "{mode:?} o={o}: {got} vs {exp}");
                }
            }
            // SubMode::None drops the sub-branch
            let mut y = vec![0f32; out];
            ql.gemv(&x, &mut y, SubMode::None, &mut ws, &mut t);
            if rank > 0 {
                let diff: f32 = y.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
                assert!(diff > 0.0);
            }
        }
    }

    #[test]
    fn gemm_matches_gemv() {
        let mut rng = Pcg64::seeded(42);
        let (ql, _) = make_layer(&mut rng, 24, 64, 8, 4, 16, true);
        let m = 5;
        let x: Vec<f32> = (0..m * 64).map(|_| rng.normal() as f32).collect();
        let mut ws = Workspace::default();
        let mut t = Traffic::default();
        for mode in [SubMode::None, SubMode::Fused, SubMode::Unfused] {
            let mut yg = vec![0f32; m * 24];
            ql.gemm(&x, m, &mut yg, mode, &mut ws, &mut t);
            for i in 0..m {
                let mut yv = vec![0f32; 24];
                ql.gemv(&x[i * 64..(i + 1) * 64], &mut yv, mode, &mut ws, &mut t);
                for o in 0..24 {
                    assert!((yg[i * 24 + o] - yv[o]).abs() < 1e-3, "{mode:?}");
                }
            }
        }
    }

    #[test]
    fn traffic_fused_less_than_unfused() {
        let mut rng = Pcg64::seeded(43);
        let (ql, _) = make_layer(&mut rng, 128, 128, 16, 4, 32, false);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let mut ws = Workspace::default();
        let mut y = vec![0f32; 128];

        let mut tf = Traffic::default();
        ql.gemv(&x, &mut y, SubMode::Fused, &mut ws, &mut tf);
        let mut tu = Traffic::default();
        ql.gemv(&x, &mut y, SubMode::Unfused, &mut ws, &mut tu);

        assert!(tf.total_bytes() < tu.total_bytes(),
                "fused {} !< unfused {}", tf.total_bytes(), tu.total_bytes());
        assert_eq!(tf.kernel_launches, 2);
        assert_eq!(tu.kernel_launches, 4);
        assert_eq!(tf.macs, tu.macs); // fusion changes traffic, not math
    }

    #[test]
    fn gemv_multi_is_bitwise_identical_to_per_row_gemv() {
        let mut rng = Pcg64::seeded(45);
        for &(out, cin, rank, cs) in
            &[(24usize, 64usize, 8usize, true), (16, 32, 4, false), (8, 64, 0, false)]
        {
            let (mut ql, _) = make_layer(&mut rng, out, cin, rank, 4, 16, cs);
            if rank == 0 {
                ql.a = None;
                ql.b = None;
                ql.rank = 0;
            }
            let m = 5usize;
            let xs: Vec<f32> = (0..m * cin).map(|_| rng.normal() as f32).collect();
            let mut ws = Workspace::default();
            let mut t = Traffic::default();
            for mode in [SubMode::None, SubMode::Fused, SubMode::Unfused] {
                let mut ym = vec![0f32; m * out];
                ql.gemv_multi(&xs, m, &mut ym, mode, &mut ws, &mut t);
                for i in 0..m {
                    let mut yv = vec![0f32; out];
                    ql.gemv(&xs[i * cin..(i + 1) * cin], &mut yv, mode, &mut ws, &mut t);
                    assert_eq!(
                        &ym[i * out..(i + 1) * out],
                        &yv[..],
                        "{mode:?} row {i}: batched decode must be bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn gemv_multi_weight_traffic_is_slot_independent() {
        let mut rng = Pcg64::seeded(46);
        let (ql, _) = make_layer(&mut rng, 128, 128, 16, 4, 32, false);
        let mut ws = Workspace::default();
        let weight_bytes_at = |m: usize, ws: &mut Workspace| -> Traffic {
            let xs: Vec<f32> = (0..m * 128).map(|i| (i % 7) as f32 * 0.1).collect();
            let mut ys = vec![0f32; m * 128];
            let mut t = Traffic::default();
            ql.gemv_multi(&xs, m, &mut ys, SubMode::Fused, ws, &mut t);
            t
        };
        let t1 = weight_bytes_at(1, &mut ws);
        let t3 = weight_bytes_at(3, &mut ws);
        let t8 = weight_bytes_at(8, &mut ws);
        assert_eq!(t1.weight_bytes, t3.weight_bytes, "weight traffic must not scale with slots");
        assert_eq!(t1.weight_bytes, t8.weight_bytes, "weight traffic must not scale with slots");

        // the sequential baseline re-streams the weights per slot
        let mut tseq = Traffic::default();
        let xs: Vec<f32> = (0..8 * 128).map(|i| (i % 7) as f32 * 0.1).collect();
        for i in 0..8 {
            let mut y = vec![0f32; 128];
            ql.gemv(&xs[i * 128..(i + 1) * 128], &mut y, SubMode::Fused, &mut ws, &mut tseq);
        }
        assert_eq!(tseq.weight_bytes, 8 * t8.weight_bytes);
        assert!(
            tseq.bytes_read as f64 >= 4.0 * t8.bytes_read as f64,
            "batched decode must cut per-step read traffic >=4x at m=8 \
             (sequential {} vs batched {})",
            tseq.bytes_read,
            t8.bytes_read
        );
    }

    #[test]
    fn gemv_multi_above_parallel_floor_stays_exact() {
        // 8 * 512 * 1024 MACs crosses even the maximum parallel floor, so
        // with >1 available cores this exercises the pool fan-out path;
        // results must stay bit-identical to the per-row kernel either way.
        let mut rng = Pcg64::seeded(47);
        let (ql, _) = make_layer(&mut rng, 512, 1024, 16, 4, 128, false);
        let m = 8usize;
        let xs: Vec<f32> = (0..m * 1024).map(|_| rng.normal() as f32).collect();
        let mut ws = Workspace::default();
        let mut t = Traffic::default();
        let mut ym = vec![0f32; m * 512];
        ql.gemv_multi(&xs, m, &mut ym, SubMode::Fused, &mut ws, &mut t);
        for i in 0..m {
            let mut yv = vec![0f32; 512];
            ql.gemv(&xs[i * 1024..(i + 1) * 1024], &mut yv, SubMode::Fused, &mut ws, &mut t);
            assert_eq!(&ym[i * 512..(i + 1) * 512], &yv[..], "row {i}");
        }
    }

    #[test]
    fn plan_threads_ramp_is_monotone_and_honors_floor_and_width() {
        let floor = 1 << 18;
        for threads in [1usize, 2, 4, 8, 16] {
            let mut prev = 0usize;
            for shift in 10..=26 {
                let macs = 1usize << shift;
                let t = plan_threads_with(macs, floor, threads);
                assert!(t >= 1 && t <= threads.max(1), "macs {macs} threads {threads} -> {t}");
                assert!(t >= prev, "thread count must be monotone in MACs ({prev} -> {t})");
                prev = t;
            }
            if threads > 1 {
                assert_eq!(plan_threads_with(floor - 1, floor, threads), 1, "below floor = serial");
                assert!(plan_threads_with(floor, floor, threads) >= 2, "at floor fans out");
                assert_eq!(
                    plan_threads_with(floor * threads * 4, floor, threads),
                    threads,
                    "large calls saturate the pool width"
                );
            }
        }
        // FBQ_THREADS=0/1 semantics: serial no matter the work size
        assert_eq!(plan_threads_with(usize::MAX / 2, floor, 1), 1);
        // the derived floor is always inside the clamp (or env-pinned)
        if std::env::var("FBQ_PAR_FLOOR").is_err() {
            let f = par_floor_macs();
            assert!((PAR_FLOOR_MIN_MACS..=PAR_FLOOR_MAX_MACS).contains(&f), "floor {f}");
        }
    }

    #[test]
    fn row_parallel_conserves_rows_in_both_dispatch_modes() {
        use crate::util::pool::{force_dispatch, Dispatch};
        let mut rng = Pcg64::seeded(48);
        for mode in [Dispatch::Pool, Dispatch::Scoped] {
            for _ in 0..6 {
                let n_rows = 1 + rng.below(97);
                let m = 1 + rng.below(5);
                let threads = 1 + rng.below(9); // includes serial and oversubscribed
                let mut ytile = Vec::new();
                let mut ys = vec![0f32; m * n_rows];
                force_dispatch(Some(mode));
                row_parallel(n_rows, m, threads, &mut ytile, &mut ys, |lo, hi, tile| {
                    for r in lo..hi {
                        for i in 0..m {
                            tile[(r - lo) * m + i] += (r * 10 + i) as f32 + 1.0;
                        }
                    }
                });
                force_dispatch(None);
                for r in 0..n_rows {
                    for i in 0..m {
                        assert_eq!(
                            ys[i * n_rows + r],
                            (r * 10 + i) as f32 + 1.0,
                            "{mode:?} n={n_rows} m={m} t={threads}: row {r} slot {i} \
                             written zero or multiple times"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn row_parallel_panicking_fill_surfaces_error_and_recovers() {
        let mut ytile = Vec::new();
        let mut ys = vec![0f32; 64];
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            row_parallel(64, 1, 4, &mut ytile, &mut ys, |lo, _hi, _tile| {
                if lo > 0 {
                    panic!("poisoned worker chunk at {lo}");
                }
            });
        }));
        assert!(res.is_err(), "worker panic must surface, not deadlock");
        // the pool survives: the same call without the panic completes
        let mut ytile = Vec::new();
        let mut ys = vec![0f32; 64];
        row_parallel(64, 1, 4, &mut ytile, &mut ys, |lo, hi, tile| {
            for r in lo..hi {
                tile[r - lo] = r as f32;
            }
        });
        for (r, v) in ys.iter().enumerate() {
            assert_eq!(*v, r as f32);
        }
    }

    #[test]
    fn split_rows_covers_exactly_once() {
        for (n, parts) in [(10usize, 3usize), (1, 8), (16, 16), (7, 2), (0, 4), (5, 1)] {
            let chunks = split_rows(n, parts);
            let mut covered = 0;
            let mut expect_start = 0;
            for &(lo, hi) in &chunks {
                assert_eq!(lo, expect_start, "chunks must be contiguous");
                assert!(hi > lo, "empty chunk");
                covered += hi - lo;
                expect_start = hi;
            }
            assert_eq!(covered, n, "split_rows({n}, {parts}) lost rows");
            assert!(chunks.len() <= parts.max(1));
        }
    }

    #[test]
    fn bits_affect_logical_code_bytes() {
        let mut rng = Pcg64::seeded(44);
        let (ql4, _) = make_layer(&mut rng, 16, 64, 0, 4, 16, false);
        let (ql3, _) = make_layer(&mut rng, 16, 64, 0, 3, 16, false);
        assert_eq!(ql4.code_bytes(), 16 * 64 / 2);
        assert_eq!(ql3.code_bytes(), 16 * 64 * 3 / 8);
    }
}
