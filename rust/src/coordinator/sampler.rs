//! Token sampling: greedy, temperature and top-k over logits.

use super::request::SamplingParams;
use crate::tensor::ops;
use crate::util::Pcg64;

#[derive(Debug)]
pub struct Sampler {
    rng: Pcg64,
}

impl Sampler {
    pub fn new(seed: u64) -> Sampler {
        Sampler { rng: Pcg64::seeded(seed) }
    }

    pub fn sample(&mut self, logits: &[f32], p: &SamplingParams) -> u32 {
        if p.temperature <= 0.0 {
            return ops::argmax(logits) as u32;
        }
        // temperature scaling on a (possibly top-k-restricted) candidate set
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if p.top_k > 0 && p.top_k < logits.len() {
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(p.top_k);
        }
        let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - m) / p.temperature) as f64).exp())
            .collect();
        idx[self.rng.weighted(&weights)] as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let p = SamplingParams { temperature: 0.0, top_k: 0, seed: 0 };
        for _ in 0..5 {
            assert_eq!(s.sample(&logits, &p), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1);
        let logits = vec![5.0, 4.9, -100.0, -100.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, seed: 0 };
        for _ in 0..200 {
            let t = s.sample(&logits, &p);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        let mut s = Sampler::new(2);
        let logits = vec![1.0, 0.8, 0.6, 0.4];
        let hot = SamplingParams { temperature: 5.0, top_k: 0, seed: 0 };
        let mut seen = [0usize; 4];
        for _ in 0..400 {
            seen[s.sample(&logits, &hot) as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 20), "{seen:?}");
    }
}
