//! Token sampling: greedy, temperature, top-k and top-p (nucleus) over
//! logits.
//!
//! [`distribution`] is the single source of truth for what "sampling
//! with these params" means: it maps a logits row to the full-vocab
//! probability vector (temperature-scaled softmax restricted to the
//! top-k / top-p candidate set, renormalized). [`Sampler::sample`] draws
//! from exactly that vector, and the stochastic speculative path
//! (`crate::spec::accept`) builds its target/draft distributions through
//! the same function — which is what makes rejection-sampling acceptance
//! provably distribution-preserving: both sides of the `p/q` ratio come
//! from one definition.

use super::request::SamplingParams;
use crate::tensor::ops;
use crate::util::Pcg64;

/// The full-vocab sampling distribution for `logits` under `p`
/// (`p.temperature > 0`): temperature-scaled softmax over the top-k
/// candidate set (all tokens when `top_k == 0`), then restricted to the
/// smallest descending-probability prefix reaching `top_p` mass and
/// renormalized. Entries outside the candidate set are exactly `0.0`.
/// Computed in f64 so the speculative accept ratios are stable.
pub fn distribution(logits: &[f32], p: &SamplingParams) -> Vec<f64> {
    debug_assert!(p.temperature > 0.0, "distribution of a greedy request");
    let n = logits.len();
    let mut idx: Vec<usize> = (0..n).collect();
    let truncate_k = p.top_k > 0 && p.top_k < n;
    if truncate_k || p.top_p < 1.0 {
        // stable sort: equal logits keep ascending token order, so the
        // candidate set is deterministic
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    }
    if truncate_k {
        idx.truncate(p.top_k);
    }
    let m = idx.iter().map(|&i| logits[i] as f64).fold(f64::NEG_INFINITY, f64::max);
    let mut w: Vec<f64> =
        idx.iter().map(|&i| ((logits[i] as f64 - m) / p.temperature as f64).exp()).collect();
    let total: f64 = w.iter().sum();
    for v in w.iter_mut() {
        *v /= total;
    }
    if p.top_p < 1.0 {
        // idx is descending by logit, hence descending by probability:
        // keep the smallest prefix reaching the nucleus mass
        let mut cum = 0.0;
        let mut keep = w.len();
        for (j, &wv) in w.iter().enumerate() {
            cum += wv;
            if cum >= p.top_p as f64 {
                keep = j + 1;
                break;
            }
        }
        idx.truncate(keep);
        w.truncate(keep);
        let kept: f64 = w.iter().sum();
        for v in w.iter_mut() {
            *v /= kept;
        }
    }
    let mut probs = vec![0f64; n];
    for (&i, &wv) in idx.iter().zip(&w) {
        probs[i] = wv;
    }
    probs
}

/// Draw an index from a (possibly unnormalized) non-negative probability
/// vector. Zero-probability entries are never returned.
pub fn draw_from(rng: &mut Pcg64, probs: &[f64]) -> u32 {
    let total: f64 = probs.iter().sum();
    debug_assert!(total > 0.0, "drawing from an empty distribution");
    let mut t = rng.next_f64() * total;
    let mut last = 0usize;
    for (i, &w) in probs.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        last = i;
        t -= w;
        if t <= 0.0 {
            return i as u32;
        }
    }
    // float round-off on the cumulative walk: the last supported index
    last as u32
}

#[derive(Debug)]
pub struct Sampler {
    rng: Pcg64,
}

impl Sampler {
    pub fn new(seed: u64) -> Sampler {
        Sampler { rng: Pcg64::seeded(seed) }
    }

    pub fn sample(&mut self, logits: &[f32], p: &SamplingParams) -> u32 {
        if !p.is_sampled() {
            return ops::argmax(logits) as u32;
        }
        let probs = distribution(logits, p);
        draw_from(&mut self.rng, &probs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let p = SamplingParams { temperature: 0.0, ..SamplingParams::default() };
        for _ in 0..5 {
            assert_eq!(s.sample(&logits, &p), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1);
        let logits = vec![5.0, 4.9, -100.0, -100.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, ..SamplingParams::default() };
        for _ in 0..200 {
            let t = s.sample(&logits, &p);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        let mut s = Sampler::new(2);
        let logits = vec![1.0, 0.8, 0.6, 0.4];
        let hot = SamplingParams { temperature: 5.0, ..SamplingParams::default() };
        let mut seen = [0usize; 4];
        for _ in 0..400 {
            seen[s.sample(&logits, &hot) as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 20), "{seen:?}");
    }

    #[test]
    fn distribution_is_normalized_and_top_p_truncates() {
        let logits = vec![2.0, 1.0, 0.0, -1.0];
        let full = SamplingParams { temperature: 1.0, ..SamplingParams::default() };
        let d = distribution(&logits, &full);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.windows(2).all(|w| w[0] > w[1]), "descending logits, descending probs");

        // top_p = 0.5: the head token alone carries ~0.64 mass, so the
        // nucleus is exactly {0}
        let narrow =
            SamplingParams { temperature: 1.0, top_p: 0.5, ..SamplingParams::default() };
        let d = distribution(&logits, &narrow);
        assert!((d[0] - 1.0).abs() < 1e-12, "{d:?}");
        assert!(d[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sample_matches_distribution_support() {
        let mut s = Sampler::new(3);
        let logits = vec![3.0, 2.9, 0.1, -5.0];
        let p = SamplingParams {
            temperature: 0.9,
            top_k: 3,
            top_p: 0.9,
            ..SamplingParams::default()
        };
        let d = distribution(&logits, &p);
        for _ in 0..300 {
            let t = s.sample(&logits, &p) as usize;
            assert!(d[t] > 0.0, "sampled outside the distribution's support");
        }
    }

    #[test]
    fn draw_from_respects_zero_mass() {
        let mut rng = Pcg64::seeded(9);
        let probs = vec![0.0, 0.3, 0.0, 0.7];
        for _ in 0..200 {
            let t = draw_from(&mut rng, &probs) as usize;
            assert!(t == 1 || t == 3);
        }
    }
}
