//! Prometheus text exposition (format 0.0.4) for [`ServeMetrics`].
//!
//! Rendered behind `GET /metrics?format=prometheus` alongside the JSON
//! snapshot. Counters/gauges carry `class`/`event`/`mode` labels; the
//! latency histograms reuse the log-bucket bounds of
//! [`crate::util::hist::Hist`] directly as `le` boundaries (converted to
//! seconds, per Prometheus convention). Only boundaries whose bucket is
//! non-empty are emitted (plus the mandatory `+Inf`), which keeps the
//! exposition compact and is valid: cumulative `_bucket` samples may list
//! any subset of boundaries.

use super::metrics::{ServeMetrics, PHASE_NAMES};
use super::request::Priority;
use crate::util::hist::{bucket_upper_us, Hist};

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{v}\""));
        }
        out.push('}');
    }
    if value.fract() == 0.0 && value.abs() < 9e15 {
        out.push_str(&format!(" {}\n", value as i64));
    } else {
        out.push_str(&format!(" {value}\n"));
    }
}

/// One histogram family whose series differ by a single label
/// (`label_key=label_val`). Bounds are emitted in seconds.
fn hist_family(out: &mut String, name: &str, help: &str, label_key: &str, series: &[(&str, &Hist)]) {
    header(out, name, help, "histogram");
    let bucket_name = format!("{name}_bucket");
    for (label_val, h) in series {
        let labels = [(label_key, *label_val)];
        let mut cum = 0u64;
        for (i, &n) in h.bucket_counts().iter().enumerate() {
            if n == 0 {
                continue;
            }
            cum += n;
            let le = bucket_upper_us(i);
            if le.is_finite() {
                let le_s = format!("{}", le / 1e6);
                sample(
                    out,
                    &bucket_name,
                    &[(label_key, label_val), ("le", le_s.as_str())],
                    cum as f64,
                );
            }
        }
        sample(
            out,
            &bucket_name,
            &[(label_key, label_val), ("le", "+Inf")],
            h.count() as f64,
        );
        sample(out, &format!("{name}_sum"), &labels, h.sum_us() / 1e6);
        sample(out, &format!("{name}_count"), &labels, h.count() as f64);
    }
}

/// Render the full exposition document.
pub fn render(m: &ServeMetrics) -> String {
    let mut out = String::with_capacity(8192);

    header(&mut out, "fbq_build_info", "Build metadata (value is always 1).", "gauge");
    sample(&mut out, "fbq_build_info", &[("version", env!("CARGO_PKG_VERSION"))], 1.0);

    header(&mut out, "fbq_uptime_seconds", "Seconds since the coordinator started.", "gauge");
    sample(&mut out, "fbq_uptime_seconds", &[], m.started.elapsed().as_secs_f64());

    header(&mut out, "fbq_requests_total", "Requests by lifecycle event.", "counter");
    for (event, v) in [
        ("in", m.requests_in),
        ("done", m.requests_done),
        ("shed", m.requests_shed),
        ("cancelled", m.cancellations),
    ] {
        sample(&mut out, "fbq_requests_total", &[("event", event)], v as f64);
    }

    header(&mut out, "fbq_tokens_total", "Tokens processed by kind.", "counter");
    for (kind, v) in [("prefilled", m.tokens_prefilled), ("generated", m.tokens_generated)] {
        sample(&mut out, "fbq_tokens_total", &[("kind", kind)], v as f64);
    }

    header(&mut out, "fbq_admissions_total", "Requests admitted into decode slots.", "counter");
    sample(&mut out, "fbq_admissions_total", &[], m.admissions as f64);

    header(&mut out, "fbq_decode_steps_total", "Batched decode steps executed.", "counter");
    sample(&mut out, "fbq_decode_steps_total", &[], m.decode_steps as f64);

    header(&mut out, "fbq_decode_tokens_per_second", "Decode throughput over the run.", "gauge");
    sample(&mut out, "fbq_decode_tokens_per_second", &[], m.decode_tps());

    header(
        &mut out,
        "fbq_slot_occupancy_mean",
        "Mean fraction of the slot pool occupied per decode step.",
        "gauge",
    );
    sample(&mut out, "fbq_slot_occupancy_mean", &[], m.mean_slot_occupancy());

    header(&mut out, "fbq_slots_peak_occupied", "Most slots ever simultaneously live.", "gauge");
    sample(&mut out, "fbq_slots_peak_occupied", &[], m.peak_occupied as f64);

    header(
        &mut out,
        "fbq_weight_bytes_total",
        "Decode-phase persistent-weight bytes streamed.",
        "counter",
    );
    sample(&mut out, "fbq_weight_bytes_total", &[], m.weight_bytes as f64);

    header(
        &mut out,
        "fbq_swapped_bytes_total",
        "Bytes moved through the KV parking buffer by preemptions.",
        "counter",
    );
    sample(&mut out, "fbq_swapped_bytes_total", &[], m.swapped_bytes as f64);

    header(&mut out, "fbq_parked_requests", "Requests currently swapped out.", "gauge");
    sample(&mut out, "fbq_parked_requests", &[], m.parked as f64);

    header(
        &mut out,
        "fbq_degrade_level",
        "Current load-adaptive degradation level (0 = none).",
        "gauge",
    );
    sample(&mut out, "fbq_degrade_level", &[], m.degrade_level as f64);

    header(
        &mut out,
        "fbq_class_events_total",
        "Per-priority-class lifecycle and overload events.",
        "counter",
    );
    for (i, c) in m.classes.iter().enumerate() {
        let class = Priority::from_index(i).name();
        for (event, v) in [
            ("submitted", c.submitted),
            ("done", c.done),
            ("shed", c.shed),
            ("preemptions", c.preemptions),
            ("resumes", c.resumes),
            ("degrades", c.degrades),
            ("restores", c.restores),
        ] {
            sample(
                &mut out,
                "fbq_class_events_total",
                &[("class", class), ("event", event)],
                v as f64,
            );
        }
    }

    header(
        &mut out,
        "fbq_spec_events_total",
        "Speculative decoding counters by acceptance mode.",
        "counter",
    );
    for (mode, s) in [("greedy", &m.spec_greedy), ("sampled", &m.spec_sampled)] {
        for (event, v) in [
            ("steps", s.steps),
            ("proposed", s.proposed),
            ("accepted", s.accepted),
            ("committed", s.committed),
        ] {
            sample(
                &mut out,
                "fbq_spec_events_total",
                &[("mode", mode), ("event", event)],
                v as f64,
            );
        }
    }

    if let Some(p) = &m.kv_pool {
        header(&mut out, "fbq_kv_pages_total", "KV pool page capacity.", "gauge");
        sample(&mut out, "fbq_kv_pages_total", &[], p.pages_total as f64);
        header(&mut out, "fbq_kv_pages_in_use", "KV pool pages currently in use.", "gauge");
        sample(&mut out, "fbq_kv_pages_in_use", &[], p.pages_in_use as f64);
        header(&mut out, "fbq_kv_prefix_lookups_total", "Prefix-cache lookups.", "counter");
        sample(&mut out, "fbq_kv_prefix_lookups_total", &[], p.prefix_lookups as f64);
        header(&mut out, "fbq_kv_prefix_hits_total", "Prefix-cache hits.", "counter");
        sample(&mut out, "fbq_kv_prefix_hits_total", &[], p.prefix_hits as f64);
        header(&mut out, "fbq_kv_cow_copies_total", "Copy-on-write page copies.", "counter");
        sample(&mut out, "fbq_kv_cow_copies_total", &[], p.cow_copies as f64);
        header(
            &mut out,
            "fbq_kv_pages_aliased_total",
            "Pages adopted by reference (draft mirrors aliasing target pages).",
            "counter",
        );
        sample(&mut out, "fbq_kv_pages_aliased_total", &[], p.pages_aliased as f64);
        header(&mut out, "fbq_kv_alloc_failures_total", "Failed KV page allocations.", "counter");
        sample(&mut out, "fbq_kv_alloc_failures_total", &[], p.alloc_failures as f64);
    }

    hist_family(
        &mut out,
        "fbq_latency_seconds",
        "Request latency distributions by kind.",
        "kind",
        &[
            ("admission_wait", &m.admission_wait),
            ("ttft", &m.ttft),
            ("itl", &m.itl),
            ("per_token", &m.per_token),
            ("e2e", &m.e2e),
        ],
    );

    let phase_series: Vec<(&str, &Hist)> =
        PHASE_NAMES.iter().copied().zip(m.phases.iter()).collect();
    hist_family(
        &mut out,
        "fbq_phase_seconds",
        "Per-phase decode latency distributions.",
        "phase",
        &phase_series,
    );

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::MetricPhase;

    /// Minimal exposition-syntax check: every line is a comment or
    /// `name[{labels}] value` with a parseable value.
    fn assert_valid_exposition(text: &str) {
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (metric, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad: {line}"));
            assert!(value.parse::<f64>().is_ok() || value == "+Inf", "bad value in: {line}");
            let name_end = metric.find('{').unwrap_or(metric.len());
            let name = &metric[..name_end];
            assert!(
                !name.is_empty()
                    && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                    && !name.starts_with(|c: char| c.is_ascii_digit()),
                "bad metric name in: {line}"
            );
            if name_end < metric.len() {
                assert!(metric.ends_with('}'), "unterminated labels in: {line}");
            }
        }
    }

    #[test]
    fn golden_exposition() {
        let mut m = ServeMetrics::new();
        m.requests_in = 5;
        m.requests_done = 3;
        m.requests_shed = 1;
        m.tokens_generated = 40;
        m.admissions = 4;
        m.degrade_level = 2;
        m.parked = 1;
        m.class(Priority::Interactive).submitted = 2;
        m.class(Priority::Batch).preemptions = 3;
        m.record_spec_step(false, 4, 3, 3);
        m.ttft.record_us(1500.0);
        m.ttft.record_us(2500.0);
        m.record_phase_us(MetricPhase::Verify, 300.0);
        let text = render(&m);
        assert_valid_exposition(&text);

        for needle in [
            "# TYPE fbq_requests_total counter",
            "fbq_requests_total{event=\"in\"} 5",
            "fbq_requests_total{event=\"done\"} 3",
            "fbq_tokens_total{kind=\"generated\"} 40",
            "fbq_degrade_level 2",
            "fbq_parked_requests 1",
            "fbq_class_events_total{class=\"interactive\",event=\"submitted\"} 2",
            "fbq_class_events_total{class=\"batch\",event=\"preemptions\"} 3",
            "fbq_spec_events_total{mode=\"greedy\",event=\"accepted\"} 3",
            "# TYPE fbq_latency_seconds histogram",
            "fbq_latency_seconds_bucket{kind=\"ttft\",le=\"+Inf\"} 2",
            "fbq_latency_seconds_count{kind=\"ttft\"} 2",
            "# TYPE fbq_phase_seconds histogram",
            "fbq_phase_seconds_bucket{phase=\"verify\",le=\"+Inf\"} 1",
            "fbq_phase_seconds_count{phase=\"verify\"} 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Histogram sum is in seconds.
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("fbq_latency_seconds_sum{kind=\"ttft\"}"))
            .expect("ttft sum line");
        let v: f64 = sum_line.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!((v - 0.004).abs() < 1e-9, "ttft sum {v} != 4ms");
        // Cumulative bucket counts are monotonically non-decreasing.
        let mut last = 0.0;
        for l in text.lines().filter(|l| {
            l.starts_with("fbq_latency_seconds_bucket{kind=\"ttft\"")
        }) {
            let v: f64 = l.rsplit_once(' ').unwrap().1.parse().unwrap();
            assert!(v >= last, "non-monotone buckets: {l}");
            last = v;
        }
        assert_eq!(last, 2.0);
    }

    #[test]
    fn empty_metrics_still_render_required_families() {
        let text = render(&ServeMetrics::new());
        assert_valid_exposition(&text);
        for fam in [
            "fbq_build_info",
            "fbq_uptime_seconds",
            "fbq_requests_total",
            "fbq_latency_seconds",
            "fbq_phase_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {fam} ")), "missing family {fam}");
        }
        // Empty histograms still expose +Inf/sum/count.
        assert!(text.contains("fbq_latency_seconds_bucket{kind=\"e2e\",le=\"+Inf\"} 0"));
    }
}
