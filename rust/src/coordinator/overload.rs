//! Load-adaptive degradation policy for the serving loop.
//!
//! Under pressure the coordinator trades decode quality/speed for
//! survival in three reversible steps, driven by one scalar pressure
//! signal in `[0, 1]` derived from live [`super::ServeMetrics`] inputs
//! (KV-pool page utilisation, queue depth, parked requests):
//!
//! * **L1 — cap speculative K.** Every speculative slot's draft window
//!   is clamped to [`DegradeConfig::k_cap`]; mirrors stay intact, so
//!   lifting the cap resumes full drafting exactly.
//! * **L2 — bare quantized branch.** The engine drops its sub-branch
//!   correction ([`crate::engine::native::SubMode::None`]): faster,
//!   coarser decode on the same weights and KV.
//! * **L3 — shadow-engine routing.** The lowest-class occupied slots
//!   route decode through a lower-bit shadow engine sharing the same KV
//!   geometry, freeing verifier bandwidth for higher classes.
//!
//! Each level subsumes the ones below it. Transitions are hysteretic —
//! a level is only left once pressure clears its entry threshold by
//! [`DegradeConfig::hysteresis`] — so an oscillating signal near a
//! threshold cannot flap the engine mode every step. The controller is
//! pure state-machine (no clocks, no randomness): the same pressure
//! trace always produces the same transition sequence, which is what
//! lets the soak test assert exact per-class degrade/restore counts.

/// Thresholds for the three degradation levels. Disabled by default:
/// exactness tests and calm deployments see the stock engine behaviour
/// unless a config opts in.
#[derive(Debug, Clone)]
pub struct DegradeConfig {
    /// master switch; when false the controller always reports level 0
    pub enabled: bool,
    /// pressure at which speculative K is capped (level 1)
    pub l1_pressure: f64,
    /// pressure at which the bare quantized branch engages (level 2)
    pub l2_pressure: f64,
    /// pressure at which shadow-engine routing engages (level 3)
    pub l3_pressure: f64,
    /// margin below a level's entry threshold required to leave it
    pub hysteresis: f64,
    /// speculative-K clamp applied at level 1 and above (0 = no drafting)
    pub k_cap: usize,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        DegradeConfig {
            enabled: false,
            l1_pressure: 0.70,
            l2_pressure: 0.85,
            l3_pressure: 0.95,
            hysteresis: 0.10,
            k_cap: 1,
        }
    }
}

impl DegradeConfig {
    /// Enabled with the default thresholds.
    pub fn enabled() -> DegradeConfig {
        DegradeConfig { enabled: true, ..DegradeConfig::default() }
    }

    /// Entry threshold of `level` (1..=3).
    fn threshold(&self, level: u8) -> f64 {
        match level {
            1 => self.l1_pressure,
            2 => self.l2_pressure,
            _ => self.l3_pressure,
        }
    }
}

/// Hysteretic three-level degradation state machine. Feed it the
/// current pressure once per scheduling step; it reports the level the
/// serving loop should be operating at.
#[derive(Debug, Clone)]
pub struct PressureController {
    cfg: DegradeConfig,
    level: u8,
}

impl PressureController {
    pub fn new(cfg: DegradeConfig) -> PressureController {
        PressureController { cfg, level: 0 }
    }

    /// Current degradation level (0 = none, 3 = shadow routing).
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Advance the state machine with the current pressure. Returns
    /// `(old_level, new_level)`; the caller applies the backend knob
    /// transitions for every level crossed.
    pub fn update(&mut self, pressure: f64) -> (u8, u8) {
        let old = self.level;
        if !self.cfg.enabled {
            return (old, old);
        }
        let mut target = 0u8;
        for level in (1..=3u8).rev() {
            if pressure >= self.cfg.threshold(level) {
                target = level;
                break;
            }
        }
        if target > self.level {
            // escalation is immediate: overload is the emergency
            self.level = target;
        } else {
            // de-escalate only through levels whose entry threshold the
            // pressure clears by the hysteresis margin
            while self.level > 0
                && pressure < self.cfg.threshold(self.level) - self.cfg.hysteresis
            {
                self.level -= 1;
            }
        }
        (old, self.level)
    }
}

/// Combine the serving loop's live signals into one pressure scalar:
/// the max of KV-pool page utilisation and queue fill, saturating to
/// 1.0 whenever any request sits parked (a parked request *is* the
/// overload — capacity freed by preemption must not read as calm).
pub fn pressure_signal(pool_frac: f64, queue_frac: f64, parked: usize) -> f64 {
    if parked > 0 {
        return 1.0;
    }
    pool_frac.max(queue_frac).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_controller_stays_flat() {
        let mut c = PressureController::new(DegradeConfig::default());
        assert_eq!(c.update(1.0), (0, 0));
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn escalates_immediately_and_descends_with_hysteresis() {
        let mut c = PressureController::new(DegradeConfig::enabled());
        assert_eq!(c.update(0.50), (0, 0));
        assert_eq!(c.update(0.72), (0, 1));
        // straight to L3 in one step when the signal spikes
        assert_eq!(c.update(0.99), (1, 3));
        // just under the L3 threshold: hysteresis holds the level
        assert_eq!(c.update(0.90), (3, 3));
        // clears l3 - hysteresis (0.85) but not l2 - hysteresis (0.75):
        // one step down, then held
        assert_eq!(c.update(0.80), (3, 2));
        assert_eq!(c.update(0.80), (2, 2));
        // calm signal walks the rest of the way down in one update
        assert_eq!(c.update(0.10), (2, 0));
    }

    #[test]
    fn same_trace_same_transitions() {
        let trace = [0.2, 0.9, 0.97, 0.6, 0.3, 0.96, 0.1];
        let run = |mut c: PressureController| {
            trace.iter().map(|&p| c.update(p)).collect::<Vec<_>>()
        };
        let a = run(PressureController::new(DegradeConfig::enabled()));
        let b = run(PressureController::new(DegradeConfig::enabled()));
        assert_eq!(a, b);
        assert!(a.iter().any(|&(o, n)| n > o), "trace escalates");
        assert!(a.iter().any(|&(o, n)| n < o), "trace recovers");
    }

    #[test]
    fn pressure_signal_saturates_on_parked() {
        assert_eq!(pressure_signal(0.2, 0.1, 0), 0.2);
        assert_eq!(pressure_signal(0.1, 0.4, 0), 0.4);
        assert_eq!(pressure_signal(0.0, 0.0, 1), 1.0);
        assert_eq!(pressure_signal(2.0, 0.0, 0), 1.0);
    }
}
