//! The coordinator serving loop: batcher → slot pool → sampler → event
//! streams.
//!
//! Scheduling is **continuous** (slot-based): the backend exposes a
//! persistent pool of decode slots; a request is admitted into a free
//! slot the moment one exists, decodes alongside whatever else is in
//! flight, and releases its slot on completion so the next queued
//! request can be admitted mid-flight. No prompt-length alignment and no
//! lock-step draining — occupancy (and with it decode throughput on a
//! batch-parallel backend) stays high under mixed-length traffic.
//!
//! Backends whose compiled surface cannot admit mid-flight (the PJRT
//! lock-step artifacts share a scalar `pos0` across lanes — see
//! [`super::backend`]) fall back to aligned group admission: the batcher
//! forms a prompt-length-aligned group, the group prefills into a fresh
//! surface, and freed slots within the group are masked until it drains.
//! `CoordinatorConfig { continuous: false, .. }` forces this mode on any
//! backend (the batch-synchronous baseline in `benches/fig7_throughput`).
//!
//! Two operating modes:
//! * [`Coordinator::run_closed_loop`] — drive a fixed request set to
//!   completion (benches, eval),
//! * [`Coordinator::spawn`] — a long-lived worker thread with a submit
//!   channel; [`CoordinatorHandle::submit`] returns a per-request
//!   [`GenEvent`] stream delivering each token as it is sampled,
//!   terminated by exactly one `Done` (or `Error` for shed/rejected
//!   requests — nothing blocks forever on an overloaded queue).
//!
//! Memory pressure: on the default (paged-KV) native backend the loop
//! snapshots the pool's counters into [`ServeMetrics::kv_pool`] —
//! admission accounting is **pages in use**, the bytes sequences
//! actually occupy, not the `max_seq`-capacity figure dense caches
//! would report.
//!
//! Overload tier (continuous mode, preemptible backends): exhaustion no
//! longer sheds first. A request that cannot get pages — at prefill or
//! mid-decode — **preempts** the lowest strictly-lower-priority
//! occupant instead: the victim's full engine state (target KV, draft
//! mirror, catch-up tokens, K controller) swaps out bit-exactly to a
//! host-side parking buffer ([`super::backend::ParkedSlot`]), its pages
//! return to the pool, and it resumes through [`Backend::swap_in`] when
//! capacity frees — continuing its stream exactly where it stopped. A
//! starved mid-decode slot likewise suspends rather than dying.
//! Shedding remains only for requests that can never fit (or queue
//! overflow), always with a terminal `Error` event rather than aborting
//! the loop. Stacked on top, a hysteretic pressure controller
//! ([`super::overload`]) degrades decode under load: speculative-K
//! caps, the bare quantized branch, and per-slot lower-bit shadow
//! routing — every transition counted per priority class in
//! [`ServeMetrics::classes`].

use super::backend::{
    validate_batch, validate_request, Backend, BatchState, ParkedSlot, SlotToken, SpecSlot,
};
use super::batcher::{effective_class, Batcher, BatcherConfig, Submitted};
use super::metrics::{MetricPhase, ServeMetrics};
use super::overload::{pressure_signal, DegradeConfig, PressureController};
use super::request::{GenEvent, GenRequest, GenResponse, Priority};
use super::sampler::Sampler;
use crate::trace::{self, Phase};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Consecutive park/resume round-trips a request may make without
/// committing a single new token before it is declared unable to fit
/// and shed (prevents a swap-in/starve/swap-out livelock).
const MAX_STALLS: u32 = 3;

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Admit into freed slots mid-flight when the backend supports it
    /// (false = batch-synchronous aligned groups on every backend).
    pub continuous: bool,
    /// Continuous slot-pool size; 0 = `backend.max_batch()`. Aligned
    /// (non-continuous) groups are sized by the batcher's compiled batch
    /// sizes instead.
    pub slots: usize,
    /// Load-adaptive degradation thresholds (disabled by default).
    pub degrade: DegradeConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            continuous: true,
            slots: 0,
            degrade: DegradeConfig::default(),
        }
    }
}

/// A request occupying a decode slot.
struct Active {
    req: GenRequest,
    /// sampled but not yet committed token
    current: u32,
    output: Vec<u32>,
    ttft_us: Option<f64>,
    prefill_done: Instant,
    /// when the previous token event was emitted (inter-token latency)
    last_token_at: Option<Instant>,
    /// consecutive preemptions without a new committed token
    stalls: u32,
    /// output length at the previous preemption (`usize::MAX` = never
    /// preempted, so the first park can't count as a stall)
    parked_len: usize,
    /// `current` was committed and emitted before a mid-decode
    /// preemption: the next step must only re-feed it to the engine,
    /// not emit it a second time
    refeed: bool,
    /// admission queue wait (arrival → slot placement), for the response
    queue_us: f64,
    /// prompt prefill wall time, for the response
    prefill_us: f64,
}

/// A preempted request: its scheduling state plus the host-side parking
/// buffer holding its engine state. Holds no slot and no pool pages —
/// that is the point — and restores both exactly via
/// [`Backend::swap_in`] when capacity frees up.
struct ParkedReq {
    active: Active,
    kv: ParkedSlot,
}

/// What `peek_candidate` nominated for the next free slot.
enum Cand {
    /// `parked[i]` — resume a preempted request
    Parked(usize),
    /// the batcher's best queued request
    Queued,
}

/// The scheduling core shared by the closed loop and the spawned worker:
/// one slot pool, one admission queue, per-request event delivery.
struct ServeLoop<'a> {
    backend: &'a mut dyn Backend,
    continuous: bool,
    /// fixed pool size — the occupancy denominator in both modes
    pool_capacity: usize,
    max_wait: Duration,
    state: BatchState,
    slots: Vec<Option<Active>>,
    /// preempted requests awaiting swap-in (unordered; admission picks
    /// by effective class, FIFO within a class)
    parked: Vec<ParkedReq>,
    batcher: Batcher,
    sampler: Sampler,
    /// load-adaptive degradation state machine (level 0 when disabled)
    pressure: PressureController,
    degrade: DegradeConfig,
    age_after: Duration,
    max_queue: usize,
    metrics: ServeMetrics,
    sinks: HashMap<u64, mpsc::Sender<GenEvent>>,
    /// in-flight ids whose sink dropped mid-stream (client disconnect),
    /// awaiting slot release at the next reap point
    cancelled: Vec<u64>,
    finished: Vec<GenResponse>,
    collect: bool,
}

impl<'a> ServeLoop<'a> {
    fn new(backend: &'a mut dyn Backend, cfg: &CoordinatorConfig, collect: bool)
        -> Result<ServeLoop<'a>> {
        // pin the flight-recorder epoch so request timestamps are small
        // positive offsets from serving start
        trace::init();
        let continuous = cfg.continuous && backend.continuous();
        let pool_capacity = if cfg.slots > 0 {
            cfg.slots.min(backend.max_batch())
        } else {
            backend.max_batch()
        };
        let mut metrics = ServeMetrics::new();
        // the persistent pool only exists in continuous mode; the aligned
        // path opens a fresh surface per group, so it starts from an
        // empty placeholder that is never handed to the backend
        let (state, slots) = if continuous {
            metrics.pools_opened += 1;
            (backend.open_batch(pool_capacity)?, (0..pool_capacity).map(|_| None).collect())
        } else {
            (BatchState::Native { slots: Vec::new() }, Vec::new())
        };
        Ok(ServeLoop {
            backend,
            continuous,
            pool_capacity,
            max_wait: cfg.batcher.max_wait,
            state,
            slots,
            parked: Vec::new(),
            batcher: Batcher::new(cfg.batcher.clone()),
            sampler: Sampler::new(0xfb90),
            pressure: PressureController::new(cfg.degrade.clone()),
            degrade: cfg.degrade.clone(),
            age_after: cfg.batcher.age_after,
            max_queue: cfg.batcher.max_queue,
            metrics,
            sinks: HashMap::new(),
            cancelled: Vec::new(),
            finished: Vec::new(),
            collect,
        })
    }

    fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn idle(&self) -> bool {
        self.occupied() == 0 && self.batcher.is_empty() && self.parked.is_empty()
    }

    /// Deliver an event to its request's sink (if any); terminal events
    /// close the sink, `Done` responses are collected in closed-loop mode.
    /// A failed send on a non-terminal event means the receiver is gone
    /// (HTTP client disconnected): the id is marked for cancellation so
    /// the next reap point frees its slot and KV pages instead of
    /// decoding for a dead stream.
    fn emit(&mut self, ev: GenEvent) {
        let id = ev.id();
        let terminal = ev.is_terminal();
        if self.collect {
            if let GenEvent::Done(r) = &ev {
                self.finished.push(r.clone());
            }
        }
        if let Some(sink) = self.sinks.get(&id) {
            if sink.send(ev).is_err() && !terminal {
                self.sinks.remove(&id);
                self.cancelled.push(id);
            }
        }
        if terminal {
            self.sinks.remove(&id);
        }
    }

    /// Release the slots of requests whose stream receiver dropped:
    /// frees the slot (returning its KV pages to the pool), strikes the
    /// slot from this step's decode set, and counts the cancellation.
    fn reap_cancelled(
        &mut self,
        to_decode: &mut Vec<SlotToken>,
        to_spec: &mut Vec<SpecSlot>,
    ) -> Result<()> {
        if self.cancelled.is_empty() {
            return Ok(());
        }
        for id in std::mem::take(&mut self.cancelled) {
            // a parked request's buffer is host memory only: drop it
            if let Some(pi) = self.parked.iter().position(|p| p.active.req.id == id) {
                self.parked.swap_remove(pi);
                self.metrics.parked = self.parked.len();
                self.metrics.cancellations += 1;
                trace::instant(Phase::Cancel, id, trace::SLOT_NONE, 0);
                continue;
            }
            let slot =
                self.slots.iter().position(|s| s.as_ref().is_some_and(|a| a.req.id == id));
            // a request can finish (stop token, budget) between the failed
            // send and the reap — nothing left to release then
            let Some(slot) = slot else { continue };
            self.slots[slot] = None;
            self.backend.release_slot(&mut self.state, slot)?;
            self.metrics.cancellations += 1;
            trace::instant(Phase::Cancel, id, slot as u16, 0);
            to_decode.retain(|st| st.slot != slot);
            to_spec.retain(|sp| sp.slot != slot);
        }
        self.snapshot_kv();
        Ok(())
    }

    /// Accept a request into the admission queue. Invalid requests error
    /// out in closed-loop (collect) mode and get a terminal `Error` event
    /// in streaming mode; a full queue sheds the request (also with a
    /// terminal `Error` — the sink never leaks) and returns `Ok(false)`.
    fn submit(&mut self, req: GenRequest, sink: Option<mpsc::Sender<GenEvent>>) -> Result<bool> {
        self.metrics.requests_in += 1;
        self.metrics.class(req.class).submitted += 1;
        let id = req.id;
        if let Some(s) = sink {
            // a duplicate in-flight id would overwrite the first stream's
            // sink and strand it without a terminal event: reject the new
            // stream instead (id 0 auto-assigns, so this only hits callers
            // reusing explicit ids)
            if self.sinks.contains_key(&id) {
                self.metrics.requests_shed += 1;
                self.metrics.class(req.class).shed += 1;
                trace::instant(Phase::Reject, id, trace::SLOT_NONE, 0);
                let _ = s.send(GenEvent::Error {
                    id,
                    message: format!("request id {id} is already in flight"),
                });
                return Ok(true);
            }
            self.sinks.insert(id, s);
        }
        if let Err(e) = validate_request(self.backend.cfg(), &req) {
            self.metrics.requests_shed += 1;
            self.metrics.class(req.class).shed += 1;
            trace::instant(Phase::Reject, id, trace::SLOT_NONE, 0);
            if self.collect {
                // closed loop: nobody watches an event stream — surface
                // the rejection to the caller
                return Err(e);
            }
            self.emit(GenEvent::Error { id, message: e.to_string() });
            return Ok(true); // rejected, but handled — not an overload signal
        }
        match self.batcher.submit(req) {
            Submitted::Queued { displaced: Some(d) } => {
                // a full queue made room by pushing out its youngest
                // strictly-lower-class entry; that one sheds instead
                self.metrics.requests_shed += 1;
                self.metrics.class(d.class).shed += 1;
                trace::instant(Phase::Shed, d.id, trace::SLOT_NONE, 0);
                self.emit(GenEvent::Error {
                    id: d.id,
                    message: "displaced by a higher-priority arrival: request shed".into(),
                });
                Ok(true)
            }
            Submitted::Queued { displaced: None } => Ok(true),
            Submitted::Shed(r) => {
                self.metrics.requests_shed += 1;
                self.metrics.class(r.class).shed += 1;
                trace::instant(Phase::Shed, id, trace::SLOT_NONE, 0);
                self.emit(GenEvent::Error {
                    id,
                    message: "admission queue full: request shed".into(),
                });
                Ok(false)
            }
        }
    }

    /// Fold the backend's KV-pool counters (if any) into the metrics.
    fn snapshot_kv(&mut self) {
        if let Some(s) = self.backend.kv_stats(&self.state) {
            self.metrics.kv_pool = Some(s);
        }
    }

    /// Release a completed slot and build its terminal `Done` event
    /// (shared by the plain commit path and the speculative path).
    fn finish_slot(&mut self, slot: usize) -> Result<GenEvent> {
        let a = self.slots[slot].take().expect("finish of an empty slot");
        self.backend.release_slot(&mut self.state, slot)?;
        let total_us = a.req.arrived.elapsed().as_secs_f64() * 1e6;
        self.metrics.e2e.record_us(total_us);
        self.metrics.requests_done += 1;
        self.metrics.class(a.req.class).done += 1;
        trace::instant(Phase::Done, a.req.id, slot as u16, a.output.len() as u64);
        Ok(GenEvent::Done(GenResponse {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.output,
            ttft_us: a.ttft_us.unwrap_or(total_us),
            total_us,
            decode_s: a.prefill_done.elapsed().as_secs_f64(),
            queue_us: a.queue_us,
            prefill_us: a.prefill_us,
        }))
    }

    /// Bookkeeping shared by both admission paths.
    fn place(
        &mut self,
        slot: usize,
        req: GenRequest,
        logits: &[f32],
        wait_us: f64,
        prefill_us: f64,
    ) -> Result<()> {
        self.metrics.tokens_prefilled += req.prompt.len();
        self.metrics.record_admission(wait_us);
        self.metrics.record_phase_us(MetricPhase::Prefill, prefill_us);
        if trace::request_on() {
            // both spans were measured by the caller: queue wait ended at
            // admission, prefill ended just now
            let q0 = trace::instant_ns(req.arrived);
            trace::span_closed(
                Phase::Queue,
                req.id,
                slot as u16,
                q0,
                q0 + (wait_us * 1e3) as u64,
                0,
            );
            let end = trace::now_ns();
            trace::span_closed(
                Phase::Prefill,
                req.id,
                slot as u16,
                end.saturating_sub((prefill_us * 1e3) as u64),
                end,
                req.prompt.len() as u64,
            );
        }
        if req.max_new_tokens == 0 {
            // degenerate budget: complete immediately with zero tokens
            // rather than letting the step loop commit the sampled one
            self.backend.release_slot(&mut self.state, slot)?;
            let total_us = req.arrived.elapsed().as_secs_f64() * 1e6;
            self.metrics.ttft.record_us(total_us);
            self.metrics.e2e.record_us(total_us);
            self.metrics.requests_done += 1;
            self.metrics.class(req.class).done += 1;
            trace::instant(Phase::Done, req.id, slot as u16, 0);
            self.emit(GenEvent::Done(GenResponse {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                ttft_us: total_us,
                total_us,
                decode_s: 0.0,
                queue_us: wait_us,
                prefill_us,
            }));
            return Ok(());
        }
        let current = self.sampler.sample(logits, &req.params);
        self.slots[slot] = Some(Active {
            req,
            current,
            output: Vec::new(),
            ttft_us: None,
            prefill_done: Instant::now(),
            last_token_at: None,
            stalls: 0,
            parked_len: usize::MAX,
            refeed: false,
            queue_us: wait_us,
            prefill_us,
        });
        Ok(())
    }

    /// Preempt `slot`: swap its full engine state out to a host parking
    /// buffer (pages return to the pool) and queue it for resume. Should
    /// the swap itself fail (non-preemptible backend reached this path)
    /// the request sheds with a terminal error — never silently lost.
    fn park_slot(&mut self, slot: usize) -> Result<()> {
        let mut a = self.slots[slot].take().expect("park of an empty slot");
        let mut sw = trace::span(Phase::SwapOut, a.req.id, slot as u16);
        let t_swap = Instant::now();
        match self.backend.swap_out(&mut self.state, slot) {
            Ok(kv) => {
                sw.payload(kv.bytes() as u64);
                sw.end();
                self.metrics
                    .record_phase_us(MetricPhase::KvSwap, t_swap.elapsed().as_secs_f64() * 1e6);
                if a.parked_len == a.output.len() {
                    // resumed and preempted again without committing a
                    // token: starving, not just unlucky
                    a.stalls += 1;
                } else {
                    a.stalls = 0;
                }
                a.parked_len = a.output.len();
                self.metrics.swapped_bytes += kv.bytes() as u64;
                self.metrics.class(a.req.class).preemptions += 1;
                self.parked.push(ParkedReq { active: a, kv });
                self.metrics.parked = self.parked.len();
            }
            Err(e) => {
                sw.end();
                self.backend.release_slot(&mut self.state, slot)?;
                self.metrics.requests_shed += 1;
                self.metrics.class(a.req.class).shed += 1;
                trace::instant(Phase::Shed, a.req.id, slot as u16, 0);
                self.emit(GenEvent::Error {
                    id: a.req.id,
                    message: format!("preemption failed ({e:#}): request shed"),
                });
            }
        }
        self.snapshot_kv();
        Ok(())
    }

    /// Occupied slot to preempt in favour of a `class` candidate: the
    /// youngest occupant of the worst **declared** class strictly below
    /// the candidate's. Declared (not aged) classes on both sides keep
    /// the relation antisymmetric — an aged batch request may be
    /// *admitted* like an interactive one but can never evict one, so
    /// two requests can't take turns preempting each other.
    fn preempt_victim(&self, class: Priority) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|a| (i, a)))
            .filter(|(_, a)| a.req.class > class)
            .max_by_key(|&(_, a)| (a.req.class, a.prefill_done))
            .map(|(i, _)| i)
    }

    /// Swap `parked[idx]` back into the free `slot`, restoring its KV
    /// (and draft mirror) bit-exactly. Returns whether admission made
    /// progress: a failed swap-in with other work still holding pages
    /// puts the buffer back and pauses admission (`false`); a failed
    /// swap-in with the pool otherwise EMPTY can never succeed, so the
    /// request sheds (`true` — the parked entry is gone).
    fn resume_parked(&mut self, idx: usize, slot: usize) -> Result<bool> {
        let pr = self.parked.swap_remove(idx);
        let mut sw = trace::span(Phase::SwapIn, pr.active.req.id, slot as u16);
        sw.payload(pr.kv.bytes() as u64);
        let t_swap = Instant::now();
        match self.backend.swap_in(&mut self.state, slot, &pr.kv) {
            Ok(()) => {
                sw.end();
                self.metrics
                    .record_phase_us(MetricPhase::KvSwap, t_swap.elapsed().as_secs_f64() * 1e6);
                self.metrics.class(pr.active.req.class).resumes += 1;
                self.slots[slot] = Some(pr.active);
                self.metrics.parked = self.parked.len();
                self.snapshot_kv();
                Ok(true)
            }
            Err(e) => {
                sw.end();
                if self.occupied() == 0 {
                    self.metrics.requests_shed += 1;
                    self.metrics.class(pr.active.req.class).shed += 1;
                    trace::instant(Phase::Shed, pr.active.req.id, slot as u16, 0);
                    self.emit(GenEvent::Error {
                        id: pr.active.req.id,
                        message: format!("resume after preemption failed ({e:#}): request shed"),
                    });
                } else {
                    self.parked.push(pr);
                }
                self.metrics.parked = self.parked.len();
                Ok(self.occupied() == 0)
            }
        }
    }

    /// Nominate the next admission: the best of the parked set and the
    /// batcher's queue by (effective class, arrival), parked winning
    /// ties — a preempted request already paid its queue wait once.
    /// Returns the candidate and its **declared** class (the preemption
    /// currency).
    fn peek_candidate(&self, now: Instant) -> Option<(Cand, Priority)> {
        let queued = self
            .batcher
            .peek_ready(now)
            .map(|r| (effective_class(self.age_after, r, now), r.arrived, r.class));
        let parked = self
            .parked
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = &p.active.req;
                (i, effective_class(self.age_after, r, now), r.arrived, r.class)
            })
            .min_by_key(|&(_, ec, arrived, _)| (ec, arrived));
        match (parked, queued) {
            (None, None) => None,
            (Some((i, _, _, c)), None) => Some((Cand::Parked(i), c)),
            (None, Some((_, _, c))) => Some((Cand::Queued, c)),
            (Some((i, pec, parr, pc)), Some((qec, qarr, qc))) => {
                if (pec, parr) <= (qec, qarr) {
                    Some((Cand::Parked(i), pc))
                } else {
                    Some((Cand::Queued, qc))
                }
            }
        }
    }

    /// Prefill `req` into the free `slot`. Under pool exhaustion, a
    /// preemptible backend makes room by parking strictly-lower-class
    /// occupants (worst class first) and retrying; only when no such
    /// victim remains does the request shed.
    fn admit_prefill(&mut self, slot: usize, req: GenRequest) -> Result<()> {
        let wait_us = req.arrived.elapsed().as_secs_f64() * 1e6;
        let reused_before =
            self.backend.kv_stats(&self.state).map_or(0, |s| s.prefix_tokens_reused);
        // time only the attempt that succeeds — preempt-and-retry rounds
        // are accounted to the KV-swap phase, not to prefill
        let mut t_pref = Instant::now();
        let mut res = self.backend.prefill_slot(&mut self.state, slot, &req.prompt);
        while res.is_err() && self.continuous && self.backend.preemptible() {
            let Some(victim) = self.preempt_victim(req.class) else { break };
            self.park_slot(victim)?;
            t_pref = Instant::now();
            res = self.backend.prefill_slot(&mut self.state, slot, &req.prompt);
        }
        match res {
            Ok(logits) => {
                let prefill_us = t_pref.elapsed().as_secs_f64() * 1e6;
                // count engine-executed prefill work: positions served
                // from the prefix cache were not prefilled
                let reused = self
                    .backend
                    .kv_stats(&self.state)
                    .map_or(0, |s| s.prefix_tokens_reused)
                    .saturating_sub(reused_before);
                self.place(slot, req, &logits, wait_us, prefill_us)?;
                self.metrics.tokens_prefilled =
                    self.metrics.tokens_prefilled.saturating_sub(reused);
            }
            Err(e) => {
                self.metrics.requests_shed += 1;
                self.metrics.class(req.class).shed += 1;
                trace::instant(Phase::Shed, req.id, slot as u16, 0);
                self.emit(GenEvent::Error { id: req.id, message: e.to_string() });
            }
        }
        Ok(())
    }

    /// Drive the degradation state machine with the current pressure
    /// signal and apply whatever backend knob transitions the level
    /// change demands (see [`super::overload`]). Runs once per
    /// scheduling step; every transition is counted against the class of
    /// each running request it touches.
    fn apply_pressure(&mut self) {
        if !self.degrade.enabled {
            return;
        }
        let pool_frac = self.metrics.kv_pool.as_ref().map_or_else(
            || self.occupied() as f64 / self.pool_capacity.max(1) as f64,
            |p| p.pages_in_use as f64 / p.pages_total.max(1) as f64,
        );
        let queue_frac = self.batcher.len() as f64 / self.max_queue.max(1) as f64;
        let p = pressure_signal(pool_frac, queue_frac, self.parked.len());
        let (old, new) = self.pressure.update(p);
        self.metrics.degrade_level = new as usize;
        if new != old {
            trace::instant(Phase::Degrade, 0, trace::SLOT_NONE, new as u64);
            // global knobs at the L1/L2 boundaries (level 3 keeps both)
            if new >= 1 && old < 1 {
                self.backend.set_spec_k_cap(Some(self.degrade.k_cap));
            } else if new < 1 && old >= 1 {
                self.backend.set_spec_k_cap(None);
            }
            if new >= 2 && old < 2 {
                self.backend.set_bare_branch(true);
            } else if new < 2 && old >= 2 {
                self.backend.set_bare_branch(false);
            }
            let levels = new.abs_diff(old) as usize;
            for a in self.slots.iter().flatten() {
                let c = &mut self.metrics.classes[a.req.class.index()];
                if new > old {
                    c.degrades += levels;
                } else {
                    c.restores += levels;
                }
            }
        }
        // L3 per-slot routing: send batch-class occupants through the
        // lower-bit shadow engine (reconciled every step so admissions
        // and releases during a sustained L3 episode are covered)
        for i in 0..self.slots.len() {
            let Some(a) = self.slots[i].as_ref() else { continue };
            let class = a.req.class;
            let want = new >= 3 && class == Priority::Batch;
            if want != self.backend.slot_shadowed(i)
                && self.backend.set_slot_shadow(i, want).is_ok()
            {
                let c = self.metrics.class(class);
                if want {
                    c.degrades += 1;
                } else {
                    c.restores += 1;
                }
            }
        }
    }

    /// Admit queued requests into free slots. `now` drives the batcher's
    /// wait-timeout release on the aligned (non-continuous) path.
    fn admit(&mut self, now: Instant) -> Result<()> {
        if self.continuous {
            loop {
                let Some((cand, class)) = self.peek_candidate(now) else { break };
                // a free slot, or one vacated by preempting a strictly
                // lower-priority occupant on the candidate's behalf
                let slot = match self.slots.iter().position(|s| s.is_none()) {
                    Some(s) => s,
                    None => {
                        if !self.backend.preemptible() {
                            break;
                        }
                        let Some(victim) = self.preempt_victim(class) else { break };
                        self.park_slot(victim)?;
                        victim
                    }
                };
                match cand {
                    Cand::Parked(idx) => {
                        if !self.resume_parked(idx, slot)? {
                            break;
                        }
                    }
                    Cand::Queued => {
                        let Some(req) = self.batcher.pop_ready(now) else { break };
                        self.admit_prefill(slot, req)?;
                    }
                }
            }
        } else if self.occupied() == 0 {
            let Some(batch) = self.batcher.next_batch(now) else { return Ok(()) };
            validate_batch(&*self.backend, &batch.requests)?;
            let capacity = batch.capacity;
            // fresh aligned surface per group (lock-step artifacts only
            // admit at pos 0); the previous group's surface is dropped
            self.state = self.backend.open_batch(capacity)?;
            self.slots = (0..capacity).map(|_| None).collect();
            self.metrics.record_batch(batch.requests.len(), capacity);
            // queue wait ends here — measure before the batched prefill so
            // the number is comparable with the continuous path
            let waits: Vec<f64> = batch
                .requests
                .iter()
                .map(|r| r.arrived.elapsed().as_secs_f64() * 1e6)
                .collect();
            let admissions: Vec<(usize, &[u32])> = batch
                .requests
                .iter()
                .enumerate()
                .map(|(i, r)| (i, r.prompt.as_slice()))
                .collect();
            let t_pref = Instant::now();
            let logits = self.backend.prefill_slots(&mut self.state, &admissions)?;
            // lock-step group prefill: every member waits out the whole
            // batched pass, so each is attributed the full duration
            let prefill_us = t_pref.elapsed().as_secs_f64() * 1e6;
            for ((i, req), (lg, wait_us)) in
                batch.requests.into_iter().enumerate().zip(logits.iter().zip(waits))
            {
                self.place(i, req, lg, wait_us, prefill_us)?;
            }
        }
        self.snapshot_kv();
        Ok(())
    }

    /// One scheduling step: commit the sampled token of every occupied
    /// slot (emitting `Token` events), finish + release completed slots
    /// (emitting `Done`), then run one batched decode over the
    /// survivors. On a speculative backend EVERY slot routes through
    /// [`Backend::decode_speculative`] and may commit up to K extra
    /// accepted tokens this same step (`1..=K+1` per slot): greedy slots
    /// under argmax acceptance (token-identical output), sampled slots
    /// under rejection-sampling acceptance (distribution-identical
    /// output). Returns false when no slot was occupied (nothing to do).
    fn step(&mut self) -> Result<bool> {
        let step_t0 = Instant::now();
        self.apply_pressure();
        let spec_on = self.backend.speculative().is_some();
        let mut events: Vec<GenEvent> = Vec::new();
        let mut to_decode: Vec<SlotToken> = Vec::new();
        let mut to_spec: Vec<SpecSlot> = Vec::new();
        let mut parked_this_step = false;
        for i in 0..self.slots.len() {
            let done = {
                let Some(a) = self.slots[i].as_mut() else { continue };
                if a.refeed {
                    // resumed after a mid-decode preemption: `current`
                    // already went out on the stream; it only needs to be
                    // fed through the engine again (its KV position was
                    // never written). The done-check already ran false
                    // before the park.
                    a.refeed = false;
                    false
                } else {
                    a.output.push(a.current);
                    if a.ttft_us.is_none() {
                        let us = a.req.arrived.elapsed().as_secs_f64() * 1e6;
                        a.ttft_us = Some(us);
                        self.metrics.ttft.record_us(us);
                    }
                    let now = Instant::now();
                    if let Some(prev) = a.last_token_at {
                        self.metrics.itl.record(now - prev);
                    }
                    a.last_token_at = Some(now);
                    self.metrics.tokens_generated += 1;
                    events.push(GenEvent::Token {
                        id: a.req.id,
                        index: a.output.len() - 1,
                        token: a.current,
                    });
                    Some(a.current) == a.req.stop_token
                        || a.output.len() >= a.req.max_new_tokens
                }
            };
            if done {
                events.push(self.finish_slot(i)?);
            } else {
                // reserve what the slot needs for its next step; a slot
                // that cannot advance (e.g. KV pool exhausted mid-decode)
                // SUSPENDS — swaps out to the parking buffer, resuming
                // when pages free — rather than dying. Only when parking
                // cannot help (non-preemptible backend, nothing else
                // holds capacity, or the slot keeps starving) does the
                // request shed with a terminal error
                match self.backend.prepare_decode(&mut self.state, i) {
                    Ok(()) => {
                        let a = self.slots[i].as_ref().expect("slot emptied mid-step");
                        // a speculative backend serves every slot through
                        // the speculative path — greedy under argmax
                        // acceptance, sampled under rejection sampling
                        // (both output-preserving; a slot must stay on
                        // one decode path for its whole lifetime)
                        if spec_on {
                            to_spec.push(SpecSlot {
                                slot: i,
                                token: a.current,
                                sampling: a.req.params.clone(),
                            });
                        } else {
                            to_decode.push(SlotToken { slot: i, token: a.current });
                        }
                    }
                    Err(e) => {
                        let can_park = {
                            let a = self.slots[i].as_ref().expect("slot emptied mid-step");
                            self.continuous
                                && self.backend.preemptible()
                                && a.stalls < MAX_STALLS
                                && (self.occupied() > 1
                                    || !self.batcher.is_empty()
                                    || !self.parked.is_empty())
                        };
                        if can_park {
                            // the token committed above must not re-emit
                            // when this request resumes — only re-feed
                            if let Some(a) = self.slots[i].as_mut() {
                                a.refeed = true;
                            }
                            self.park_slot(i)?;
                            parked_this_step = true;
                        } else {
                            let a = self.slots[i].take().expect("slot emptied mid-step");
                            self.backend.release_slot(&mut self.state, i)?;
                            self.metrics.requests_shed += 1;
                            self.metrics.class(a.req.class).shed += 1;
                            events.push(GenEvent::Error { id: a.req.id, message: e.to_string() });
                        }
                    }
                }
            }
        }
        // a park IS progress: it frees pages the next admission round
        // turns into an admission, a resume, or a terminal shed
        let progressed = !events.is_empty() || parked_this_step;
        for ev in events {
            self.emit(ev);
        }
        // reap disconnected clients before spending a decode on them
        self.reap_cancelled(&mut to_decode, &mut to_spec)?;
        if to_decode.is_empty() && to_spec.is_empty() {
            return Ok(progressed);
        }
        // denominator: the configured pool in continuous mode; an aligned
        // group can be wider than `cfg.slots`, so never report above 1.0
        self.metrics.record_step(
            to_decode.len() + to_spec.len(),
            self.pool_capacity.max(self.slots.len()),
        );
        // meter decode-phase weight traffic only (prefill would swamp
        // the per-generated-token number this metric exists to expose)
        let weight_before = self.backend.weight_bytes().unwrap_or(0);
        // one DecodeStep span per surviving slot covers this step's
        // engine pass + sampling/commit (clock reads gated on the level)
        let dec_t0_ns = if trace::request_on() { trace::now_ns() } else { 0 };
        if !to_decode.is_empty() {
            let logits = self.backend.decode(&mut self.state, &to_decode)?;
            let mut samp_span = trace::span(Phase::Sampler, 0, trace::SLOT_NONE);
            samp_span.payload(to_decode.len() as u64);
            let t_samp = Instant::now();
            for (st, lg) in to_decode.iter().zip(&logits) {
                let a = self.slots[st.slot].as_mut().expect("decoded slot vanished");
                a.current = self.sampler.sample(lg, &a.req.params);
            }
            self.metrics
                .record_phase_us(MetricPhase::Sampler, t_samp.elapsed().as_secs_f64() * 1e6);
            samp_span.end();
            if trace::request_on() {
                let end_ns = trace::now_ns();
                for st in &to_decode {
                    let rid = self.slots[st.slot].as_ref().map_or(0, |a| a.req.id);
                    trace::span_closed(
                        Phase::DecodeStep,
                        rid,
                        st.slot as u16,
                        dec_t0_ns,
                        end_ns,
                        1,
                    );
                }
            }
        }
        if !to_spec.is_empty() {
            let steps = self.backend.decode_speculative(&mut self.state, &to_spec)?;
            // draft/verify wall time measured inside the engine this step
            let (draft_ns, verify_ns) = self.backend.take_step_phases();
            self.metrics.record_phase_ns(MetricPhase::Draft, draft_ns);
            self.metrics.record_phase_ns(MetricPhase::Verify, verify_ns);
            let dec_end_ns = if trace::request_on() { trace::now_ns() } else { 0 };
            let mut spec_events: Vec<GenEvent> = Vec::new();
            for (st, sp) in to_spec.iter().zip(steps) {
                let rid = self.slots[st.slot].as_ref().map_or(0, |a| a.req.id);
                let mut finished = false;
                let mut committed = 0usize;
                let sampled = st.sampling.is_sampled();
                {
                    let a = self.slots[st.slot].as_mut().expect("decoded slot vanished");
                    // commit every accepted draft token now (the slot
                    // emits 1..=K+1 tokens this scheduling step); the
                    // correction/bonus token becomes the next feed
                    for &tok in &sp.accepted {
                        a.output.push(tok);
                        committed += 1;
                        let now = Instant::now();
                        if let Some(prev) = a.last_token_at {
                            self.metrics.itl.record(now - prev);
                        }
                        a.last_token_at = Some(now);
                        self.metrics.tokens_generated += 1;
                        spec_events.push(GenEvent::Token {
                            id: a.req.id,
                            index: a.output.len() - 1,
                            token: tok,
                        });
                        if Some(tok) == a.req.stop_token
                            || a.output.len() >= a.req.max_new_tokens
                        {
                            finished = true;
                            break;
                        }
                    }
                    if !finished {
                        a.current = sp.next;
                    }
                }
                self.metrics.record_spec_step(sampled, sp.proposed, sp.accepted.len(), committed);
                trace::span_closed(
                    Phase::DecodeStep,
                    rid,
                    st.slot as u16,
                    dec_t0_ns,
                    dec_end_ns,
                    committed as u64,
                );
                if finished {
                    spec_events.push(self.finish_slot(st.slot)?);
                }
            }
            for ev in spec_events {
                self.emit(ev);
            }
        }
        if let Some(w) = self.backend.weight_bytes() {
            self.metrics.weight_bytes += w.saturating_sub(weight_before);
        }
        let step_el = step_t0.elapsed();
        self.metrics.record_phase_us(MetricPhase::DecodeStep, step_el.as_secs_f64() * 1e6);
        self.metrics.per_token.record(step_el);
        self.snapshot_kv();
        Ok(true)
    }

    /// Run admissions + steps until pool and queue are empty. Used by the
    /// closed loop and by shutdown drain — nothing else is arriving, so
    /// the batcher's wait timeout is forced.
    fn drain_all(&mut self) -> Result<()> {
        while !self.idle() {
            let now = Instant::now() + self.max_wait + Duration::from_millis(1);
            self.admit(now)?;
            if !self.step()? && self.occupied() == 0 && !self.idle() {
                anyhow::bail!(
                    "scheduler stalled with {} queued and {} parked requests",
                    self.batcher.len(),
                    self.parked.len()
                );
            }
        }
        // step() early-returns before its KV snapshot when the last slot
        // finishes; take a final one so the drained pool counters land
        self.snapshot_kv();
        Ok(())
    }

    fn into_parts(self) -> (Vec<GenResponse>, ServeMetrics) {
        (self.finished, self.metrics)
    }
}

pub struct Coordinator;

impl Coordinator {
    /// Drive a fixed request set to completion (closed loop).
    pub fn run_closed_loop(
        backend: &mut dyn Backend,
        requests: Vec<GenRequest>,
        cfg: &CoordinatorConfig,
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let mut lp = ServeLoop::new(backend, cfg, true)?;
        for r in requests {
            if !lp.submit(r, None)? {
                anyhow::bail!("admission queue overflow in closed loop");
            }
        }
        lp.drain_all()?;
        let (mut responses, metrics) = lp.into_parts();
        responses.sort_by_key(|r| r.id);
        Ok((responses, metrics))
    }

    /// Spawn a worker thread owning the backend. Returns a submit handle.
    ///
    /// `make_backend` runs inside the worker thread (PJRT clients are not
    /// required to be `Send`).
    pub fn spawn<F>(make_backend: F, cfg: CoordinatorConfig) -> CoordinatorHandle
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let join = std::thread::spawn(move || -> Result<ServeMetrics> {
            let mut backend = make_backend()?;
            let mut lp = ServeLoop::new(backend.as_mut(), &cfg, false)?;
            loop {
                // 1) pull work: while slots are decoding only drain what
                //    is already queued; otherwise block briefly (covers
                //    both truly idle and a partial group waiting out
                //    max_wait — no busy spin)
                let timeout = if lp.occupied() > 0 {
                    Duration::ZERO
                } else {
                    cfg.batcher.max_wait.min(Duration::from_millis(5))
                };
                match rx.recv_timeout(timeout) {
                    Ok(WorkItem::Request(req, sink)) => {
                        let _ = lp.submit(req, Some(sink));
                        while let Ok(item) = rx.try_recv() {
                            match item {
                                WorkItem::Request(req, sink) => {
                                    let _ = lp.submit(req, Some(sink));
                                }
                                WorkItem::Metrics(reply) => {
                                    lp.snapshot_kv();
                                    let _ = reply.send(lp.metrics.clone());
                                }
                                WorkItem::Shutdown => {
                                    lp.drain_all()?;
                                    return Ok(lp.into_parts().1);
                                }
                            }
                        }
                    }
                    Ok(WorkItem::Metrics(reply)) => {
                        lp.snapshot_kv();
                        let _ = reply.send(lp.metrics.clone());
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Ok(WorkItem::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                        lp.drain_all()?;
                        return Ok(lp.into_parts().1);
                    }
                }
                // 2) admit into free slots, then one decode step
                lp.admit(Instant::now())?;
                lp.step()?;
            }
        });
        CoordinatorHandle {
            client: CoordinatorClient {
                tx,
                next_id: std::sync::Arc::new(std::sync::atomic::AtomicU64::new(1)),
            },
            join: Some(join),
        }
    }
}

enum WorkItem {
    Request(GenRequest, mpsc::Sender<GenEvent>),
    /// Live metrics snapshot request (the `GET /metrics` endpoint).
    Metrics(mpsc::Sender<ServeMetrics>),
    Shutdown,
}

/// Cheap, cloneable submit handle to a spawned coordinator: what each
/// server connection thread holds. Shares the id counter with every
/// sibling clone; does not own the worker — shutdown (and the final
/// metrics) stay with the [`CoordinatorHandle`].
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::Sender<WorkItem>,
    next_id: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl CoordinatorClient {
    /// Submit a request; returns its event stream (see
    /// [`CoordinatorHandle::submit`]).
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenEvent> {
        self.submit_with_id(req).1
    }

    /// Submit a request and return the id it was admitted under alongside
    /// its event stream. The id is stable from this point on — it is what
    /// the `X-Request-Id` header, the SSE payloads and the flight
    /// recorder all carry (id 0 auto-assigns here, before admission).
    pub fn submit_with_id(&self, mut req: GenRequest) -> (u64, mpsc::Receiver<GenEvent>) {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        req.arrived = Instant::now();
        let id = req.id;
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(WorkItem::Request(req, tx));
        (id, rx)
    }

    /// Convenience: submit and block for the final response, discarding
    /// intermediate token events.
    pub fn submit_wait(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(req);
        for ev in rx {
            match ev {
                GenEvent::Done(r) => return Ok(r),
                GenEvent::Error { id, message } => {
                    anyhow::bail!("request {id} failed: {message}")
                }
                GenEvent::Token { .. } => {}
            }
        }
        anyhow::bail!("coordinator dropped the event stream")
    }

    /// Live metrics snapshot from the serving loop (blocks until the
    /// worker answers between scheduling steps).
    pub fn metrics(&self) -> Result<ServeMetrics> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(WorkItem::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("coordinator worker is gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator worker dropped the metrics reply"))
    }
}

/// Owning handle to a spawned coordinator (shutdown joins the worker).
pub struct CoordinatorHandle {
    client: CoordinatorClient,
    join: Option<std::thread::JoinHandle<Result<ServeMetrics>>>,
}

impl CoordinatorHandle {
    /// Submit a request; returns its event stream. Tokens arrive as they
    /// are sampled; the stream ends with one `Done` or `Error` event.
    /// Explicit (nonzero) ids must be unique among in-flight requests;
    /// id 0 is auto-assigned.
    ///
    /// ```no_run
    /// use fbquant::coordinator::backend::{Backend, NativeBackend};
    /// use fbquant::coordinator::request::{GenEvent, GenRequest};
    /// use fbquant::coordinator::server::{Coordinator, CoordinatorConfig};
    /// use fbquant::engine::SubMode;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let handle = Coordinator::spawn(
    ///     move || -> anyhow::Result<Box<dyn Backend>> {
    ///         let ckpt = std::path::Path::new("artifacts/models/llamoid-tiny_fbquant_w4.fbqw");
    ///         Ok(Box::new(NativeBackend::from_checkpoint(ckpt, SubMode::Fused, "doc")?))
    ///     },
    ///     CoordinatorConfig::default(),
    /// );
    /// let rx = handle.submit(GenRequest::new(0, vec![104, 105], 16));
    /// for ev in rx {
    ///     match ev {
    ///         GenEvent::Token { token, .. } => println!("sampled {token}"),
    ///         GenEvent::Done(r) => {
    ///             println!("{} tokens in {:.1} ms", r.tokens.len(), r.total_us / 1e3);
    ///             break;
    ///         }
    ///         GenEvent::Error { message, .. } => anyhow::bail!(message),
    ///     }
    /// }
    /// handle.shutdown()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn submit(&self, req: GenRequest) -> mpsc::Receiver<GenEvent> {
        self.client.submit(req)
    }

    /// Convenience: submit and block for the final response, discarding
    /// intermediate token events.
    pub fn submit_wait(&self, req: GenRequest) -> Result<GenResponse> {
        self.client.submit_wait(req)
    }

    /// Live metrics snapshot (see [`CoordinatorClient::metrics`]).
    pub fn metrics(&self) -> Result<ServeMetrics> {
        self.client.metrics()
    }

    /// A cloneable submit handle for connection threads.
    pub fn client(&self) -> CoordinatorClient {
        self.client.clone()
    }

    /// Graceful shutdown; returns final metrics.
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        let _ = self.client.tx.send(WorkItem::Shutdown);
        self.join
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("coordinator worker panicked"))?
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        let _ = self.client.tx.send(WorkItem::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
