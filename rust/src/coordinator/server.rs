//! The coordinator serving loop: batcher → backend → sampler → responses.
//!
//! Two operating modes:
//! * [`Coordinator::run_closed_loop`] — drive a fixed request set to
//!   completion (benches, eval),
//! * [`Coordinator::spawn`] — a long-lived worker thread with a submit
//!   channel and per-request response channels (the `serve` command and
//!   the concurrent-load example).
//!
//! Execution is batch-synchronous: a formed batch prefills together and
//! decodes in lock-step; finished slots idle until the batch drains (their
//! waste shows up in the occupancy metric — exactly the effect dynamic
//! batching policies trade against).

use super::backend::{validate_batch, Backend};
use super::batcher::{Batch, Batcher, BatcherConfig};
use super::metrics::ServeMetrics;
use super::request::{GenRequest, GenResponse};
use super::sampler::Sampler;
use anyhow::Result;
use std::sync::mpsc;
use std::time::Instant;

#[derive(Debug, Clone, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

pub struct Coordinator;

impl Coordinator {
    /// Run one formed batch to completion.
    fn run_batch(
        backend: &mut dyn Backend,
        batch: Batch,
        sampler: &mut Sampler,
        metrics: &mut ServeMetrics,
    ) -> Result<Vec<GenResponse>> {
        validate_batch(backend.cfg(), &batch.requests)?;
        metrics.record_batch(batch.requests.len(), batch.capacity);
        let n = batch.requests.len();
        let prompts: Vec<&[u32]> = batch.requests.iter().map(|r| r.prompt.as_slice()).collect();

        let t0 = Instant::now();
        let (mut state, mut logits) = backend.prefill(&prompts, batch.capacity)?;
        let prefill_done = Instant::now();
        metrics.tokens_prefilled += prompts.iter().map(|p| p.len()).sum::<usize>();

        let mut outputs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut done: Vec<bool> = vec![false; n];
        let mut ttft: Vec<Option<f64>> = vec![None; n];
        let max_gen = batch.requests.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);

        let mut current: Vec<u32> = Vec::with_capacity(n);
        for (i, lg) in logits.iter().enumerate() {
            let tok = sampler.sample(lg, &batch.requests[i].params);
            current.push(tok);
        }

        for _step in 0..max_gen {
            let step_t0 = Instant::now();
            // commit the sampled tokens
            for i in 0..n {
                if done[i] {
                    continue;
                }
                outputs[i].push(current[i]);
                if ttft[i].is_none() {
                    ttft[i] = Some(batch.requests[i].arrived.elapsed().as_secs_f64() * 1e6);
                }
                metrics.tokens_generated += 1;
                if Some(current[i]) == batch.requests[i].stop_token
                    || outputs[i].len() >= batch.requests[i].max_new_tokens
                {
                    done[i] = true;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            logits = backend.decode(&mut state, &current)?;
            for i in 0..n {
                if !done[i] {
                    current[i] = sampler.sample(&logits[i], &batch.requests[i].params);
                }
            }
            metrics.per_token.record(step_t0.elapsed());
        }
        drop(state);

        let decode_s = prefill_done.elapsed().as_secs_f64();
        let mut responses = Vec::with_capacity(n);
        for (i, req) in batch.requests.into_iter().enumerate() {
            let ttft_us = ttft[i].unwrap_or_else(|| req.arrived.elapsed().as_secs_f64() * 1e6);
            metrics.ttft.record_us(ttft_us);
            let total_us = req.arrived.elapsed().as_secs_f64() * 1e6;
            metrics.e2e.record_us(total_us);
            metrics.requests_done += 1;
            responses.push(GenResponse {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: std::mem::take(&mut outputs[i]),
                ttft_us,
                total_us,
                decode_s,
            });
        }
        let _ = t0;
        Ok(responses)
    }

    /// Drive a fixed request set to completion (closed loop).
    pub fn run_closed_loop(
        backend: &mut dyn Backend,
        requests: Vec<GenRequest>,
        cfg: &CoordinatorConfig,
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let mut metrics = ServeMetrics::new();
        let mut batcher = Batcher::new(cfg.batcher.clone());
        let mut sampler = Sampler::new(0xfb90);
        let mut responses = Vec::new();
        for r in requests {
            metrics.requests_in += 1;
            if !batcher.submit(r) {
                anyhow::bail!("admission queue overflow in closed loop");
            }
        }
        // force release: in a closed loop nothing else arrives
        while !batcher.is_empty() {
            let now = Instant::now() + cfg.batcher.max_wait + std::time::Duration::from_millis(1);
            if let Some(batch) = batcher.next_batch(now) {
                responses.extend(Self::run_batch(backend, batch, &mut sampler, &mut metrics)?);
            }
        }
        responses.sort_by_key(|r| r.id);
        Ok((responses, metrics))
    }

    /// Spawn a worker thread owning the backend. Returns a submit handle.
    ///
    /// `make_backend` runs inside the worker thread (PJRT clients are not
    /// required to be `Send`).
    pub fn spawn<F>(make_backend: F, cfg: CoordinatorConfig) -> CoordinatorHandle
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let join = std::thread::spawn(move || -> Result<ServeMetrics> {
            let mut backend = make_backend()?;
            let mut metrics = ServeMetrics::new();
            let mut batcher = Batcher::new(cfg.batcher.clone());
            let mut sampler = Sampler::new(0xfb90);
            let mut sinks: Vec<(u64, mpsc::Sender<GenResponse>)> = Vec::new();
            loop {
                // 1) drain the submit channel (bounded wait keeps latency low)
                let timeout = cfg.batcher.max_wait.min(std::time::Duration::from_millis(5));
                match rx.recv_timeout(timeout) {
                    Ok(WorkItem::Request(req, sink)) => {
                        metrics.requests_in += 1;
                        sinks.push((req.id, sink));
                        if !batcher.submit(req) {
                            crate::log_warn!("queue full: shedding request");
                        }
                        // opportunistically drain everything already queued
                        while let Ok(item) = rx.try_recv() {
                            match item {
                                WorkItem::Request(req, sink) => {
                                    metrics.requests_in += 1;
                                    sinks.push((req.id, sink));
                                    if !batcher.submit(req) {
                                        crate::log_warn!("queue full: shedding request");
                                    }
                                }
                                WorkItem::Shutdown => return Ok(metrics),
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Ok(WorkItem::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // drain remaining work before exiting
                        while !batcher.is_empty() {
                            let now = Instant::now() + cfg.batcher.max_wait;
                            if let Some(batch) = batcher.next_batch(now) {
                                let rs = Self::run_batch(&mut *backend, batch, &mut sampler, &mut metrics)?;
                                deliver(&mut sinks, rs);
                            }
                        }
                        return Ok(metrics);
                    }
                }
                // 2) form + run batches
                while let Some(batch) = batcher.next_batch(Instant::now()) {
                    let rs = Self::run_batch(&mut *backend, batch, &mut sampler, &mut metrics)?;
                    deliver(&mut sinks, rs);
                }
            }
        });
        CoordinatorHandle { tx, join: Some(join), next_id: std::sync::atomic::AtomicU64::new(1) }
    }
}

enum WorkItem {
    Request(GenRequest, mpsc::Sender<GenResponse>),
    Shutdown,
}

fn deliver(sinks: &mut Vec<(u64, mpsc::Sender<GenResponse>)>, responses: Vec<GenResponse>) {
    for r in responses {
        if let Some(idx) = sinks.iter().position(|(id, _)| *id == r.id) {
            let (_, sink) = sinks.swap_remove(idx);
            let _ = sink.send(r);
        }
    }
}

/// Client handle to a spawned coordinator.
pub struct CoordinatorHandle {
    tx: mpsc::Sender<WorkItem>,
    join: Option<std::thread::JoinHandle<Result<ServeMetrics>>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl CoordinatorHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, mut req: GenRequest) -> mpsc::Receiver<GenResponse> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        req.arrived = Instant::now();
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(WorkItem::Request(req, tx));
        rx
    }

    /// Graceful shutdown; returns final metrics.
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        let _ = self.tx.send(WorkItem::Shutdown);
        self.join
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("coordinator worker panicked"))?
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkItem::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
