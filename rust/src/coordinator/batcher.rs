//! Dynamic batcher: admission queue + batch forming.
//!
//! Two release disciplines sit on one FIFO admission queue:
//!
//! * **Continuous** ([`Batcher::pop_ready`]) — pop the oldest request the
//!   moment a decode slot frees. Pure arrival order: no length bucketing
//!   is needed when slots are filled independently, and FIFO is
//!   starvation-free by construction.
//! * **Aligned groups** ([`Batcher::next_batch`]) — for lock-step
//!   surfaces (the PJRT artifacts share a scalar `pos0` across batch
//!   slots, so a batch must be position-aligned): gather requests with
//!   the oldest request's prompt length, release when a full batch is
//!   available or the oldest has waited `max_wait`. Because grouping
//!   always keys off the *oldest* request, an odd-length request rises
//!   to the front as earlier arrivals drain and is released within its
//!   own `max_wait` — a stream of other lengths cannot starve it (see
//!   the anti-starvation test).

use super::request::GenRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// compiled batch sizes, ascending (e.g. [1, 4])
    pub batch_sizes: Vec<usize>,
    pub max_wait: Duration,
    /// admission bound; submit fails beyond this
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(20),
            max_queue: 1024,
        }
    }
}

/// A formed batch (position-aligned requests).
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<GenRequest>,
    /// the compiled batch size to run (>= requests.len())
    pub capacity: usize,
}

#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.batch_sizes.is_empty());
        let mut cfg = cfg;
        cfg.batch_sizes.sort_unstable();
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn max_batch(&self) -> usize {
        *self.cfg.batch_sizes.last().unwrap()
    }

    /// Admission control: false = queue full, caller should shed load.
    pub fn submit(&mut self, req: GenRequest) -> bool {
        if self.queue.len() >= self.cfg.max_queue {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Continuous admission: pop the oldest queued request (FIFO).
    pub fn pop_ready(&mut self) -> Option<GenRequest> {
        self.queue.pop_front()
    }

    /// The smallest compiled batch size that fits `n` requests.
    fn capacity_for(&self, n: usize) -> usize {
        for &b in &self.cfg.batch_sizes {
            if b >= n {
                return b;
            }
        }
        self.max_batch()
    }

    /// Form the next batch, or None if the queue should keep waiting.
    ///
    /// Policy: take the oldest request; gather up to `max_batch` requests
    /// with the SAME prompt length (position alignment); release when the
    /// group fills the largest batch or the oldest has waited `max_wait`.
    pub fn next_batch(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.front()?;
        let len0 = oldest.prompt.len();
        let matching: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, r)| r.prompt.len() == len0)
            .map(|(i, _)| i)
            .take(self.max_batch())
            .collect();

        let timed_out = now.duration_since(oldest.arrived) >= self.cfg.max_wait;
        if matching.len() < self.max_batch() && !timed_out {
            return None;
        }

        // remove back-to-front so indices stay valid
        let mut requests: Vec<GenRequest> = Vec::with_capacity(matching.len());
        for &i in matching.iter().rev() {
            requests.push(self.queue.remove(i).unwrap());
        }
        requests.reverse();
        let capacity = self.capacity_for(requests.len());
        Some(Batch { requests, capacity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize) -> GenRequest {
        GenRequest::new(id, vec![1; plen], 8)
    }

    fn cfg(wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(wait_ms),
            max_queue: 8,
        }
    }

    #[test]
    fn fills_full_batch_immediately() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..5 {
            assert!(b.submit(req(i, 16)));
        }
        let batch = b.next_batch(Instant::now()).expect("full batch");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(batch.capacity, 4);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_more_until_timeout() {
        let mut b = Batcher::new(cfg(1000));
        b.submit(req(0, 16));
        assert!(b.next_batch(Instant::now()).is_none());
        // after the timeout, a partial batch is released
        let later = Instant::now() + Duration::from_millis(1500);
        let batch = b.next_batch(later).expect("timeout batch");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.capacity, 1);
    }

    #[test]
    fn buckets_by_prompt_length() {
        let mut b = Batcher::new(cfg(0)); // immediate release
        b.submit(req(0, 16));
        b.submit(req(1, 32));
        b.submit(req(2, 16));
        let batch = b.next_batch(Instant::now()).unwrap();
        let lens: Vec<usize> = batch.requests.iter().map(|r| r.prompt.len()).collect();
        assert_eq!(lens, vec![16, 16]);
        assert_eq!(b.len(), 1); // the 32-token request remains
        let batch2 = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch2.requests[0].prompt.len(), 32);
    }

    #[test]
    fn admission_control_sheds_load() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..8 {
            assert!(b.submit(req(i, 4)));
        }
        assert!(!b.submit(req(99, 4)));
    }

    #[test]
    fn pop_ready_is_fifo() {
        let mut b = Batcher::new(cfg(1000));
        b.submit(req(1, 16));
        b.submit(req(2, 32));
        b.submit(req(3, 16));
        assert_eq!(b.pop_ready().unwrap().id, 1);
        assert_eq!(b.pop_ready().unwrap().id, 2);
        assert_eq!(b.pop_ready().unwrap().id, 3);
        assert!(b.pop_ready().is_none());
    }

    #[test]
    fn aligned_groups_do_not_starve_odd_lengths() {
        // a sustained stream of length-16 prompts must not indefinitely
        // starve a queued length-32 prompt: once the 32 is oldest it is
        // released within its own max_wait.
        let mut b = Batcher::new(cfg(0)); // max_wait 0 => immediate release
        let mut next_id = 0u64;
        let mut sub16 = |b: &mut Batcher, n: usize| {
            for _ in 0..n {
                next_id += 1;
                b.submit(req(next_id, 16));
            }
        };
        sub16(&mut b, 3);
        b.submit(req(999, 32));
        let mut released_32_after = None;
        for round in 0..10 {
            // keep the length-16 pressure up between releases
            sub16(&mut b, 4);
            let batch = b.next_batch(Instant::now()).expect("release under timeout");
            if batch.requests.iter().any(|r| r.id == 999) {
                released_32_after = Some(round);
                break;
            }
        }
        let round = released_32_after.expect("length-32 request starved for 10 rounds");
        assert!(round <= 2, "length-32 request waited {round} rounds");
    }

    #[test]
    fn capacity_rounds_to_compiled_sizes() {
        let mut b = Batcher::new(cfg(0));
        b.submit(req(0, 8));
        b.submit(req(1, 8));
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.capacity, 4); // padded to the compiled size
    }
}
