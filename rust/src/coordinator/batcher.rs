//! Dynamic batcher: admission queue + batch forming.
//!
//! Two release disciplines sit on one insertion-ordered admission queue:
//!
//! * **Continuous** ([`Batcher::pop_ready`]) — pop the best queued
//!   request the moment a decode slot frees. "Best" is lowest
//!   *effective class* (declared [`Priority`] improved by one step per
//!   [`BatcherConfig::age_after`] waited — the aging bound below), FIFO
//!   within a class. With a single class this degenerates to pure
//!   arrival order.
//! * **Aligned groups** ([`Batcher::next_batch`]) — for lock-step
//!   surfaces (the PJRT artifacts share a scalar `pos0` across batch
//!   slots, so a batch must be position-aligned): gather requests with
//!   the best request's prompt length, release when a full batch is
//!   available or the best has waited `max_wait`. Because grouping
//!   always keys off the *best* request, an odd-length request rises
//!   to the front as earlier arrivals drain and is released within its
//!   own `max_wait` — a stream of other lengths cannot starve it (see
//!   the anti-starvation test).
//!
//! **Anti-starvation aging.** Strict priority order would let a
//! sustained stream of high-class arrivals starve the batch class
//! forever. Instead a queued request's effective class improves by one
//! step for every `age_after` it has waited, so after
//! `(N_CLASSES - 1) * age_after` the lowest class competes at the top
//! class's level and plain FIFO order admits it.
//!
//! **Displacement.** When the queue is full, an arriving request of a
//! strictly higher class displaces the youngest queued request of the
//! worst (declared) class below it instead of being shed; the displaced
//! request is handed back to the caller to emit its shed event. A
//! lower-or-equal class arrival into a full queue is shed as before.

use super::request::GenRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Effective class index of a waiting request at `now`: the declared
/// class improved one step per `age_after` waited, saturating at the
/// top class (zero `age_after` disables aging). Shared by the batcher's
/// queue ordering and the serving loop's parked-request resume ordering
/// so one starvation bound covers both waiting sets.
pub(crate) fn effective_class(age_after: Duration, req: &GenRequest, now: Instant) -> usize {
    let class = req.class.index();
    if age_after.is_zero() {
        return class;
    }
    let waited = now.saturating_duration_since(req.arrived);
    let steps = (waited.as_nanos() / age_after.as_nanos()) as usize;
    class.saturating_sub(steps)
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// compiled batch sizes, ascending (e.g. [1, 4])
    pub batch_sizes: Vec<usize>,
    pub max_wait: Duration,
    /// admission bound; submit fails beyond this
    pub max_queue: usize,
    /// anti-starvation aging: a queued request's effective class
    /// improves one step per `age_after` waited (zero disables aging)
    pub age_after: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(20),
            max_queue: 1024,
            age_after: Duration::from_millis(500),
        }
    }
}

/// Outcome of [`Batcher::submit`].
#[derive(Debug)]
pub enum Submitted {
    /// Queued. `displaced` carries the lower-class request this one
    /// pushed out of a full queue (the caller emits its shed event).
    Queued { displaced: Option<GenRequest> },
    /// Queue full of same-or-higher-class requests: shed the arrival.
    Shed(GenRequest),
}

impl Submitted {
    /// Whether the submitted request itself was admitted.
    pub fn admitted(&self) -> bool {
        matches!(self, Submitted::Queued { .. })
    }
}

/// A formed batch (position-aligned requests).
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<GenRequest>,
    /// the compiled batch size to run (>= requests.len())
    pub capacity: usize,
}

#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.batch_sizes.is_empty());
        let mut cfg = cfg;
        cfg.batch_sizes.sort_unstable();
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn max_batch(&self) -> usize {
        *self.cfg.batch_sizes.last().unwrap()
    }

    /// Admission control. A full queue sheds the arrival unless a
    /// strictly lower-class request can be displaced in its favour.
    pub fn submit(&mut self, req: GenRequest) -> Submitted {
        if self.queue.len() >= self.cfg.max_queue {
            // youngest queued request of the worst declared class
            let victim = self
                .queue
                .iter()
                .enumerate()
                .filter(|(_, r)| r.class > req.class)
                .max_by_key(|(_, r)| (r.class, r.arrived));
            return match victim.map(|(i, _)| i) {
                Some(i) => {
                    let displaced = self.queue.remove(i).unwrap();
                    self.queue.push_back(req);
                    Submitted::Queued { displaced: Some(displaced) }
                }
                None => Submitted::Shed(req),
            };
        }
        self.queue.push_back(req);
        Submitted::Queued { displaced: None }
    }

    /// Queue index of the best request at `now`: lowest effective
    /// class, FIFO within a class.
    fn best_index(&self, now: Instant) -> Option<usize> {
        self.queue
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| (effective_class(self.cfg.age_after, r, now), r.arrived))
            .map(|(i, _)| i)
    }

    /// The request [`Batcher::pop_ready`] would return at `now`, without
    /// removing it (the serving loop compares it against parked
    /// candidates before committing to an admission).
    pub fn peek_ready(&self, now: Instant) -> Option<&GenRequest> {
        self.best_index(now).map(|i| &self.queue[i])
    }

    /// Continuous admission: pop the best queued request (effective
    /// class order, FIFO within a class).
    pub fn pop_ready(&mut self, now: Instant) -> Option<GenRequest> {
        let i = self.best_index(now)?;
        self.queue.remove(i)
    }

    /// Per-class queue depths (indexed by [`Priority::index`]).
    pub fn queued_by_class(&self) -> [usize; crate::coordinator::request::N_CLASSES] {
        let mut n = [0usize; crate::coordinator::request::N_CLASSES];
        for r in &self.queue {
            n[r.class.index()] += 1;
        }
        n
    }

    /// The smallest compiled batch size that fits `n` requests.
    fn capacity_for(&self, n: usize) -> usize {
        for &b in &self.cfg.batch_sizes {
            if b >= n {
                return b;
            }
        }
        self.max_batch()
    }

    /// Form the next batch, or None if the queue should keep waiting.
    ///
    /// Policy: take the best request (effective class order, FIFO
    /// within a class); gather up to `max_batch` requests with the SAME
    /// prompt length (position alignment); release when the group fills
    /// the largest batch or the best has waited `max_wait`.
    pub fn next_batch(&mut self, now: Instant) -> Option<Batch> {
        let best = &self.queue[self.best_index(now)?];
        let len0 = best.prompt.len();
        let arrived0 = best.arrived;
        let matching: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, r)| r.prompt.len() == len0)
            .map(|(i, _)| i)
            .take(self.max_batch())
            .collect();

        let timed_out = now.duration_since(arrived0) >= self.cfg.max_wait;
        if matching.len() < self.max_batch() && !timed_out {
            return None;
        }

        // remove back-to-front so indices stay valid
        let mut requests: Vec<GenRequest> = Vec::with_capacity(matching.len());
        for &i in matching.iter().rev() {
            requests.push(self.queue.remove(i).unwrap());
        }
        requests.reverse();
        let capacity = self.capacity_for(requests.len());
        Some(Batch { requests, capacity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Priority;

    fn req(id: u64, plen: usize) -> GenRequest {
        GenRequest::new(id, vec![1; plen], 8)
    }

    fn preq(id: u64, class: Priority) -> GenRequest {
        GenRequest::new(id, vec![1; 8], 8).with_class(class)
    }

    fn cfg(wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(wait_ms),
            max_queue: 8,
            // effectively no aging within a test's timescale
            age_after: Duration::from_secs(3600),
        }
    }

    #[test]
    fn fills_full_batch_immediately() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..5 {
            assert!(b.submit(req(i, 16)).admitted());
        }
        let batch = b.next_batch(Instant::now()).expect("full batch");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(batch.capacity, 4);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_more_until_timeout() {
        let mut b = Batcher::new(cfg(1000));
        b.submit(req(0, 16));
        assert!(b.next_batch(Instant::now()).is_none());
        // after the timeout, a partial batch is released
        let later = Instant::now() + Duration::from_millis(1500);
        let batch = b.next_batch(later).expect("timeout batch");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.capacity, 1);
    }

    #[test]
    fn buckets_by_prompt_length() {
        let mut b = Batcher::new(cfg(0)); // immediate release
        b.submit(req(0, 16));
        b.submit(req(1, 32));
        b.submit(req(2, 16));
        let batch = b.next_batch(Instant::now()).unwrap();
        let lens: Vec<usize> = batch.requests.iter().map(|r| r.prompt.len()).collect();
        assert_eq!(lens, vec![16, 16]);
        assert_eq!(b.len(), 1); // the 32-token request remains
        let batch2 = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch2.requests[0].prompt.len(), 32);
    }

    #[test]
    fn admission_control_sheds_load() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..8 {
            assert!(b.submit(req(i, 4)).admitted());
        }
        match b.submit(req(99, 4)) {
            Submitted::Shed(r) => assert_eq!(r.id, 99),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn pop_ready_is_fifo() {
        let mut b = Batcher::new(cfg(1000));
        b.submit(req(1, 16));
        b.submit(req(2, 32));
        b.submit(req(3, 16));
        let now = Instant::now();
        assert_eq!(b.pop_ready(now).unwrap().id, 1);
        assert_eq!(b.pop_ready(now).unwrap().id, 2);
        assert_eq!(b.pop_ready(now).unwrap().id, 3);
        assert!(b.pop_ready(now).is_none());
    }

    #[test]
    fn pop_ready_orders_by_class_then_fifo() {
        let mut b = Batcher::new(cfg(1000));
        b.submit(preq(1, Priority::Batch));
        b.submit(preq(2, Priority::Standard));
        b.submit(preq(3, Priority::Interactive));
        b.submit(preq(4, Priority::Interactive));
        let now = Instant::now();
        let order: Vec<u64> = std::iter::from_fn(|| b.pop_ready(now).map(|r| r.id)).collect();
        assert_eq!(order, vec![3, 4, 2, 1]);
    }

    #[test]
    fn aging_admits_lowest_class_under_pressure() {
        let mut c = cfg(1000);
        c.age_after = Duration::from_millis(10);
        let mut b = Batcher::new(c);
        b.submit(preq(1, Priority::Batch));
        for id in 2..6 {
            b.submit(preq(id, Priority::Interactive));
        }
        // freshly queued: interactive wins
        assert_eq!(b.pop_ready(Instant::now()).unwrap().id, 2);
        // after 2 aging steps the batch request competes at class 0 and
        // is the oldest there, so sustained pressure no longer starves it
        let later = Instant::now() + Duration::from_millis(25);
        assert_eq!(b.pop_ready(later).unwrap().id, 1);
        assert_eq!(b.queued_by_class(), [3, 0, 0]);
    }

    #[test]
    fn full_queue_displaces_lower_class_only() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..7 {
            assert!(b.submit(preq(i, Priority::Standard)).admitted());
        }
        assert!(b.submit(preq(7, Priority::Batch)).admitted());
        // full queue: an interactive arrival displaces the youngest of
        // the worst class (the batch request), never a peer or better
        match b.submit(preq(100, Priority::Interactive)) {
            Submitted::Queued { displaced: Some(d) } => assert_eq!(d.id, 7),
            other => panic!("expected displacement, got {other:?}"),
        }
        assert_eq!(b.len(), 8);
        // still full, now all standard-or-better: a batch arrival sheds
        // itself and a standard arrival has no strictly-lower victim
        assert!(matches!(b.submit(preq(101, Priority::Batch)), Submitted::Shed(_)));
        assert!(matches!(b.submit(preq(102, Priority::Standard)), Submitted::Shed(_)));
        // the displaced-in interactive request pops first
        assert_eq!(b.pop_ready(Instant::now()).unwrap().id, 100);
    }

    #[test]
    fn aligned_groups_do_not_starve_odd_lengths() {
        // a sustained stream of length-16 prompts must not indefinitely
        // starve a queued length-32 prompt: once the 32 is oldest it is
        // released within its own max_wait.
        let mut b = Batcher::new(cfg(0)); // max_wait 0 => immediate release
        let mut next_id = 0u64;
        let mut sub16 = |b: &mut Batcher, n: usize| {
            for _ in 0..n {
                next_id += 1;
                b.submit(req(next_id, 16));
            }
        };
        sub16(&mut b, 3);
        b.submit(req(999, 32));
        let mut released_32_after = None;
        for round in 0..10 {
            // keep the length-16 pressure up between releases
            sub16(&mut b, 4);
            let batch = b.next_batch(Instant::now()).expect("release under timeout");
            if batch.requests.iter().any(|r| r.id == 999) {
                released_32_after = Some(round);
                break;
            }
        }
        let round = released_32_after.expect("length-32 request starved for 10 rounds");
        assert!(round <= 2, "length-32 request waited {round} rounds");
    }

    #[test]
    fn capacity_rounds_to_compiled_sizes() {
        let mut b = Batcher::new(cfg(0));
        b.submit(req(0, 8));
        b.submit(req(1, 8));
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.capacity, 4); // padded to the compiled size
    }
}
