//! Serving metrics: TTFT, per-token latency, throughput, queue depth.

use crate::util::timer::LatencyStats;
use std::time::Instant;

#[derive(Debug)]
pub struct ServeMetrics {
    pub started: Instant,
    pub requests_in: usize,
    pub requests_done: usize,
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    pub batches_formed: usize,
    pub batch_occupancy_sum: f64,
    pub ttft: LatencyStats,
    pub per_token: LatencyStats,
    pub e2e: LatencyStats,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            started: Instant::now(),
            requests_in: 0,
            requests_done: 0,
            tokens_prefilled: 0,
            tokens_generated: 0,
            batches_formed: 0,
            batch_occupancy_sum: 0.0,
            ttft: LatencyStats::new(),
            per_token: LatencyStats::new(),
            e2e: LatencyStats::new(),
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&mut self, occupied: usize, capacity: usize) {
        self.batches_formed += 1;
        self.batch_occupancy_sum += occupied as f64 / capacity.max(1) as f64;
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.batches_formed == 0 {
            0.0
        } else {
            self.batch_occupancy_sum / self.batches_formed as f64
        }
    }

    /// Decode throughput over the whole run (tokens/second).
    pub fn decode_tps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed > 0.0 {
            self.tokens_generated as f64 / elapsed
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={}/{} prefill_tokens={} gen_tokens={} tps={:.1} occupancy={:.2}\n  {}\n  {}\n  {}",
            self.requests_done,
            self.requests_in,
            self.tokens_prefilled,
            self.tokens_generated,
            self.decode_tps(),
            self.mean_occupancy(),
            self.ttft.report("ttft"),
            self.per_token.report("per-token"),
            self.e2e.report("e2e"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let mut m = ServeMetrics::new();
        m.record_batch(2, 4);
        m.record_batch(4, 4);
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-9);
    }
}
