//! Serving metrics: TTFT, per-token latency, throughput, slot occupancy,
//! admission latency and KV-pool pressure.
//!
//! Two occupancy views coexist:
//! * **batch occupancy** ([`ServeMetrics::record_batch`]) — how full each
//!   aligned lock-step group was when it formed (legacy view, only
//!   populated on the non-continuous path),
//! * **slot occupancy** ([`ServeMetrics::record_step`]) — per decode
//!   step, how many of the pool's slots held live requests. This is the
//!   number continuous batching exists to maximise; the histogram shows
//!   the full distribution (steps by occupied-slot count).
//!
//! When the backend serves from a paged KV pool, the serving loop also
//! snapshots [`KvPoolStats`] into [`ServeMetrics::kv_pool`]: pages in
//! use (real memory pressure, as opposed to the dense caches'
//! capacity-sized `resident_bytes`), prefix-cache hits and tokens
//! reused, copy-on-write copies, and failed (shed) allocations.

use super::request::{Priority, N_CLASSES};
use crate::engine::kv::KvPoolStats;
use crate::util::hist::Hist;
use crate::util::json::Json;
use std::time::Instant;

/// Phases with a dedicated latency histogram on [`ServeMetrics`], indexed
/// into [`ServeMetrics::phases`]. These mirror the flight recorder's span
/// taxonomy ([`crate::trace::Phase`]) but aggregate constant-memory
/// distributions instead of individual events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum MetricPhase {
    /// Prompt prefill per admitted request.
    Prefill = 0,
    /// One batched decode step (wall time of the whole step).
    DecodeStep = 1,
    /// Speculative drafting share of a decode step.
    Draft = 2,
    /// Speculative verification share of a decode step.
    Verify = 3,
    /// Sampling share of a decode step.
    Sampler = 4,
    /// One KV swap-out or swap-in (overload preempt/resume traffic).
    KvSwap = 5,
}

/// Number of [`MetricPhase`] buckets.
pub const N_PHASES: usize = 6;

/// Phase names, indexed like [`ServeMetrics::phases`] (stable: these are
/// the Prometheus `phase` label values and the JSON `phases` keys).
pub const PHASE_NAMES: [&str; N_PHASES] =
    ["prefill", "decode_step", "draft", "verify", "sampler", "kv_swap"];

/// Speculative-decoding counters for one acceptance mode (greedy argmax
/// vs stochastic rejection sampling). The serving loop keeps one per
/// mode so mixed traffic reports per-mode acceptance rates; the legacy
/// totals on [`ServeMetrics`] stay the across-mode sums.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecModeStats {
    /// speculative steps executed (slots × scheduling steps)
    pub steps: usize,
    /// draft tokens proposed
    pub proposed: usize,
    /// draft tokens the verifier accepted
    pub accepted: usize,
    /// accepted draft tokens actually emitted to streams (≤ `accepted`:
    /// a stop token or budget can truncate a step's tail)
    pub committed: usize,
}

impl SpecModeStats {
    /// Fraction of proposed draft tokens accepted in this mode.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Per-priority-class lifecycle counters. Every overload transition the
/// coordinator takes — preempting a slot to host KV, resuming it,
/// degrading a running slot's decode mode, shedding — lands in exactly
/// one class bucket, so a trace can be reconciled class by class:
/// `submitted == done + shed + still-in-flight` and
/// `preemptions == resumes + still-parked`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassStats {
    /// requests submitted declaring this class
    pub submitted: usize,
    /// requests of this class that completed
    pub done: usize,
    /// requests of this class shed (queue overflow, displacement, or
    /// unrecoverable exhaustion)
    pub shed: usize,
    /// times a running slot of this class was preempted (KV swapped out
    /// to the host parking buffer, pages freed)
    pub preemptions: usize,
    /// times a parked request of this class was swapped back in
    pub resumes: usize,
    /// degradation transitions applied while a request of this class
    /// occupied a slot (spec-K cap, bare branch, or shadow routing)
    pub degrades: usize,
    /// degradation transitions lifted (pressure receded)
    pub restores: usize,
}

#[derive(Debug, Clone)]
pub struct ServeMetrics {
    pub started: Instant,
    pub requests_in: usize,
    pub requests_done: usize,
    /// shed (queue overflow) or rejected (validation) requests
    pub requests_shed: usize,
    /// requests abandoned mid-stream (event sink dropped): slot released,
    /// KV pages freed, decoding stopped
    pub cancellations: usize,
    /// prompt positions the engine actually prefilled (positions served
    /// from the KV prefix cache are excluded on the continuous path)
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    /// aligned lock-step groups formed (non-continuous path)
    pub batches_formed: usize,
    pub batch_occupancy_sum: f64,
    /// persistent slot pools opened (1 per continuous run)
    pub pools_opened: usize,
    /// requests admitted into a decode slot
    pub admissions: usize,
    /// batched decode steps executed
    pub decode_steps: usize,
    /// sum over decode steps of occupied/pool-capacity
    pub slot_occupancy_sum: f64,
    /// most slots ever simultaneously occupied
    pub peak_occupied: usize,
    /// decode steps by occupied-slot count (index = occupied slots)
    pub occupancy_hist: Vec<usize>,
    /// speculative steps executed (slots × scheduling steps on the
    /// speculative path)
    pub spec_steps: usize,
    /// draft tokens proposed across all speculative steps
    pub spec_proposed: usize,
    /// draft tokens accepted (each one a token committed without its
    /// own verifier weight stream)
    pub spec_accepted: usize,
    /// speculative counters for greedy (argmax-accept) slots
    pub spec_greedy: SpecModeStats,
    /// speculative counters for sampled (rejection-sampling) slots
    pub spec_sampled: SpecModeStats,
    /// decode-phase persistent-weight read bytes (target + draft),
    /// accumulated per scheduling step when the backend meters traffic
    /// (prefill traffic deliberately excluded); 0 otherwise
    pub weight_bytes: u64,
    /// queue wait: request arrival → slot admission
    pub admission_wait: Hist,
    pub ttft: Hist,
    /// server-side inter-token latency: gap between consecutive token
    /// emissions of the same request (speculative bursts record 0-gap
    /// entries for the extra tokens committed in one step)
    pub itl: Hist,
    pub per_token: Hist,
    pub e2e: Hist,
    /// per-phase latency histograms, indexed by [`MetricPhase`]
    pub phases: [Hist; N_PHASES],
    /// current degradation controller level (0 = none)
    pub degrade_level: usize,
    /// latest paged KV-pool snapshot (None on dense/PJRT backends)
    pub kv_pool: Option<KvPoolStats>,
    /// per-priority-class lifecycle counters, indexed by
    /// [`Priority::index`]
    pub classes: [ClassStats; N_CLASSES],
    /// bytes moved through the host parking buffer by KV swap-outs
    pub swapped_bytes: u64,
    /// requests currently parked (swapped out, awaiting resume)
    pub parked: usize,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            started: Instant::now(),
            requests_in: 0,
            requests_done: 0,
            requests_shed: 0,
            cancellations: 0,
            tokens_prefilled: 0,
            tokens_generated: 0,
            batches_formed: 0,
            batch_occupancy_sum: 0.0,
            pools_opened: 0,
            admissions: 0,
            decode_steps: 0,
            slot_occupancy_sum: 0.0,
            peak_occupied: 0,
            occupancy_hist: Vec::new(),
            spec_steps: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            spec_greedy: SpecModeStats::default(),
            spec_sampled: SpecModeStats::default(),
            weight_bytes: 0,
            admission_wait: Hist::new(),
            ttft: Hist::new(),
            itl: Hist::new(),
            per_token: Hist::new(),
            e2e: Hist::new(),
            phases: std::array::from_fn(|_| Hist::new()),
            degrade_level: 0,
            kv_pool: None,
            classes: [ClassStats::default(); N_CLASSES],
            swapped_bytes: 0,
            parked: 0,
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&mut self, occupied: usize, capacity: usize) {
        self.batches_formed += 1;
        self.batch_occupancy_sum += occupied as f64 / capacity.max(1) as f64;
    }

    /// One request admitted into a slot after `wait_us` in the queue.
    pub fn record_admission(&mut self, wait_us: f64) {
        self.admissions += 1;
        self.admission_wait.record_us(wait_us);
    }

    /// One decode step ran with `occupied` of `capacity` slots live.
    pub fn record_step(&mut self, occupied: usize, capacity: usize) {
        self.decode_steps += 1;
        self.slot_occupancy_sum += occupied as f64 / capacity.max(1) as f64;
        if occupied > self.peak_occupied {
            self.peak_occupied = occupied;
        }
        if self.occupancy_hist.len() <= occupied {
            self.occupancy_hist.resize(occupied + 1, 0);
        }
        self.occupancy_hist[occupied] += 1;
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.batches_formed == 0 {
            0.0
        } else {
            self.batch_occupancy_sum / self.batches_formed as f64
        }
    }

    /// Mean fraction of the slot pool doing useful work per decode step.
    pub fn mean_slot_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.slot_occupancy_sum / self.decode_steps as f64
        }
    }

    /// Compact occupancy histogram, e.g. `1:12 2:30 4:200`.
    pub fn occupancy_histogram(&self) -> String {
        let cells: Vec<String> = self
            .occupancy_hist
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(occ, &n)| format!("{occ}:{n}"))
            .collect();
        if cells.is_empty() {
            "-".to_string()
        } else {
            cells.join(" ")
        }
    }

    /// One speculative step for one slot. `sampled` picks the mode
    /// bucket (stochastic vs greedy acceptance); `committed` is how many
    /// accepted drafts were actually emitted to the stream (a stop token
    /// or generation budget can truncate the tail). The legacy totals
    /// stay the across-mode sums.
    pub fn record_spec_step(
        &mut self,
        sampled: bool,
        proposed: usize,
        accepted: usize,
        committed: usize,
    ) {
        self.spec_steps += 1;
        self.spec_proposed += proposed;
        self.spec_accepted += accepted;
        let m = if sampled { &mut self.spec_sampled } else { &mut self.spec_greedy };
        m.steps += 1;
        m.proposed += proposed;
        m.accepted += accepted;
        m.committed += committed;
    }

    /// Mutable per-class counter bucket for `class`.
    pub fn class(&mut self, class: Priority) -> &mut ClassStats {
        &mut self.classes[class.index()]
    }

    /// Record one sample into a per-phase latency histogram.
    pub fn record_phase_us(&mut self, phase: MetricPhase, us: f64) {
        self.phases[phase as usize].record_us(us);
    }

    /// Record a nanosecond interval into a per-phase histogram (no-op for
    /// zero, so absent backend phase timings don't pollute the buckets).
    pub fn record_phase_ns(&mut self, phase: MetricPhase, ns: u64) {
        if ns > 0 {
            self.phases[phase as usize].record_ns(ns);
        }
    }

    /// Read access to one phase histogram.
    pub fn phase(&self, phase: MetricPhase) -> &Hist {
        &self.phases[phase as usize]
    }

    /// Whether any overload machinery fired (preempt, resume, degrade,
    /// restore, or swap traffic) — gates the report/JSON class blocks so
    /// calm runs keep their legacy shape.
    fn overload_active(&self) -> bool {
        self.swapped_bytes > 0
            || self.parked > 0
            || self.classes.iter().any(|c| {
                c.preemptions > 0 || c.resumes > 0 || c.degrades > 0 || c.restores > 0
            })
    }

    /// Fraction of proposed draft tokens the verifier accepted.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// Mean committed tokens per speculative step (1.0 = no speculation
    /// win; up to K+1).
    pub fn spec_tokens_per_step(&self) -> f64 {
        if self.spec_steps == 0 {
            0.0
        } else {
            (self.spec_steps + self.spec_accepted) as f64 / self.spec_steps as f64
        }
    }

    /// Decode-phase persistent-weight bytes streamed per generated
    /// (accepted + corrected) token — the number speculation exists to
    /// lower. Prefill traffic is excluded by construction.
    pub fn weight_bytes_per_token(&self) -> f64 {
        if self.tokens_generated == 0 {
            0.0
        } else {
            self.weight_bytes as f64 / self.tokens_generated as f64
        }
    }

    /// Decode throughput over the whole run (tokens/second).
    pub fn decode_tps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed > 0.0 {
            self.tokens_generated as f64 / elapsed
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "requests={}/{} (shed {}, cancelled {}) prefill_tokens={} gen_tokens={} tps={:.1}\n  \
             slots: occupancy={:.2} peak={} hist[{}] admissions={} pools={} groups={} (occ {:.2})",
            self.requests_done,
            self.requests_in,
            self.requests_shed,
            self.cancellations,
            self.tokens_prefilled,
            self.tokens_generated,
            self.decode_tps(),
            self.mean_slot_occupancy(),
            self.peak_occupied,
            self.occupancy_histogram(),
            self.admissions,
            self.pools_opened,
            self.batches_formed,
            self.mean_occupancy(),
        );
        if self.spec_steps > 0 {
            out.push_str(&format!(
                "\n  speculative: steps {} proposed {} accepted {} (rate {:.2}, {:.2} tok/step) \
                 weight {:.0} B/tok",
                self.spec_steps,
                self.spec_proposed,
                self.spec_accepted,
                self.spec_acceptance_rate(),
                self.spec_tokens_per_step(),
                self.weight_bytes_per_token(),
            ));
            for (name, m) in [("greedy", &self.spec_greedy), ("sampled", &self.spec_sampled)] {
                if m.steps > 0 {
                    out.push_str(&format!(
                        "\n    {name}: steps {} proposed {} accepted {} committed {} \
                         (rate {:.2})",
                        m.steps,
                        m.proposed,
                        m.accepted,
                        m.committed,
                        m.acceptance_rate(),
                    ));
                }
            }
        }
        if self.overload_active() {
            out.push_str(&format!(
                "\n  overload: parked {} swapped {} B",
                self.parked, self.swapped_bytes,
            ));
            for (i, c) in self.classes.iter().enumerate() {
                if *c == ClassStats::default() {
                    continue;
                }
                out.push_str(&format!(
                    "\n    {}: submitted {} done {} shed {} preempt {} resume {} \
                     degrade {} restore {}",
                    Priority::from_index(i).name(),
                    c.submitted,
                    c.done,
                    c.shed,
                    c.preemptions,
                    c.resumes,
                    c.degrades,
                    c.restores,
                ));
            }
        }
        if let Some(p) = &self.kv_pool {
            out.push_str(&format!(
                "\n  kv pool: pages {}/{} (peak {}) prefix hits {}/{} reused {} tok \
                 cow {} aliased {} evictions {} alloc_failures {}",
                p.pages_in_use,
                p.pages_total,
                p.peak_pages_in_use,
                p.prefix_hits,
                p.prefix_lookups,
                p.prefix_tokens_reused,
                p.cow_copies,
                p.pages_aliased,
                p.prefix_evictions,
                p.alloc_failures,
            ));
        }
        for line in [
            self.admission_wait.report("admission"),
            self.ttft.report("ttft"),
            self.itl.report("itl"),
            self.per_token.report("per-token"),
            self.e2e.report("e2e"),
        ] {
            out.push_str("\n  ");
            out.push_str(&line);
        }
        for (name, h) in PHASE_NAMES.iter().zip(self.phases.iter()) {
            if h.count() > 0 {
                out.push_str("\n  ");
                out.push_str(&h.report(&format!("phase/{name}")));
            }
        }
        out
    }

    /// Snapshot as JSON (the `GET /metrics` response body and the
    /// `BENCH_serve.json` building block).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("uptime_s", self.started.elapsed().as_secs_f64().into()),
            ("requests_in", self.requests_in.into()),
            ("requests_done", self.requests_done.into()),
            ("requests_shed", self.requests_shed.into()),
            ("cancellations", self.cancellations.into()),
            ("tokens_prefilled", self.tokens_prefilled.into()),
            ("tokens_generated", self.tokens_generated.into()),
            ("decode_tps", self.decode_tps().into()),
            ("admissions", self.admissions.into()),
            ("decode_steps", self.decode_steps.into()),
            ("mean_slot_occupancy", self.mean_slot_occupancy().into()),
            ("peak_occupied", self.peak_occupied.into()),
            ("weight_bytes", (self.weight_bytes as f64).into()),
            ("swapped_bytes", (self.swapped_bytes as f64).into()),
            ("parked", self.parked.into()),
            ("degrade_level", self.degrade_level.into()),
            ("classes", self.classes_json()),
            ("admission_wait", lat_json(&self.admission_wait)),
            ("ttft", lat_json(&self.ttft)),
            ("itl", lat_json(&self.itl)),
            ("per_token", lat_json(&self.per_token)),
            ("e2e", lat_json(&self.e2e)),
            ("phases", self.phases_json()),
        ];
        if self.spec_steps > 0 {
            fields.push((
                "speculative",
                Json::obj(vec![
                    ("steps", self.spec_steps.into()),
                    ("proposed", self.spec_proposed.into()),
                    ("accepted", self.spec_accepted.into()),
                    ("acceptance_rate", self.spec_acceptance_rate().into()),
                    ("tokens_per_step", self.spec_tokens_per_step().into()),
                ]),
            ));
        }
        if let Some(p) = &self.kv_pool {
            fields.push((
                "kv_pool",
                Json::obj(vec![
                    ("pages_total", p.pages_total.into()),
                    ("pages_in_use", p.pages_in_use.into()),
                    ("peak_pages_in_use", p.peak_pages_in_use.into()),
                    ("prefix_lookups", p.prefix_lookups.into()),
                    ("prefix_hits", p.prefix_hits.into()),
                    ("prefix_tokens_reused", p.prefix_tokens_reused.into()),
                    ("cow_copies", p.cow_copies.into()),
                    ("pages_aliased", p.pages_aliased.into()),
                    ("alloc_failures", p.alloc_failures.into()),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Per-phase latency histograms as a JSON object keyed by phase name
    /// (only phases that recorded at least one sample appear).
    fn phases_json(&self) -> Json {
        Json::obj(
            PHASE_NAMES
                .iter()
                .zip(self.phases.iter())
                .filter(|(_, h)| h.count() > 0)
                .map(|(name, h)| (*name, h.to_json()))
                .collect::<Vec<_>>(),
        )
    }

    /// Per-class counters as a JSON object keyed by class name. Always
    /// present in [`ServeMetrics::to_json`] (with zeros when the
    /// overload tier never fired) so dashboards and the CI serve-smoke
    /// check can rely on the keys existing.
    fn classes_json(&self) -> Json {
        Json::obj(
            self.classes
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    (
                        Priority::from_index(i).name(),
                        Json::obj(vec![
                            ("submitted", c.submitted.into()),
                            ("done", c.done.into()),
                            ("shed", c.shed.into()),
                            ("preemptions", c.preemptions.into()),
                            ("resumes", c.resumes.into()),
                            ("degrades", c.degrades.into()),
                            ("restores", c.restores.into()),
                        ]),
                    )
                })
                .collect::<Vec<_>>(),
        )
    }
}

/// Latency summary as JSON: count, mean, the tail percentiles every
/// serving dashboard wants, plus the sparse log-bucket array capturing
/// distribution shape.
fn lat_json(l: &Hist) -> Json {
    l.to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let mut m = ServeMetrics::new();
        m.record_batch(2, 4);
        m.record_batch(4, 4);
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn slot_occupancy_and_histogram() {
        let mut m = ServeMetrics::new();
        m.record_step(2, 4);
        m.record_step(4, 4);
        m.record_step(4, 4);
        assert!((m.mean_slot_occupancy() - (0.5 + 1.0 + 1.0) / 3.0).abs() < 1e-9);
        assert_eq!(m.peak_occupied, 4);
        assert_eq!(m.occupancy_hist[2], 1);
        assert_eq!(m.occupancy_hist[4], 2);
        assert_eq!(m.occupancy_histogram(), "2:1 4:2");
    }

    #[test]
    fn speculative_counters() {
        let mut m = ServeMetrics::new();
        m.spec_steps = 4;
        m.spec_proposed = 8;
        m.spec_accepted = 6;
        m.tokens_generated = 10;
        m.weight_bytes = 1000;
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-9);
        assert!((m.spec_tokens_per_step() - 2.5).abs() < 1e-9);
        assert!((m.weight_bytes_per_token() - 100.0).abs() < 1e-9);
        assert!(m.report().contains("speculative: steps 4"));
    }

    #[test]
    fn per_mode_spec_counters_sum_to_totals() {
        let mut m = ServeMetrics::new();
        m.record_spec_step(false, 4, 3, 3);
        m.record_spec_step(true, 4, 2, 1);
        m.record_spec_step(true, 2, 2, 2);
        assert_eq!(m.spec_steps, m.spec_greedy.steps + m.spec_sampled.steps);
        assert_eq!(m.spec_proposed, m.spec_greedy.proposed + m.spec_sampled.proposed);
        assert_eq!(m.spec_accepted, m.spec_greedy.accepted + m.spec_sampled.accepted);
        assert_eq!(m.spec_sampled.committed, 3);
        assert!((m.spec_greedy.acceptance_rate() - 0.75).abs() < 1e-9);
        assert!((m.spec_sampled.acceptance_rate() - 4.0 / 6.0).abs() < 1e-9);
        assert!(m.report().contains("sampled: steps 2"));
    }

    #[test]
    fn json_snapshot_has_latency_keys() {
        let mut m = ServeMetrics::new();
        m.requests_in = 3;
        m.requests_done = 2;
        m.cancellations = 1;
        m.ttft.record_us(1000.0);
        m.itl.record_us(200.0);
        let j = m.to_json();
        assert_eq!(j.get("cancellations").and_then(Json::as_usize), Some(1));
        for lat in ["ttft", "itl", "e2e"] {
            let l = j.get(lat).unwrap_or_else(|| panic!("missing {lat}"));
            for k in ["n", "mean_us", "p50_us", "p95_us", "p99_us", "max_us", "buckets"] {
                assert!(l.get(k).is_some(), "{lat} missing {k}");
            }
        }
        assert!(j.get("speculative").is_none(), "no spec steps → no spec block");
        assert_eq!(j.get("degrade_level").and_then(Json::as_usize), Some(0));
        assert!(j.get("phases").is_some(), "phases object always present");
    }

    #[test]
    fn phase_histograms_record_and_export() {
        let mut m = ServeMetrics::new();
        m.record_phase_us(MetricPhase::Draft, 120.0);
        m.record_phase_ns(MetricPhase::Verify, 90_000);
        m.record_phase_ns(MetricPhase::Sampler, 0); // zero ns = no sample
        assert_eq!(m.phase(MetricPhase::Draft).count(), 1);
        assert_eq!(m.phase(MetricPhase::Verify).count(), 1);
        assert_eq!(m.phase(MetricPhase::Sampler).count(), 0);
        assert!((m.phase(MetricPhase::Verify).mean_us() - 90.0).abs() < 1e-9);
        let phases = m.to_json();
        let phases = phases.get("phases").unwrap();
        assert!(phases.get("draft").is_some());
        assert!(phases.get("verify").is_some());
        assert!(phases.get("sampler").is_none(), "empty phases stay out of JSON");
        assert!(m.report().contains("phase/draft"));
    }

    #[test]
    fn class_counters_and_json_keys() {
        let mut m = ServeMetrics::new();
        assert!(!m.overload_active());
        m.class(Priority::Batch).submitted += 1;
        m.class(Priority::Batch).preemptions += 1;
        m.class(Priority::Batch).resumes += 1;
        m.class(Priority::Interactive).submitted += 2;
        m.class(Priority::Interactive).done += 2;
        m.swapped_bytes = 4096;
        assert!(m.overload_active());
        let rep = m.report();
        assert!(rep.contains("overload: parked 0 swapped 4096 B"));
        assert!(rep.contains("batch: submitted 1 done 0 shed 0 preempt 1 resume 1"));
        assert!(!rep.contains("standard:"), "all-zero classes stay silent");
        let j = m.to_json();
        let classes = j.get("classes").expect("classes object always present");
        for name in ["interactive", "standard", "batch"] {
            let c = classes.get(name).unwrap_or_else(|| panic!("missing class {name}"));
            for k in ["submitted", "done", "shed", "preemptions", "resumes", "degrades", "restores"]
            {
                assert!(c.get(k).is_some(), "{name} missing {k}");
            }
        }
        assert_eq!(
            classes.get("batch").and_then(|c| c.get("preemptions")).and_then(Json::as_usize),
            Some(1)
        );
        assert!(j.get("swapped_bytes").is_some());
        assert!(j.get("parked").is_some());
    }

    #[test]
    fn admission_wait_records() {
        let mut m = ServeMetrics::new();
        m.record_admission(120.0);
        m.record_admission(80.0);
        assert_eq!(m.admissions, 2);
        assert!((m.admission_wait.mean_us() - 100.0).abs() < 1e-9);
    }
}
