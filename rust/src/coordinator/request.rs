//! Request/response types flowing through the coordinator.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// 0.0 => greedy
    pub temperature: f32,
    /// 0 => full distribution
    pub top_k: usize,
    /// nucleus mass kept; >= 1.0 => full distribution
    pub top_p: f32,
    pub seed: u64,
}

impl SamplingParams {
    /// Whether these params sample (vs the greedy argmax fast path).
    pub fn is_sampled(&self) -> bool {
        self.temperature > 0.0
    }
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

/// Priority class of a request. Lower [`Priority::index`] = more
/// important. Admission orders by class (with anti-starvation aging in
/// the batcher) and, under pool or slot exhaustion, the coordinator
/// preempts the lowest class first — never a class above the candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Latency-sensitive traffic; preempted last, shed last.
    Interactive,
    /// Default class for traffic that does not declare one.
    #[default]
    Standard,
    /// Throughput traffic; first to be preempted, degraded or shed.
    Batch,
}

/// Number of priority classes ([`Priority::index`] is `0..N_CLASSES`).
pub const N_CLASSES: usize = 3;

impl Priority {
    /// Dense index for per-class metric arrays (0 = most important).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    pub fn from_index(i: usize) -> Priority {
        match i {
            0 => Priority::Interactive,
            1 => Priority::Standard,
            _ => Priority::Batch,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Parse the wire name (`/v1/generate`'s optional `priority` field).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "standard" => Some(Priority::Standard),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// EOS byte (generation stops when sampled); None = run to budget.
    pub stop_token: Option<u32>,
    /// Priority class: admission order, preemption order, shed order.
    pub class: Priority,
    pub arrived: Instant,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            params: SamplingParams::default(),
            stop_token: None,
            class: Priority::default(),
            arrived: Instant::now(),
        }
    }

    /// Builder: set the priority class.
    pub fn with_class(mut self, class: Priority) -> GenRequest {
        self.class = class;
        self
    }
}

/// One event on a request's response stream. Tokens are delivered as
/// they are sampled; the stream ends with exactly one terminal event
/// ([`GenEvent::Done`] or [`GenEvent::Error`]).
#[derive(Debug, Clone)]
pub enum GenEvent {
    /// `index`-th generated token of request `id`.
    Token { id: u64, index: usize, token: u32 },
    /// Terminal: the request completed.
    Done(GenResponse),
    /// Terminal: the request was shed or rejected.
    Error { id: u64, message: String },
}

impl GenEvent {
    pub fn id(&self) -> u64 {
        match self {
            GenEvent::Token { id, .. } | GenEvent::Error { id, .. } => *id,
            GenEvent::Done(r) => r.id,
        }
    }

    /// Whether this event ends the stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, GenEvent::Done(_) | GenEvent::Error { .. })
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// time from arrival to first generated token
    pub ttft_us: f64,
    /// time from arrival to completion
    pub total_us: f64,
    /// decode-phase seconds (for tk/s accounting)
    pub decode_s: f64,
    /// admission queue wait (arrival → slot placement)
    pub queue_us: f64,
    /// prompt prefill wall time for this request
    pub prefill_us: f64,
}

impl GenResponse {
    pub fn decode_tps(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens.len() as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_round_trips() {
        for i in 0..N_CLASSES {
            let p = Priority::from_index(i);
            assert_eq!(p.index(), i);
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("vip"), None);
        assert_eq!(Priority::default(), Priority::Standard);
        assert!(Priority::Interactive < Priority::Batch);
        let r = GenRequest::new(1, vec![1], 4).with_class(Priority::Batch);
        assert_eq!(r.class, Priority::Batch);
    }

    #[test]
    fn response_tps() {
        let r = GenResponse {
            id: 1,
            prompt_len: 4,
            tokens: vec![1, 2, 3, 4, 5, 6],
            ttft_us: 100.0,
            total_us: 400.0,
            decode_s: 2.0,
            queue_us: 50.0,
            prefill_us: 30.0,
        };
        assert!((r.decode_tps() - 3.0).abs() < 1e-9);
    }
}
