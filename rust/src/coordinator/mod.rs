//! Layer-3 serving coordinator: continuous batching over a slot pool.
//!
//! The paper's contribution is the quantization scheme + fused kernel, so
//! the coordinator is the serving shell that makes it deployable. Decode
//! is memory-bandwidth-bound, which means serving throughput is won or
//! lost on keeping decode slots full — the coordinator therefore
//! schedules **continuously**: the backend exposes a persistent pool of
//! decode slots, requests are admitted into free slots mid-flight (no
//! prompt-length alignment, no lock-step draining) and every sampled
//! token is streamed to the caller as a [`request::GenEvent`].
//!
//! * [`request`] — request/response types, per-stage timestamps and the
//!   streaming event enum,
//! * [`sampler`] — greedy / temperature / top-k / top-p sampling (and
//!   the [`sampler::distribution`] definition the stochastic
//!   speculative path shares),
//! * [`batcher`] — FIFO admission queue with two release disciplines:
//!   continuous per-slot pops, or wait-timeout aligned groups for
//!   lock-step surfaces,
//! * [`backend`] — the slot-pool execution abstraction
//!   (`open_batch` / `prefill_slot` / `decode` / `release_slot`) over
//!   the native engine (default: paged KV pool with prompt-prefix
//!   reuse, see [`crate::engine::kv`]; optional self-speculative
//!   decoding, see [`crate::spec`]) or the PJRT artifacts,
//! * [`server`] — the continuous scheduling loop: admit whenever a slot
//!   frees, step the occupied slots, stream events; under exhaustion it
//!   preempts the lowest priority class via exact KV swap-out instead
//!   of shedding,
//! * [`overload`] — the load-adaptive degradation policy: a hysteretic
//!   pressure controller that caps speculative K, drops to the bare
//!   quantized branch, or routes slots through a lower-bit shadow
//!   engine as pressure rises,
//! * [`metrics`] — TTFT / per-token latency / throughput as log-bucketed
//!   histograms, per-phase (prefill/draft/verify/sampler/KV-swap)
//!   latency distributions, slot-occupancy histogram, admission-latency
//!   and per-priority-class preempt/degrade/shed accounting,
//! * [`prom`] — Prometheus text exposition of the above
//!   (`GET /metrics?format=prometheus`),
//! * [`workload`] — the trace-driven load generator: Poisson / bursty
//!   arrivals, lognormal length mixes with straggler tails, templated
//!   shared prefixes and a greedy/sampled split (drives the `loadgen`
//!   harness and the Fig-7 bench).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod overload;
pub mod prom;
pub mod request;
pub mod sampler;
pub mod server;
pub mod workload;

pub use backend::{
    Backend, BatchState, NativeBackend, ParkedSlot, PjrtBackend, SlotToken, SpecSlot,
};
pub use batcher::{Batcher, BatcherConfig, Submitted};
pub use metrics::{ClassStats, MetricPhase, ServeMetrics, SpecModeStats};
pub use overload::{DegradeConfig, PressureController};
pub use request::{GenEvent, GenRequest, GenResponse, Priority, SamplingParams, N_CLASSES};
pub use sampler::Sampler;
pub use server::{Coordinator, CoordinatorClient, CoordinatorConfig, CoordinatorHandle};
pub use workload::{Arrival, LenDist, ReqMeta, Workload, WorkloadConfig};
