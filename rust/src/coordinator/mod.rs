//! Layer-3 serving coordinator.
//!
//! The paper's contribution is the quantization scheme + fused kernel, so
//! the coordinator is the serving shell that makes it deployable:
//!
//! * [`request`] — request/response types with per-stage timestamps,
//! * [`sampler`] — greedy / temperature / top-k sampling,
//! * [`batcher`] — dynamic batching: admission queue, wait-timeout batch
//!   forming, bucketing by (prompt length, compiled batch size),
//! * [`backend`] — the execution abstraction: the native engine or the
//!   PJRT artifacts (prefill chunking + batched decode),
//! * [`server`] — the coordinator loop: batcher → backend → sampler →
//!   responses, with metrics,
//! * [`metrics`] — TTFT / per-token latency / throughput accounting,
//! * [`workload`] — synthetic request generators for `serve` and the
//!   Fig-7 bench.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod server;
pub mod workload;

pub use backend::{Backend, NativeBackend, PjrtBackend};
pub use batcher::{Batcher, BatcherConfig};
pub use metrics::ServeMetrics;
pub use request::{GenRequest, GenResponse, SamplingParams};
pub use sampler::Sampler;
pub use server::{Coordinator, CoordinatorConfig};
