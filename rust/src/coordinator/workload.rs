//! Trace-driven synthetic workloads for the serving front end, the
//! `loadgen` harness and the Fig-7 / serving benches.
//!
//! A [`Workload`] is a seeded, reproducible request trace with the
//! statistical structure production traffic has and uniform smoke
//! traffic lacks:
//!
//! * **arrivals** — closed-loop (everything at t=0), Poisson at a fixed
//!   rate, or bursty: a two-state on/off modulated Poisson process whose
//!   phase durations are themselves exponential (tail latency lives in
//!   the bursts, not the average rate),
//! * **lengths** — prompt and output budgets drawn from clamped
//!   lognormal distributions ([`LenDist`]), plus a configurable fraction
//!   of long-tail *straggler* outputs that occupy slots far longer than
//!   the median request,
//! * **templated prefixes** — a fraction of prompts share one of
//!   `n_templates` fixed prefixes (system-prompt style), which exercises
//!   the paged KV pool's prefix cache,
//! * **sampling mix** — a fraction of requests decode stochastically
//!   (temperature sampling), the rest greedy; on a speculative backend
//!   this splits traffic across both acceptance modes,
//! * **priority mix + chaos plan** — requests draw a priority class from
//!   [`WorkloadConfig::class_mix`] and a fraction of clients disconnect
//!   mid-stream ([`WorkloadConfig::drop_frac`]), driving the overload
//!   tier's preemption and cancellation paths. Both are drawn from an
//!   **auxiliary** rng stream so that enabling them leaves the base
//!   trace (prompts, lengths, arrivals) bit-identical per seed.
//!
//! Everything is deterministic per seed: the same config yields the same
//! trace, so the in-process and HTTP-loopback harness modes (and any two
//! commits) measure identical traffic.

use super::request::{GenRequest, Priority, SamplingParams, N_CLASSES};
use crate::eval::data::TokenStream;
use crate::util::Pcg64;
use std::time::Duration;

/// Arrival process for open-loop load generation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// No schedule: every request is available at t=0 (closed loop).
    Closed,
    /// Poisson arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// On/off modulated Poisson: requests arrive at `rate_on` req/s
    /// during bursts and `rate_off` req/s between them; phase durations
    /// are exponential with means `mean_on_s` / `mean_off_s` seconds.
    Bursty { rate_on: f64, rate_off: f64, mean_on_s: f64, mean_off_s: f64 },
}

/// Discretized lognormal length distribution clamped to `[min, max]`:
/// `round(exp(log_mean + log_sigma * N(0,1)))`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LenDist {
    /// mean of ln(length) — `exp(log_mean)` is the median length
    pub log_mean: f64,
    /// standard deviation of ln(length)
    pub log_sigma: f64,
    pub min: usize,
    pub max: usize,
}

impl LenDist {
    pub fn new(log_mean: f64, log_sigma: f64, min: usize, max: usize) -> Self {
        LenDist { log_mean, log_sigma, min, max }
    }

    /// A degenerate point distribution (every draw returns `n`).
    pub fn fixed(n: usize) -> Self {
        LenDist { log_mean: (n.max(1) as f64).ln(), log_sigma: 0.0, min: n, max: n }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let x = (self.log_mean + self.log_sigma * rng.normal()).exp();
        (x.round() as usize).clamp(self.min, self.max.max(self.min))
    }
}

/// Per-request trace annotations: which generator paths produced it.
/// The harness groups its latency records by these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReqMeta {
    /// index of the shared prompt-prefix template, if any
    pub template: Option<usize>,
    /// long-tail output (budget multiplied by `straggler_mult`)
    pub straggler: bool,
    /// stochastic (temperature) sampling instead of greedy
    pub sampled: bool,
    /// priority class drawn from [`WorkloadConfig::class_mix`]
    pub class: Priority,
    /// chaos plan: the client disconnects after streaming this many
    /// token events (None = well-behaved client)
    pub drop_after: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    pub arrival: Arrival,
    pub prompt_len: LenDist,
    /// per-request generation budget (`max_new_tokens`)
    pub output_len: LenDist,
    /// fraction of requests whose output budget is multiplied by
    /// `straggler_mult` (long-tail stragglers)
    pub straggler_frac: f64,
    pub straggler_mult: usize,
    /// distinct shared prompt-prefix templates in the trace
    pub n_templates: usize,
    /// shared prefix length per template, in tokens
    pub template_len: usize,
    /// fraction of prompts that start with a templated prefix
    pub template_frac: f64,
    /// fraction of requests decoded with temperature sampling
    pub sampled_frac: f64,
    pub temperature: f32,
    pub top_k: usize,
    /// synthetic token id space when no corpus stream is supplied
    pub vocab: u32,
    /// priority-class weights, indexed by [`Priority::index`]
    /// (normalised at draw time; all-standard by default). Drawn from an
    /// auxiliary rng so enabling a mix does not perturb the base trace.
    pub class_mix: [f64; N_CLASSES],
    /// fraction of requests whose client disconnects mid-stream (the
    /// chaos plan; also drawn from the auxiliary rng)
    pub drop_frac: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 32,
            arrival: Arrival::Poisson { rate: 16.0 },
            // median ~30-token prompts, ~16-token outputs
            prompt_len: LenDist::new(3.4, 0.4, 8, 96),
            output_len: LenDist::new(2.8, 0.5, 4, 64),
            straggler_frac: 0.05,
            straggler_mult: 4,
            n_templates: 4,
            template_len: 24,
            template_frac: 0.5,
            sampled_frac: 0.25,
            temperature: 0.8,
            top_k: 8,
            vocab: 96,
            class_mix: [0.0, 1.0, 0.0],
            drop_frac: 0.0,
            seed: 7,
        }
    }
}

/// A generated trace: requests, their arrival offsets, per-request
/// annotations and the shared template prefixes.
#[derive(Debug, Clone)]
pub struct Workload {
    pub requests: Vec<GenRequest>,
    /// arrival offset of each request from trace start (all zero for
    /// [`Arrival::Closed`])
    pub arrivals: Vec<Duration>,
    pub meta: Vec<ReqMeta>,
    /// the shared prompt-prefix templates (token ids)
    pub templates: Vec<Vec<u32>>,
}

impl Workload {
    /// Largest prompt + output footprint in the trace (for sizing
    /// `max_seq` and KV pools).
    pub fn max_seq(&self) -> usize {
        self.requests.iter().map(|r| r.prompt.len() + r.max_new_tokens).max().unwrap_or(0)
    }

    /// Total generation budget across the trace.
    pub fn total_output_budget(&self) -> usize {
        self.requests.iter().map(|r| r.max_new_tokens).sum()
    }

    /// Clamp every request to fit a model context of `max_seq` tokens
    /// (truncating prompts, shrinking budgets) so a synthetic trace
    /// stays valid on a tiny model instead of drawing 400s.
    pub fn clamp_to(&mut self, max_seq: usize) {
        for r in &mut self.requests {
            let cap = max_seq.saturating_sub(1).max(1);
            r.prompt.truncate(cap);
            let room = max_seq.saturating_sub(r.prompt.len()).max(1);
            r.max_new_tokens = r.max_new_tokens.clamp(1, room);
        }
    }
}

/// Phase state for the bursty arrival process.
struct BurstState {
    on: bool,
    /// seconds left in the current phase
    left: f64,
}

impl BurstState {
    fn init(rng: &mut Pcg64, arrival: &Arrival) -> BurstState {
        match *arrival {
            Arrival::Bursty { mean_on_s, .. } => {
                BurstState { on: true, left: rng.exponential(1.0 / mean_on_s.max(1e-9)) }
            }
            _ => BurstState { on: true, left: 0.0 },
        }
    }
}

/// Seconds until the next arrival under `arrival`, advancing the burst
/// phase state as needed (standard Markov-modulated Poisson stepping:
/// if the candidate wait overruns the phase, consume the phase and
/// redraw in the next one).
fn next_arrival(rng: &mut Pcg64, arrival: &Arrival, state: &mut BurstState) -> f64 {
    match *arrival {
        Arrival::Closed => 0.0,
        Arrival::Poisson { rate } => rng.exponential(rate.max(1e-9)),
        Arrival::Bursty { rate_on, rate_off, mean_on_s, mean_off_s } => {
            let mut gap = 0.0;
            loop {
                let rate = if state.on { rate_on } else { rate_off };
                let wait = if rate > 0.0 { rng.exponential(rate) } else { f64::INFINITY };
                if wait <= state.left {
                    state.left -= wait;
                    return gap + wait;
                }
                gap += state.left;
                state.on = !state.on;
                let mean = if state.on { mean_on_s } else { mean_off_s };
                state.left = rng.exponential(1.0 / mean.max(1e-9));
            }
        }
    }
}

/// Draw `len` prompt tokens: a random window of the corpus stream when
/// one is supplied (real byte statistics), uniform ids below `vocab`
/// otherwise (synthetic checkpoints).
fn draw_tokens(rng: &mut Pcg64, corpus: Option<&TokenStream>, vocab: u32, len: usize) -> Vec<u32> {
    if len == 0 {
        return Vec::new();
    }
    match corpus {
        Some(stream) if stream.tokens().len() > len => {
            let toks = stream.tokens();
            let start = rng.below(toks.len() - len);
            toks[start..start + len].iter().map(|&b| b as u32).collect()
        }
        _ => (0..len).map(|_| rng.next_u32() % vocab.max(1)).collect(),
    }
}

/// Draw a priority class from the normalised `class_mix` weights.
fn draw_class(rng: &mut Pcg64, mix: &[f64; N_CLASSES]) -> Priority {
    let total: f64 = mix.iter().map(|w| w.max(0.0)).sum();
    if total <= 0.0 {
        return Priority::default();
    }
    let mut x = rng.next_f64() * total;
    for (i, w) in mix.iter().enumerate() {
        x -= w.max(0.0);
        if x < 0.0 {
            return Priority::from_index(i);
        }
    }
    Priority::from_index(N_CLASSES - 1)
}

/// Generate a seeded trace. `corpus` supplies prompt bytes when present
/// (the held-out eval stream); synthetic ids below `cfg.vocab` otherwise.
pub fn generate(cfg: &WorkloadConfig, corpus: Option<&TokenStream>) -> Workload {
    let mut rng = Pcg64::seeded(cfg.seed);
    // class/chaos draws come from their own stream so flipping them on
    // cannot shift the base trace's prompts, lengths or arrivals
    let mut aux = Pcg64::seeded(cfg.seed ^ 0x6f76_6572_6c6f_6164);
    let templates: Vec<Vec<u32>> = (0..cfg.n_templates)
        .map(|_| draw_tokens(&mut rng, corpus, cfg.vocab, cfg.template_len))
        .collect();
    let mut burst = BurstState::init(&mut rng, &cfg.arrival);
    let mut requests = Vec::with_capacity(cfg.n_requests);
    let mut arrivals = Vec::with_capacity(cfg.n_requests);
    let mut meta = Vec::with_capacity(cfg.n_requests);
    let mut t = Duration::ZERO;
    for i in 0..cfg.n_requests {
        let template = (!templates.is_empty() && rng.next_f64() < cfg.template_frac)
            .then(|| rng.below(templates.len()));
        let plen = cfg.prompt_len.sample(&mut rng).max(1);
        let mut prompt = match template {
            Some(ti) => templates[ti].clone(),
            None => Vec::new(),
        };
        // unique tail: ≥1 token so two requests on the same template are
        // still distinct sequences past the shared prefix
        let tail = plen.saturating_sub(prompt.len()).max(1);
        prompt.extend(draw_tokens(&mut rng, corpus, cfg.vocab, tail));
        let straggler = rng.next_f64() < cfg.straggler_frac;
        let mut output = cfg.output_len.sample(&mut rng).max(1);
        if straggler {
            output *= cfg.straggler_mult.max(1);
        }
        let sampled = rng.next_f64() < cfg.sampled_frac;
        let mut req = GenRequest::new((i + 1) as u64, prompt, output);
        if sampled {
            req.params = SamplingParams {
                temperature: cfg.temperature,
                top_k: cfg.top_k,
                seed: cfg.seed ^ i as u64,
                ..SamplingParams::default()
            };
        }
        let class = draw_class(&mut aux, &cfg.class_mix);
        req.class = class;
        // the roll is unconditional so changing `drop_frac` re-labels
        // requests without reshuffling the class draws above
        let drop_roll = aux.next_f64();
        let drop_after = (drop_roll < cfg.drop_frac).then(|| aux.below(output.max(1)));
        t += Duration::from_secs_f64(next_arrival(&mut rng, &cfg.arrival, &mut burst));
        requests.push(req);
        arrivals.push(t);
        meta.push(ReqMeta { template, straggler, sampled, class, drop_after });
    }
    Workload { requests, arrivals, meta, templates }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaps(w: &Workload) -> Vec<f64> {
        w.arrivals.windows(2).map(|p| (p[1] - p[0]).as_secs_f64()).collect()
    }

    fn mean(xs: &[f64]) -> f64 {
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    fn cv(xs: &[f64]) -> f64 {
        let m = mean(xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        var.sqrt() / m
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, None);
        let b = generate(&cfg, None);
        for (ra, rb) in a.requests.iter().zip(&b.requests) {
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.max_new_tokens, rb.max_new_tokens);
        }
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.meta, b.meta);
        let c = generate(&WorkloadConfig { seed: 8, ..cfg }, None);
        assert_ne!(a.arrivals, c.arrivals);
    }

    #[test]
    fn closed_arrivals_are_zero_and_poisson_increase() {
        let closed =
            generate(&WorkloadConfig { arrival: Arrival::Closed, ..Default::default() }, None);
        assert!(closed.arrivals.iter().all(|a| *a == Duration::ZERO));
        let open = generate(&WorkloadConfig::default(), None);
        for p in open.arrivals.windows(2) {
            assert!(p[1] >= p[0]);
        }
        assert!(*open.arrivals.last().unwrap() > Duration::ZERO);
    }

    #[test]
    fn poisson_interarrival_mean_and_cv() {
        let cfg = WorkloadConfig {
            n_requests: 4000,
            arrival: Arrival::Poisson { rate: 50.0 },
            seed: 11,
            ..Default::default()
        };
        let w = generate(&cfg, None);
        let g = gaps(&w);
        // Exp(50): mean 0.02 s, CV 1 — generous n=4000 tolerance bands
        assert!((mean(&g) - 0.02).abs() < 0.002, "mean gap {}", mean(&g));
        assert!((cv(&g) - 1.0).abs() < 0.15, "cv {}", cv(&g));
    }

    #[test]
    fn bursty_arrivals_are_overdispersed() {
        let cfg = WorkloadConfig {
            n_requests: 4000,
            arrival: Arrival::Bursty {
                rate_on: 200.0,
                rate_off: 0.0,
                mean_on_s: 0.05,
                mean_off_s: 0.05,
            },
            seed: 12,
            ..Default::default()
        };
        let w = generate(&cfg, None);
        let g = gaps(&w);
        // 50% duty cycle at 200 req/s on → average rate ≈ 100 req/s
        assert!((mean(&g) - 0.01).abs() < 0.0025, "mean gap {}", mean(&g));
        // on/off modulation: inter-arrival CV well above the Poisson 1.0
        assert!(cv(&g) > 1.2, "cv {} not bursty", cv(&g));
    }

    #[test]
    fn length_mix_and_straggler_fraction() {
        let cfg = WorkloadConfig {
            n_requests: 4000,
            template_frac: 0.0,
            straggler_frac: 0.1,
            seed: 13,
            ..Default::default()
        };
        let w = generate(&cfg, None);
        let mut plens: Vec<usize> = w.requests.iter().map(|r| r.prompt.len()).collect();
        plens.sort_unstable();
        let median = plens[plens.len() / 2] as f64;
        // lognormal median = exp(log_mean) ≈ 30
        let expect = cfg.prompt_len.log_mean.exp();
        assert!((median - expect).abs() / expect < 0.2, "median {median} vs {expect}");
        assert!(plens.iter().all(|&l| l >= cfg.prompt_len.min && l <= cfg.prompt_len.max));
        let frac = w.meta.iter().filter(|m| m.straggler).count() as f64 / w.requests.len() as f64;
        assert!((frac - 0.1).abs() < 0.02, "straggler frac {frac}");
        // stragglers carry a multiplied budget: their mean budget must
        // dominate the non-straggler mean
        let (mut s_sum, mut s_n, mut n_sum, mut n_n) = (0usize, 0usize, 0usize, 0usize);
        for (r, m) in w.requests.iter().zip(&w.meta) {
            if m.straggler {
                s_sum += r.max_new_tokens;
                s_n += 1;
            } else {
                n_sum += r.max_new_tokens;
                n_n += 1;
            }
        }
        assert!(s_sum * n_n > 2 * n_sum * s_n, "straggler budgets not long-tailed");
    }

    #[test]
    fn templated_prefix_share_and_uniqueness() {
        let cfg = WorkloadConfig { n_requests: 2000, seed: 14, ..Default::default() };
        let w = generate(&cfg, None);
        let templated = w.meta.iter().filter(|m| m.template.is_some()).count();
        let frac = templated as f64 / w.requests.len() as f64;
        assert!((frac - cfg.template_frac).abs() < 0.05, "template frac {frac}");
        for (r, m) in w.requests.iter().zip(&w.meta) {
            if let Some(ti) = m.template {
                let tpl = &w.templates[ti];
                assert!(r.prompt.len() > tpl.len(), "templated prompt has no unique tail");
                assert_eq!(&r.prompt[..tpl.len()], &tpl[..], "prompt does not share prefix");
            }
        }
    }

    #[test]
    fn sampled_mix_matches_config() {
        let cfg = WorkloadConfig { n_requests: 2000, seed: 15, ..Default::default() };
        let w = generate(&cfg, None);
        let frac = w.meta.iter().filter(|m| m.sampled).count() as f64 / w.requests.len() as f64;
        assert!((frac - cfg.sampled_frac).abs() < 0.04, "sampled frac {frac}");
        for (r, m) in w.requests.iter().zip(&w.meta) {
            assert_eq!(m.sampled, r.params.is_sampled());
        }
    }

    #[test]
    fn corpus_prompts_come_from_stream() {
        let stream = TokenStream::from_vec((0..10_000u32).map(|i| (i % 251) as u8).collect());
        let cfg = WorkloadConfig { n_requests: 64, template_frac: 0.0, ..Default::default() };
        let w = generate(&cfg, Some(&stream));
        for r in &w.requests {
            assert!(r.prompt.iter().all(|&t| t < 251));
        }
    }

    #[test]
    fn clamp_to_fits_context() {
        let mut w = generate(&WorkloadConfig::default(), None);
        w.clamp_to(48);
        for r in &w.requests {
            assert!(r.prompt.len() + r.max_new_tokens <= 48);
            assert!(r.max_new_tokens >= 1);
        }
        assert!(w.max_seq() <= 48);
    }
}
