//! Synthetic request workloads for the `serve` command and the Fig-7 /
//! serving benches: prompts sampled from the held-out corpus, fixed or
//! Poisson arrivals.

use super::request::{GenRequest, SamplingParams};
use crate::eval::data::TokenStream;
use crate::util::Pcg64;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// prompt lengths are drawn from this set (position-aligned batching
    /// needs a small set of lengths to bucket on)
    pub prompt_lens: Vec<usize>,
    pub max_new_tokens: usize,
    /// requests per second for open-loop generation (0 = closed loop)
    pub arrival_rate: f64,
    pub temperature: f32,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 16,
            prompt_lens: vec![32, 64],
            max_new_tokens: 32,
            arrival_rate: 0.0,
            temperature: 0.0,
            seed: 7,
        }
    }
}

/// A generated workload: requests plus (for open loop) arrival offsets.
#[derive(Debug)]
pub struct Workload {
    pub requests: Vec<GenRequest>,
    pub arrivals: Vec<Duration>,
}

/// Sample prompts from a held-out token stream.
pub fn generate(stream: &TokenStream, cfg: &WorkloadConfig) -> Workload {
    let mut rng = Pcg64::seeded(cfg.seed);
    let toks = stream.tokens();
    let mut requests = Vec::with_capacity(cfg.n_requests);
    let mut arrivals = Vec::with_capacity(cfg.n_requests);
    let mut t = Duration::ZERO;
    for i in 0..cfg.n_requests {
        let plen = *rng.choose(&cfg.prompt_lens);
        let start = rng.below(toks.len().saturating_sub(plen + 1));
        let prompt: Vec<u32> = toks[start..start + plen].iter().map(|&b| b as u32).collect();
        let mut req = GenRequest::new((i + 1) as u64, prompt, cfg.max_new_tokens);
        req.params = SamplingParams {
            temperature: cfg.temperature,
            top_k: 8,
            seed: cfg.seed ^ i as u64,
            ..SamplingParams::default()
        };
        requests.push(req);
        if cfg.arrival_rate > 0.0 {
            t += Duration::from_secs_f64(rng.exponential(cfg.arrival_rate));
        }
        arrivals.push(t);
    }
    Workload { requests, arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> TokenStream {
        TokenStream::from_vec((0..10_000u32).map(|i| (i % 251) as u8).collect())
    }

    #[test]
    fn generates_requested_count_and_lengths() {
        let w = generate(&stream(), &WorkloadConfig::default());
        assert_eq!(w.requests.len(), 16);
        for r in &w.requests {
            assert!(r.prompt.len() == 32 || r.prompt.len() == 64);
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let cfg = WorkloadConfig { arrival_rate: 100.0, ..Default::default() };
        let w = generate(&stream(), &cfg);
        for pair in w.arrivals.windows(2) {
            assert!(pair[1] >= pair[0]);
        }
        assert!(*w.arrivals.last().unwrap() > Duration::ZERO);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&stream(), &WorkloadConfig::default());
        let b = generate(&stream(), &WorkloadConfig::default());
        assert_eq!(a.requests[3].prompt, b.requests[3].prompt);
    }
}
