//! Execution backends behind the coordinator: the native engine and the
//! PJRT AOT artifacts share one `Backend` trait so the serving loop,
//! benches and examples are backend-agnostic.

use super::request::GenRequest;
use crate::engine::native::EngineWs;
use crate::engine::{KvCache, NativeEngine, SubMode};
use crate::model::{Config, WeightStore};
use crate::runtime::exec::{build_weight_feed, Value};
use crate::runtime::{ExecRegistry, LoadedExec, Manifest};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Per-batch generation state (opaque to the serving loop).
pub enum BatchState {
    Native { kvs: Vec<KvCache>, pos: usize },
    Pjrt { kv_k: Vec<f32>, kv_v: Vec<f32>, pos: usize, capacity: usize },
}

impl BatchState {
    pub fn pos(&self) -> usize {
        match self {
            BatchState::Native { pos, .. } => *pos,
            BatchState::Pjrt { pos, .. } => *pos,
        }
    }
}

pub trait Backend {
    fn cfg(&self) -> &Config;

    /// Largest compiled/supported batch size.
    fn max_batch(&self) -> usize;

    /// Prefill `prompts` (all the same length) into a fresh batch of
    /// `capacity` slots; returns the state and last-position logits per
    /// *occupied* slot.
    fn prefill(&mut self, prompts: &[&[u32]], capacity: usize) -> Result<(BatchState, Vec<Vec<f32>>)>;

    /// One decode step: `tokens[i]` is the last sampled token of slot `i`.
    /// Returns next-token logits per occupied slot.
    fn decode(&mut self, state: &mut BatchState, tokens: &[u32]) -> Result<Vec<Vec<f32>>>;

    fn name(&self) -> String;
}

/// Validate a batch of requests against backend limits.
pub fn validate_batch(cfg: &Config, reqs: &[GenRequest]) -> Result<()> {
    let Some(first) = reqs.first() else { return Ok(()) };
    let plen = first.prompt.len();
    for r in reqs {
        if r.prompt.is_empty() {
            bail!("request {}: empty prompt", r.id);
        }
        if r.prompt.len() != plen {
            bail!("batch is not prompt-length aligned");
        }
        if r.prompt.len() + r.max_new_tokens > cfg.max_seq {
            bail!(
                "request {}: prompt {} + gen {} exceeds max_seq {}",
                r.id, r.prompt.len(), r.max_new_tokens, cfg.max_seq
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

pub struct NativeBackend {
    engine: NativeEngine,
    ws: EngineWs,
    label: String,
}

impl NativeBackend {
    pub fn new(engine: NativeEngine, label: &str) -> NativeBackend {
        NativeBackend { engine, ws: EngineWs::default(), label: label.to_string() }
    }

    pub fn from_checkpoint(path: &std::path::Path, mode: SubMode, label: &str) -> Result<NativeBackend> {
        let store = WeightStore::load(path)?;
        Ok(NativeBackend::new(NativeEngine::from_store(&store, mode)?, label))
    }

    pub fn engine(&self) -> &NativeEngine {
        &self.engine
    }

    pub fn traffic(&self) -> &crate::engine::Traffic {
        &self.ws.traffic
    }

    pub fn reset_traffic(&mut self) {
        self.ws.traffic.reset();
    }
}

impl Backend for NativeBackend {
    fn cfg(&self) -> &Config {
        &self.engine.cfg
    }

    fn max_batch(&self) -> usize {
        // the native engine decodes sequentially per slot; the batcher may
        // still group requests for fairness/occupancy accounting.
        4
    }

    fn prefill(&mut self, prompts: &[&[u32]], _capacity: usize) -> Result<(BatchState, Vec<Vec<f32>>)> {
        let cfg = self.engine.cfg.clone();
        let mut kvs = Vec::with_capacity(prompts.len());
        let mut logits = Vec::with_capacity(prompts.len());
        for prompt in prompts {
            let mut kv = KvCache::new(cfg.n_layers, cfg.max_seq, cfg.n_heads, cfg.head_dim());
            let lg = self.engine.prefill(prompt, &mut kv, &mut self.ws);
            kvs.push(kv);
            logits.push(lg);
        }
        let pos = prompts.first().map_or(0, |p| p.len());
        Ok((BatchState::Native { kvs, pos }, logits))
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        let BatchState::Native { kvs, pos } = state else {
            bail!("native backend got a foreign batch state");
        };
        if tokens.len() != kvs.len() {
            bail!("decode: {} tokens for {} slots", tokens.len(), kvs.len());
        }
        let mut out = Vec::with_capacity(tokens.len());
        for (kv, &tok) in kvs.iter_mut().zip(tokens) {
            out.push(self.engine.decode_one(tok, kv, &mut self.ws));
        }
        *pos += 1;
        Ok(out)
    }

    fn name(&self) -> String {
        format!("native:{}", self.label)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

struct PjrtArtifacts {
    /// prefill execs by (batch, t_step), t_steps descending
    prefill: Vec<(usize, usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
    /// decode execs by batch
    decode: Vec<(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)>,
}

pub struct PjrtBackend {
    cfg: Config,
    label: String,
    arts: PjrtArtifacts,
    batches: Vec<usize>,
    kv_numel: usize,
    kv_shape: Vec<usize>,
}

impl PjrtBackend {
    /// Load + compile the serve artifacts for `(model, checkpoint)`.
    pub fn new(registry: &mut ExecRegistry, store: &WeightStore,
               batches: &[usize], label: &str) -> Result<PjrtBackend> {
        let cfg = store.cfg.clone();
        let quantized = store.is_quantized();
        let model = cfg.name.clone();
        let mut prefill = Vec::new();
        let mut decode = Vec::new();
        for &b in batches {
            for t_step in [128usize, 32] {
                let name = format!(
                    "prefill_{model}_{}_b{b}_t{t_step}",
                    if quantized { "q" } else { "fp" }
                );
                let exec = registry.load(&name)?;
                let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
                prefill.push((b, t_step, exec, feed));
            }
            let name = Manifest::step_name("decode", &model, quantized, b);
            let exec = registry.load(&name)?;
            let feed = Arc::new(build_weight_feed(&exec.spec, store)?);
            decode.push((b, exec, feed));
        }
        // kv shape from the b=smallest decode spec, scaled per batch at use
        let kv_spec = decode[0]
            .1
            .spec
            .inputs
            .iter()
            .find(|t| t.name == "kv_k")
            .context("decode artifact missing kv_k input")?
            .clone();
        Ok(PjrtBackend {
            cfg,
            label: label.to_string(),
            arts: PjrtArtifacts { prefill, decode },
            batches: batches.to_vec(),
            kv_numel: kv_spec.numel(),
            kv_shape: kv_spec.shape,
        })
    }

    fn kv_len_for(&self, capacity: usize) -> usize {
        // kv shape [L, B, Tm, H, hd] recorded for the smallest batch
        let base_b = self.kv_shape[1];
        self.kv_numel / base_b * capacity
    }

    fn decode_exec(&self, capacity: usize) -> Result<&(usize, Arc<LoadedExec>, Arc<Vec<xla::Literal>>)> {
        self.arts
            .decode
            .iter()
            .find(|(b, _, _)| *b == capacity)
            .with_context(|| format!("no decode artifact for batch {capacity}"))
    }

    /// Split logits [B, V] into per-occupied-slot vectors.
    fn split_logits(&self, flat: &[f32], capacity: usize, occupied: usize) -> Vec<Vec<f32>> {
        let v = self.cfg.vocab;
        debug_assert_eq!(flat.len(), capacity * v);
        (0..occupied).map(|i| flat[i * v..(i + 1) * v].to_vec()).collect()
    }
}

impl Backend for PjrtBackend {
    fn cfg(&self) -> &Config {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        *self.batches.iter().max().unwrap_or(&1)
    }

    fn prefill(&mut self, prompts: &[&[u32]], capacity: usize) -> Result<(BatchState, Vec<Vec<f32>>)> {
        if prompts.is_empty() {
            bail!("empty prefill batch");
        }
        let plen = prompts[0].len();
        if prompts.iter().any(|p| p.len() != plen) {
            bail!("pjrt backend requires prompt-length-aligned batches");
        }
        let mut state = BatchState::Pjrt {
            kv_k: vec![0f32; self.kv_len_for(capacity)],
            kv_v: vec![0f32; self.kv_len_for(capacity)],
            pos: 0,
            capacity,
        };
        // chunk the prompt greedily: 128s, then 32s, then single steps
        let mut consumed = 0usize;
        let mut last_logits: Vec<Vec<f32>> = Vec::new();
        while consumed < plen {
            let rem = plen - consumed;
            let chunk = self
                .arts
                .prefill
                .iter()
                .filter(|(b, t, _, _)| *b == capacity && *t <= rem)
                .map(|(_, t, _, _)| *t)
                .max();
            let (exec, feed, step) = match chunk {
                Some(t) => {
                    let (_, _, e, f) = self
                        .arts
                        .prefill
                        .iter()
                        .find(|(b, tt, _, _)| *b == capacity && *tt == t)
                        .unwrap();
                    (Arc::clone(e), Arc::clone(f), t)
                }
                None => {
                    let (_, e, f) = self.decode_exec(capacity)?;
                    (Arc::clone(e), Arc::clone(f), 1)
                }
            };
            // tokens [capacity, step]: empty slots replay slot 0 (their kv
            // is discarded — the serving loop never reads those logits)
            let mut toks = Vec::with_capacity(capacity * step);
            for slot in 0..capacity {
                let src = prompts.get(slot).unwrap_or(&prompts[0]);
                toks.extend(src[consumed..consumed + step].iter().map(|&t| t as i32));
            }
            let BatchState::Pjrt { kv_k, kv_v, pos, .. } = &mut state else { unreachable!() };
            let data = vec![
                Value::I32(toks),
                Value::I32(vec![*pos as i32]),
                Value::F32(std::mem::take(kv_k)),
                Value::F32(std::mem::take(kv_v)),
            ];
            let out = exec.run(&data, &feed)?;
            let logits = out[0].as_f32()?;
            last_logits = self.split_logits(logits, capacity, prompts.len());
            *kv_k = match &out[1] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_k output not f32"),
            };
            *kv_v = match &out[2] {
                Value::F32(v) => v.clone(),
                _ => bail!("kv_v output not f32"),
            };
            *pos += step;
            consumed += step;
        }
        Ok((state, last_logits))
    }

    fn decode(&mut self, state: &mut BatchState, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        let BatchState::Pjrt { kv_k, kv_v, pos, capacity } = state else {
            bail!("pjrt backend got a foreign batch state");
        };
        let capacity = *capacity;
        let (_, exec, feed) = self.decode_exec(capacity)?;
        let (exec, feed) = (Arc::clone(exec), Arc::clone(feed));
        let mut toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        toks.resize(capacity, *toks.first().unwrap_or(&1));
        let data = vec![
            Value::I32(toks),
            Value::I32(vec![*pos as i32]),
            Value::F32(std::mem::take(kv_k)),
            Value::F32(std::mem::take(kv_v)),
        ];
        let out = exec.run(&data, &feed)?;
        let logits = self.split_logits(out[0].as_f32()?, capacity, tokens.len());
        *kv_k = match &out[1] {
            Value::F32(v) => v.clone(),
            _ => bail!("kv_k output not f32"),
        };
        *kv_v = match &out[2] {
            Value::F32(v) => v.clone(),
            _ => bail!("kv_v output not f32"),
        };
        *pos += 1;
        Ok(logits)
    }

    fn name(&self) -> String {
        format!("pjrt:{}", self.label)
    }
}
